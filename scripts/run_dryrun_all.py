"""Run the full dry-run sweep (all arch x shape x mesh cells) in one process.

Writes results/dryrun/<arch>.<shape>.<mesh>.json per cell plus a combined
results/dryrun/all.json.  Resumable: existing cell files are skipped unless
--force.  Order: cheap cells first so partial results are useful early.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import gc
import json
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCH_IDS, SHAPES_BY_NAME  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402

SHAPE_ORDER = ["train_4k", "decode_32k", "long_500k", "prefill_32k"]
# cheap-first arch order (by rough param count)
ARCH_ORDER = [
    "qwen3-1.7b", "rwkv6-1.6b", "recurrentgemma-2b", "paligemma-3b",
    "phi3-mini-3.8b", "whisper-medium", "stablelm-12b",
    "deepseek-v2-lite-16b", "llama4-scout-17b-a16e", "qwen1.5-110b",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--archs", default="", help="comma list; default all")
    ap.add_argument("--train-microbatch", type=int, default=0,
                    help="gradient-accumulation slices for train cells")
    ap.add_argument("--decode-layout", default="tp",
                    choices=["tp", "serve_tp", "dp_only"])
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    archs = args.archs.split(",") if args.archs else ARCH_ORDER
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    t_start = time.time()
    n_done = 0
    for shape in SHAPE_ORDER:
        for arch in archs:
            for mk in meshes:
                path = os.path.join(args.out_dir, f"{arch}.{shape}.{mk}.json")
                if os.path.exists(path) and not args.force:
                    continue
                kind = ("train" if shape.startswith("train") else
                        "decode" if shape in ("decode_32k", "long_500k") else
                        "prefill")
                mb = args.train_microbatch if kind == "train" else 0
                layout = args.decode_layout if kind == "decode" else "tp"
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, mk, microbatch=mb, layout=layout)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mk,
                           "error": repr(e), "traceback": traceback.format_exc()}
                rec["wall_s"] = time.time() - t0
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2, default=str)
                n_done += 1
                status = ("SKIP" if not rec.get("applicable", True)
                          else "ERR " if "error" in rec else "ok  ")
                dom = rec.get("roofline", {}).get("dominant", "-")
                print(f"[{time.time()-t_start:7.0f}s] {status} {arch:24s} "
                      f"{shape:12s} {mk:6s} {rec['wall_s']:6.1f}s dom={dom}",
                      flush=True)
                gc.collect()

    # combined file
    allrecs = []
    for fn in sorted(os.listdir(args.out_dir)):
        if fn.endswith(".json") and fn != "all.json":
            with open(os.path.join(args.out_dir, fn)) as f:
                allrecs.append(json.load(f))
    with open(os.path.join(args.out_dir, "all.json"), "w") as f:
        json.dump(allrecs, f, indent=2, default=str)
    print(f"DONE: {n_done} cells in {time.time()-t_start:.0f}s")


if __name__ == "__main__":
    main()
