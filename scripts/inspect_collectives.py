"""Hillclimb profiler: compile one cell's depth variant and print the top
collective ops with sizes and jax op_name provenance."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import re
import sys
from collections import Counter

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.hlo import _shape_bytes  # noqa: E402
from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.dryrun import _lower_compile  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", required=True, choices=list(SHAPES_BY_NAME))
    ap.add_argument("--layout", default="tp", choices=["tp","serve_tp","dp_only"])
    ap.add_argument("--full", action="store_true",
                    help="compile the full scanned model instead of depth-1")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    var = cfg if args.full else S.depth_variant(cfg, None, shape)
    mesh = make_production_mesh()
    _, comp = _lower_compile(var, shape, mesh, layout=args.layout)
    txt = comp.as_text()
    sizes = Counter()
    for line in txt.splitlines():
        s = line.strip()
        m = re.search(
            r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
            r"(?:-start)?\(", s)
        if m:
            meta = re.search(r'op_name="([^"]*)"', s)
            key = (m.group(2), m.group(1).split("{")[0][:44],
                   (meta.group(1)[:90] if meta else "?"))
            sizes[key] += 1
    rows = sorted(sizes.items(), key=lambda kv: -_shape_bytes(kv[0][1]) * kv[1])
    total = sum(_shape_bytes(k[1]) * c for k, c in sizes.items())
    print(f"total collective operand bytes (1-layer module): {total/1e9:.3f} GB")
    for (op, shp, name), c in rows[: args.top]:
        print(f"{c:3d}x {_shape_bytes(shp)/1e6:9.1f}MB {op:18s} {shp:46s} {name}")


if __name__ == "__main__":
    main()
