"""Capture a Perfetto-compatible trace of a synthetic serving run.

Runs a seeded serving scenario with span tracing (and optionally the
telemetry sampler) enabled and writes the Chrome trace-event JSON — open it
in https://ui.perfetto.dev or ``chrome://tracing``.  Run from the repo
root::

    PYTHONPATH=src python scripts/export_trace.py --out trace.json
    PYTHONPATH=src python scripts/export_trace.py --out trace.json \
        --mode hedra --ret-workers 4 --n-requests 40 --fault-seed 3 \
        --metrics-out metrics.json --attribution

With ``--fault-seed`` a seeded random FaultPlan (crashes, stalls,
transient failures) is injected so the trace shows hedge duplicates, lost
spans, retry gaps and failover re-dispatch; ``--attribution`` prints the
run-level latency attribution report (components verified to sum to each
request's measured latency).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import workflows
from repro.core.backends import SimBackend
from repro.obs.trace import request_ids_in_trace, validate_trace
from repro.retrieval import (
    CorpusConfig,
    IVFIndex,
    SyntheticEmbedder,
    make_corpus,
)
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.workload import poisson_arrivals

NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp"]
RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Record a serving run and export a Perfetto trace")
    ap.add_argument("--out", required=True, metavar="PATH",
                    help="trace JSON output path")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="also sample the metrics registry and write its "
                         "JSON snapshot here")
    ap.add_argument("--mode", default="hedra",
                    choices=["hedra", "async", "sequential"])
    ap.add_argument("--ret-workers", type=int, default=4)
    ap.add_argument("--n-requests", type=int, default=20)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--index-sharding", action="store_true")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="inject FaultPlan.random(seed, ...) so the trace "
                         "shows recovery structure")
    ap.add_argument("--attribution", action="store_true",
                    help="print the latency attribution report")
    args = ap.parse_args()

    docs, _, topics = make_corpus(CorpusConfig(
        n_docs=12000, dim=48, n_topics=96, zipf_alpha=1.2, seed=0))
    index = IVFIndex.build(docs, 48, iters=4)
    embedder = SyntheticEmbedder(topics)
    fault_plan = None
    if args.fault_seed is not None:
        from repro.serving.faults import FaultPlan

        horizon = args.n_requests / args.rate * 1e6 + 1e6
        fault_plan = FaultPlan.random(args.fault_seed, args.ret_workers,
                                      horizon, transient_prob=0.05)
        print(f"fault plan: {fault_plan.describe()}")
    be = SimBackend(index, embedder, cost_model=RET_HEAVY, seed=0)
    server = Server(index, embedder, mode=args.mode, backend=be, nprobe=12,
                    topk=5, num_ret_workers=args.ret_workers,
                    index_sharding=args.index_sharding,
                    fault_plan=fault_plan, tracing=True,
                    telemetry=args.metrics_out is not None)
    for i, t in enumerate(poisson_arrivals(args.rate, args.n_requests,
                                           seed=5)):
        server.add_request(f"q{i}", workflows.build(NAMES[i % len(NAMES)]),
                          arrival_us=float(t))
    m = server.run()
    trace = server.export_trace(args.out)
    problems = validate_trace(trace)
    if problems:
        for p in problems[:10]:
            print(f"INVALID: {p}", file=sys.stderr)
        sys.exit(1)
    n_ev = len(trace["traceEvents"])
    n_req = len(request_ids_in_trace(trace))
    print(f"served {m.finished} requests; wrote {args.out}: {n_ev} events "
          f"covering {n_req} requests (structurally valid)")
    print("open in https://ui.perfetto.dev or chrome://tracing")
    if args.metrics_out:
        server.metrics_snapshot(args.metrics_out)
        print(f"metrics snapshot written to {args.metrics_out}")
    if args.attribution:
        rep = server.attribution_report()
        print(json.dumps(
            {k: rep[k] for k in ("finished", "totals_us", "fractions",
                                 "means_us", "bottleneck",
                                 "max_rel_residual")},
            indent=2))


if __name__ == "__main__":
    main()
