"""Regenerate tests/golden_fingerprints.json: per-request event-trace
hashes for the five paper workflows across scheduler modes and worker
counts.  Run from the repo root::

    PYTHONPATH=src python scripts/make_golden_fingerprints.py

The goldens pin the serving loop's observable behaviour: any refactor of
the stage/scheduler layers must keep every (mode, num_ret_workers) trace
bit-identical for graphs built only from the paper's two original node
kinds.  tests/test_stage_registry.py recomputes the same hashes and
compares.  Everything below is seeded (synthetic corpus, k-means, workload
lengths, Poisson arrivals, backend noise), so the traces are
machine-independent.
"""
from __future__ import annotations

import hashlib
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import workflows
from repro.core.backends import SimBackend
from repro.retrieval import CorpusConfig, IVFIndex, SyntheticEmbedder, make_corpus
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.workload import poisson_arrivals

NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp"]
RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0, per_query_us=2.0)
MODES = ["hedra", "async", "sequential"]
WORKERS = [1, 4]


def fixture():
    docs, _, topics = make_corpus(CorpusConfig(
        n_docs=12000, dim=48, n_topics=96, zipf_alpha=1.2, seed=0))
    return IVFIndex.build(docs, 48, iters=4), SyntheticEmbedder(topics)


def trace_hash(server) -> str:
    fp = {
        r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
        for r in server.sched.done
    }
    blob = json.dumps(fp, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def main() -> None:
    index, emb = fixture()
    arr = poisson_arrivals(8.0, 20, seed=5)
    out = {}
    for mode in MODES:
        for nw in WORKERS:
            be = SimBackend(index, emb, cost_model=RET_HEAVY, seed=0)
            s = Server(index, emb, mode=mode, backend=be, nprobe=12, topk=5,
                       num_ret_workers=nw)
            for i, t in enumerate(arr):
                s.add_request(f"q{i}", workflows.build(NAMES[i % 5]),
                              arrival_us=float(t))
            m = s.run()
            assert m.finished == 20, (mode, nw, m.finished)
            out[f"{mode}-nw{nw}"] = trace_hash(s)
            print(f"{mode}-nw{nw}: {out[f'{mode}-nw{nw}']}")
    path = os.path.join(os.path.dirname(__file__), "..", "tests",
                        "golden_fingerprints.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.normpath(path)}")


if __name__ == "__main__":
    main()
