"""Build the EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT_DIR = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"

ARCHS = [
    "rwkv6-1.6b", "stablelm-12b", "qwen3-1.7b", "phi3-mini-3.8b",
    "qwen1.5-110b", "recurrentgemma-2b", "whisper-medium",
    "deepseek-v2-lite-16b", "llama4-scout-17b-a16e", "paligemma-3b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(arch, shape, mesh):
    p = os.path.join(OUT_DIR, f"{arch}.{shape}.{mesh}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def fmt_t(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def main():
    rows = []
    print("## Roofline table (single-pod 16x16 = 256 chips)\n")
    print("| arch | shape | status | t_compute | t_memory | t_coll | dominant "
          "| useful/HLO | peak GB/dev | fits 16GB | multi-pod |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            r = load(arch, shape, "single")
            m = load(arch, shape, "multi")
            if r is None:
                print(f"| {arch} | {shape} | MISSING | | | | | | | |")
                continue
            if not r.get("applicable", True):
                print(f"| {arch} | {shape} | SKIP ({r['reason'][:40]}) | | | | | | | | "
                      f"{'skip' if m is None or not m.get('applicable', True) else 'ok'} |")
                continue
            if "error" in r:
                print(f"| {arch} | {shape} | ERROR {r['error'][:40]} | | | | | | | | |")
                continue
            rf = r["roofline"]
            mem = r["memory"]["peak_bytes_est"] / 1e9
            fits = "yes" if mem <= 16.0 else "NO"
            multi_ok = "ok" if (m and "error" not in m and m.get("memory")) else (
                "ERR" if m else "MISSING")
            print(f"| {arch} | {shape} | ok ({r['compile_s']:.0f}s) "
                  f"| {fmt_t(rf['t_compute_s'])} | {fmt_t(rf['t_memory_s'])} "
                  f"| {fmt_t(rf['t_collective_s'])} | **{rf['dominant']}** "
                  f"| {rf['useful_flops_ratio']:.2f} | {mem:.1f} | {fits} | {multi_ok} |")
            rows.append((arch, shape, rf))
    # summary picks for hillclimbing
    print("\n## Hillclimb candidates\n")
    scored = []
    for arch, shape, rf in rows:
        terms = {"compute": rf["t_compute_s"], "memory": rf["t_memory_s"],
                 "collective": rf["t_collective_s"]}
        dom = rf["dominant"]
        tot = sum(terms.values())
        # roofline fraction: useful compute time / dominant term
        useful_t = rf["flops_per_device"] * rf["useful_flops_ratio"] / 197e12
        frac = useful_t / max(terms[dom], 1e-12)
        coll_share = terms["collective"] / max(tot, 1e-12)
        scored.append((frac, coll_share, arch, shape, dom))
    scored.sort()
    print("worst roofline fraction:")
    for frac, cs, arch, shape, dom in scored[:5]:
        print(f"  {arch} {shape}: frac={frac:.3f} dom={dom} coll_share={cs:.2f}")
    print("most collective-bound:")
    for frac, cs, arch, shape, dom in sorted(scored, key=lambda x: -x[1])[:5]:
        print(f"  {arch} {shape}: coll_share={cs:.2f} frac={frac:.3f} dom={dom}")


if __name__ == "__main__":
    main()
