"""Paper Fig. 8 + Fig. 18: access skew and partial device index caching.

(8)  cluster access-frequency skew under the Zipf workload;
(18) retrieval speedup + hit rate vs cache capacity (fraction of clusters),
     including the Eq. 2 memory-split planner output.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_COST, emit, fixture, load_requests, make_server
from repro.retrieval.hotcache import plan_memory_split


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    n = 30 if quick else 100
    rate = 6.0

    # baseline (no cache) once
    s0 = make_server(index, embedder, "hedra", hot_cache=0)
    load_requests(s0, n, rate, seed=3)
    m0 = s0.run().summary()

    fracs = [0.1, 0.3] if quick else [0.05, 0.1, 0.2, 0.3, 0.5]
    for frac in fracs:
        cap = max(2, int(index.n_clusters * frac))
        s = make_server(index, embedder, "hedra", hot_cache=cap)
        load_requests(s, n, rate, seed=3)
        m = s.run().summary()
        hyb = s.backend.hybrid
        st = hyb.stats()
        speedup = m0["avg_latency_ms"] / max(m["avg_latency_ms"], 1e-9)
        emit(f"hotcache_frac{int(frac*100)}", m["avg_latency_ms"] * 1e3,
             f"hit_rate={st['hit_rate']:.2f}_speedup={speedup:.2f}"
             f"_swaps={st['swaps']}"
             f"_oversized={m['cache_oversized_rejects']}"
             f"_stale={m['cache_stale_fallbacks']}")
        if frac == fracs[-1]:
            emit("hotcache_skew", 0.0,
                 "_".join(f"{k}={v:.2f}" for k, v in st["skew"].items()))

    # Eq. 2 planner on measured-ish tables
    kv_opts = [2 << 30, 4 << 30, 8 << 30, 16 << 30]
    t_gen = lambda kv, rps: min(kv / (8 << 30), 1.0) * 20.0  # saturates @8GB
    t_ret = lambda rps: 14.0
    kv, cache = plan_memory_split(24 << 30, t_gen=t_gen, t_ret=t_ret,
                                  rps_g=rate, rps_r=rate, kv_candidates=kv_opts)
    emit("eq2_memory_split", float(kv / (1 << 20)),
         f"kv_gb={kv/(1<<30):.0f}_cache_gb={cache/(1<<30):.0f}")
