"""Paper Fig. 7 + Fig. 9: intra-request semantic similarity and what the
locality observations buy.

(7a) distance of consecutive retrieval queries vs query-to-top-k distances;
(7b) partial-generation embedding distance vs prefix ratio;
(9a) fraction of (v, v') pairs satisfying O1/O2/O3;
(9b) effective search reduction from reordering + lossless early termination.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, fixture
from repro.core.similarity import (
    LocalCache,
    observation_stats,
    patience_termination,
    reorder_clusters,
)
from repro.retrieval.ivf import TopK


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    n = 24 if quick else 100

    # (7a) inter-retrieval similarity
    d_consec, d_topk = [], []
    for rid in range(n):
        q0 = embedder.embed_query(rid, 0)
        q1 = embedder.embed_query(rid, 1)
        d_consec.append(np.linalg.norm(q1 - q0))
        D, _ = index.search(q0[None], nprobe=16, k=5)
        d_topk.append(np.sqrt(max(D[0][-1], 0)))
    emit("sim_query_drift", float(np.mean(d_consec) * 1e3),
         f"top5_dist_x1e3={np.mean(d_topk)*1e3:.1f}_ratio={np.mean(d_consec)/np.mean(d_topk):.2f}")

    # (7b) partial-generation convergence
    for ratio in [0.22, 0.5, 0.8]:
        ds = [np.linalg.norm(embedder.embed_partial(r, 0, ratio)
                             - embedder.embed_query(r, 0)) for r in range(n)]
        emit(f"sim_partial_ratio{int(ratio*100)}", float(np.mean(ds) * 1e3),
             f"vs_top1_dist_x1e3={np.mean(d_topk)*1e3:.1f}")

    # (9a) locality observations
    o = {"o1": 0, "o2": 0, "o3": 0}
    for rid in range(n):
        st = observation_stats(index, embedder.embed_query(rid, 0),
                               embedder.embed_query(rid, 1),
                               k=1, k_prime=20, nprobe=16)
        for k in o:
            o[k] += st[k]
    emit("sim_obs_rates", 0.0,
         f"o1={o['o1']/n:.2f}_o2={o['o2']/n:.2f}_o3={o['o3']/n:.2f}")

    # (9b) reorder -> earlier ANNS termination; recall cost measured
    searched_base, searched_reord, recalls = [], [], []
    for rid in range(n):
        q0 = embedder.embed_query(rid, 0)
        q1 = embedder.embed_query(rid, 1)
        D0, I0 = index.search(q0[None], nprobe=16, k=20)
        cache = LocalCache()
        cache.update(q0, TopK(20, D0[0].astype(np.float32), I0[0]), index,
                     probed=list(index.probe_order(q0[None], 16)[0]))
        Dfull, Ifull = index.search(q1[None], nprobe=16, k=5)
        for reorder in (False, True):
            probes = [int(c) for c in index.probe_order(q1[None], 16)[0]]
            if reorder:
                probes = reorder_clusters(probes, cache).order
            tk = TopK.empty(5)
            cnt, no_imp, last_kth = 0, 0, np.inf
            while probes:
                cid = probes.pop(0)
                d, ids = index.search_cluster(q1[None], cid)
                tk = tk.merge(d[0], ids[0])
                cnt += 1
                if tk.kth < last_kth - 1e-12:
                    no_imp, last_kth = 0, tk.kth
                else:
                    no_imp += 1
                if patience_termination(no_imp, cnt, 5, patience=4):
                    break
            (searched_reord if reorder else searched_base).append(cnt)
            if reorder:
                recalls.append(len(set(tk.ids) & set(Ifull[0])) / 5)
    base, reord = np.mean(searched_base), np.mean(searched_reord)
    emit("sim_reorder_clusters_searched", reord * 1e3,
         f"baseline={base:.1f}_reduction={100*(1-reord/max(base,1e-9)):.0f}pct"
         f"_recall_vs_full={np.mean(recalls):.3f}")
