"""Paper Fig. 17: speculation accuracy + latency across speculation policies
(HedraRAG adaptive vs RaLMSpec-like always-on vs PipeRAG/RAGCache-like
conservative), at two load points."""
from __future__ import annotations

from benchmarks.common import emit, fixture, load_requests, make_server
from repro.core.speculation import SpeculationPolicy
from repro.core.wavefront import SchedulerConfig


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    n = 24 if quick else 80
    for rate in ([3.0] if quick else [3.0, 8.0]):
        results = {}
        for policy in ["off", "pipeline", "ralmspec", "hedra"]:
            cfg = SchedulerConfig.preset(
                "hedra", speculation=SpeculationPolicy(mode=policy))
            s = make_server(index, embedder, "hedra", config=cfg)
            load_requests(s, n, rate, names=["irg", "multistep"], seed=8)
            m = s.run()
            summ = m.summary()
            att = summ["spec_gen_attempts"]
            acc = summ["spec_gen_validated"] / att if att else 1.0
            results[policy] = summ["avg_latency_ms"]
            emit(f"spec_{policy}_rate{rate:g}", summ["avg_latency_ms"] * 1e3,
                 f"accuracy={acc:.2f}_attempts={att}"
                 f"_rollbacks={summ['spec_gen_rollbacks']}")
        if "off" in results:
            emit(f"spec_speedup_rate{rate:g}", 0.0,
                 f"hedra_vs_off={results['off']/max(results['hedra'],1e-9):.2f}x"
                 f"_vs_ralmspec={results['ralmspec']/max(results['hedra'],1e-9):.2f}x")
