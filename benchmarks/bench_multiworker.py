"""Multi-worker retrieval scaling: throughput + p95 for a 1/2/4/8-worker
retrieval pool under Zipf(1.2)-skewed cluster popularity, vs the
single-worker baseline, plus the per-worker utilization skew reported by
``Metrics.summary()`` and a dispatch-policy comparison at 4 workers."""
from __future__ import annotations

from benchmarks.common import emit, fixture, load_requests
from repro.core.backends import SimBackend
from repro.core.wavefront import SchedulerConfig
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server

# deeper clusters than PAPER_COST so one retrieval worker saturates
# (nw=1 ret_util ~0.9) — the regime where the pool has to help
RET_BOUND = ClusterCostModel(fixed_us=150.0, per_vector_us=20.0, per_query_us=2.0)


def _serve(index, embedder, nw: int, policy: str, n: int, rate: float):
    cfg = SchedulerConfig.preset("hedra", num_ret_workers=nw,
                                 dispatch_policy=policy, nprobe=16, topk=5)
    be = SimBackend(index, embedder, cost_model=RET_BOUND)
    s = Server(index, embedder, backend=be, config=cfg)
    load_requests(s, n, rate, seed=4)
    return s.run().summary()


def run(quick: bool = True) -> None:
    index, embedder = fixture(zipf=1.2)
    n = 40 if quick else 80
    rate = 40.0
    workers = [1, 4] if quick else [1, 2, 4, 8]
    base_rps = None
    for nw in workers:
        m = _serve(index, embedder, nw, "affinity", n, rate)
        if base_rps is None:
            base_rps = m["throughput_rps"]
        emit(f"multiworker_affinity_nw{nw}", m["avg_latency_ms"] * 1e3,
             f"rps={m['throughput_rps']:.2f}"
             f"_p95_ms={m['p95_latency_ms']:.1f}"
             f"_speedup={m['throughput_rps'] / base_rps:.2f}x"
             f"_ret_util={m['ret_util']:.2f}"
             f"_worker_skew={m['ret_worker_skew']:.2f}")
    for policy in ([] if quick else ["least_loaded", "round_robin"]):
        m = _serve(index, embedder, 4, policy, n, rate)
        emit(f"multiworker_{policy}_nw4", m["avg_latency_ms"] * 1e3,
             f"rps={m['throughput_rps']:.2f}"
             f"_speedup={m['throughput_rps'] / base_rps:.2f}x"
             f"_worker_skew={m['ret_worker_skew']:.2f}")
