"""Paper Fig. 4 + Fig. 6: execution-pattern divergence of the two engines.

(a) generation: step latency vs continuous batch size (real tiny-model
    measurements) — near-flat curve, token-level batching amortises;
(b) retrieval: cluster-search throughput vs batch size (real numpy/BLAS) —
    throughput grows with batch;
(c) workload variation: decode-step and single-cluster latency distributions.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fixture


def run(quick: bool = True) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import lm
    from repro.serving.engine import GenerationEngine

    index, embedder = fixture()

    # (a) generation step latency vs batch
    cfg = get_config("qwen3-1.7b").reduced(d_model=128, d_ff=256, n_layers=4,
                                           segments=get_config("qwen3-1.7b").reduced().segments * 4)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    for batch in ([1, 4, 8] if quick else [1, 2, 4, 8, 16]):
        eng = GenerationEngine(cfg, params, max_batch=batch, max_len=128, eos_id=-1)
        for b in range(batch):
            eng.add_sequence(np.arange(12) % 200 + 1, max_new=10_000)
        eng.step()  # compile
        t0 = time.perf_counter()
        n = 20
        for _ in range(n):
            eng.step()
        dt = (time.perf_counter() - t0) / n * 1e6
        emit(f"gen_step_batch{batch}", dt, f"tok_per_s={batch/dt*1e6:.0f}")

    # (b) retrieval throughput vs batch (queries per cluster scan)
    rng = np.random.default_rng(0)
    cid = int(np.argmax(index.cluster_sizes()))
    for batch in ([1, 8, 64] if quick else [1, 4, 16, 64, 256]):
        q = rng.standard_normal((batch, index.dim)).astype(np.float32)
        index.search_cluster(q, cid)  # warm
        t0 = time.perf_counter()
        n = 30
        for _ in range(n):
            index.search_cluster(q, cid)
        dt = (time.perf_counter() - t0) / n * 1e6
        emit(f"ret_cluster_batch{batch}", dt,
             f"queries_per_s={batch/dt*1e6:.0f}")

    # (c) workload variation distributions
    sizes = index.cluster_sizes()
    times = []
    for c in rng.choice(index.n_clusters, 32, replace=False):
        q = rng.standard_normal((1, index.dim)).astype(np.float32)
        t0 = time.perf_counter()
        index.search_cluster(q, int(c))
        times.append((time.perf_counter() - t0) * 1e6)
    times = np.array(times)
    emit("ret_cluster_latency_p50", float(np.percentile(times, 50)),
         f"p95={np.percentile(times,95):.1f}us_cv={times.std()/times.mean():.2f}")
    emit("cluster_size_skew", float(sizes.mean()),
         f"min={sizes.min()}_max={sizes.max()}_cv={sizes.std()/sizes.mean():.2f}")
