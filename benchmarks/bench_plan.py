"""Plan executor vs the per-item sub-stage path.

Times one retrieval sub-stage worth of work — Q queries x C clusters each —
through both executors:

* **legacy** — the pre-plan protocol: ``search_cluster_batch`` over per-item
  ``(query, cluster, TopK)`` tuples (one ``TopK.merge`` per item inside the
  scan) followed by the per-item completion merge the scheduler used to do
  (the "double merge").
* **plan** — ``PlanBuilder`` -> ``IVFIndex.search_plan`` (segmented GEMM
  scans into the SoA ``BatchTopK`` scoreboard) -> ``plan.finalize`` (one
  vectorized fold per group, streaks included).

Both paths produce identical ids (asserted against the reference
``IVFIndex.search``); the emitted speedup is the acceptance metric.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fixture
from repro.retrieval.ivf import TopK
from repro.retrieval.plan import PlanBuilder


def _legacy_substage(index, queries, probes, k):
    """Per-item path + completion merge, exactly the pre-plan hot loop."""
    work = [(queries[i], int(probes[i, j]), TopK.empty(k))
            for i in range(queries.shape[0]) for j in range(probes.shape[1])]
    per_cluster = index.search_cluster_batch(work)
    outs = []
    idx = 0
    for i in range(queries.shape[0]):
        tk = TopK.empty(k)
        for _ in range(probes.shape[1]):
            r = per_cluster[idx]
            idx += 1
            keep = r.ids >= 0
            tk = tk.merge(r.dists[keep], r.ids[keep])
        outs.append(tk)
    return outs


def _plan_substage(index, queries, probes, k):
    b = PlanBuilder()
    for i in range(queries.shape[0]):
        b.add(queries[i], probes[i], k=k)
    plan = b.build()
    res = plan.finalize(index.search_plan(plan))
    return plan, res


def _bench_pair(fn_a, fn_b, reps):
    """Interleaved best-of-reps so machine noise hits both paths alike."""
    fn_a(), fn_b()  # warmup
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a * 1e6, best_b * 1e6


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    rng = np.random.default_rng(11)
    k = 10
    reps = 10 if quick else 30
    sweeps = ([(64, 8), (128, 8)] if quick
              else [(32, 8), (64, 8), (128, 8), (64, 16), (256, 8)])
    for n_q, n_c in sweeps:
        queries = np.stack([embedder.embed_query(i, 0) for i in range(n_q)])
        probes = index.probe_order(queries, n_c)
        n_items = n_q * n_c

        # correctness gate: plan path == reference search over the same probes
        plan, res = _plan_substage(index, queries, probes, k)
        ref_d, ref_i = index.search(queries, n_c, k)
        assert np.array_equal(res.ids[:, :k], ref_i), "plan ids != reference"
        np.testing.assert_allclose(res.dists[:, :k], ref_d, atol=1e-4)
        legacy = _legacy_substage(index, queries, probes, k)
        for i, tk in enumerate(legacy):
            assert np.array_equal(tk.ids, ref_i[i]), "legacy ids != reference"

        t_legacy, t_plan = _bench_pair(
            lambda: _legacy_substage(index, queries, probes, k),
            lambda: _plan_substage(index, queries, probes, k), reps)
        emit(f"plan_legacy_{n_items}items", t_legacy, f"n_items={n_items}")
        emit(f"plan_soa_{n_items}items", t_plan,
             f"n_items={n_items}_speedup={t_legacy / t_plan:.2f}x_check=ok")
