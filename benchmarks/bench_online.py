"""Paper Fig. 12: online serving — request latency vs arrival rate across
the five RAG workflows, HedraRAG vs LangChain-like vs FlashRAG-like."""
from __future__ import annotations

from benchmarks.common import WORKFLOW_NAMES, emit, fixture, load_requests, make_server


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    rates = [2.0, 6.0] if quick else [1.0, 2.0, 4.0, 8.0, 12.0]
    n = 20 if quick else 60
    flows = ["one-shot", "irg"] if quick else WORKFLOW_NAMES
    for wf in flows:
        for rate in rates:
            for mode in ["sequential", "async", "hedra"]:
                s = make_server(index, embedder, mode, hot_cache=12 if mode == "hedra" else 0)
                load_requests(s, n, rate, names=[wf], seed=4)
                m = s.run().summary()
                emit(f"online_{wf}_{mode}_rate{rate:g}",
                     m["avg_latency_ms"] * 1e3,
                     f"p95_ms={m['p95_latency_ms']:.1f}"
                     f"_rps={m['throughput_rps']:.2f}"
                     f"_slo_viol={m['slo_violations']}")
