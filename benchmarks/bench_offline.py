"""Paper Fig. 13: offline batch execution — total runtime per framework mode,
normalized to HedraRAG (all requests present at t=0)."""
from __future__ import annotations

from benchmarks.common import WORKFLOW_NAMES, emit, fixture, make_server
from repro import workflows


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    n = 24 if quick else 96
    flows = ["one-shot", "multistep"] if quick else WORKFLOW_NAMES
    for wf in flows:
        totals = {}
        for mode in ["sequential", "async", "hedra"]:
            s = make_server(index, embedder, mode,
                            hot_cache=12 if mode == "hedra" else 0)
            for i in range(n):
                s.add_request(f"q{i}", workflows.build(wf), arrival_us=0.0)
            m = s.run()
            totals[mode] = m.sim_time_us
        base = totals["hedra"]
        for mode, t in totals.items():
            emit(f"offline_{wf}_{mode}", t, f"normalized={t/base:.2f}x")
