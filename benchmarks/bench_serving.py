"""Streaming serving saturation sweep (the paper's heterogeneous open-loop
scenario): sliding-window goodput — finished-under-SLO per second, warmup
excluded — vs offered load, for hedra/async/sequential over a pure one-shot
stream, the balanced five-workflow mix, and the ten-workflow
``heterogeneous`` mix where registry stages (rerank / rewrite / hybrid /
compress pipelines) compete with IVF scans for the same host pool — each
with per-class SLO tiers.

Each point runs the streaming front-end (``Server.serve``): the event clock
is stepped to every Poisson arrival, the request is submitted mid-run
through the admission layer (bounded in-system queue + deadline-
infeasibility shedding), and the run is drained at the end.  The sweep tops
out past the saturation knee (offered load above sustainable goodput); the
``serving_shed_p95_*`` rows push 2x beyond it and contrast admission
control against an unbounded queue — shedding keeps the p95 latency of
*admitted* requests bounded where the open queue's tail grows with the
backlog.
"""
from __future__ import annotations

from benchmarks.common import emit, fixture, make_server
from repro.serving.workload import MIXES

MODES = ["sequential", "async", "hedra"]
MAX_PENDING = 48  # in-system bound; binds only past the saturation knee


def _serve_point(index, embedder, mode, mix, rate, n, *, shed: bool):
    wl = mix.profile()
    kw = dict(max_pending=MAX_PENDING, admission_control=True) if shed else {}
    s = make_server(index, embedder, mode,
                    hot_cache=12 if mode == "hedra" else 0,
                    workload=wl, **kw)
    items = mix.sample(n, rate)
    m = s.serve(items)
    # steady-state window: skip the first 20% of the offered stream as
    # warmup, close at the last finish so drain idle time is excluded
    t_last_arrival = items[-1].arrival_us
    warmup = 0.2 * t_last_arrival
    end = max((f[0] for f in m.finish_log), default=warmup) + 1.0
    w = m.window_summary(warmup, end)
    return m, w


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    rates = [4.0, 16.0] if quick else [2.0, 4.0, 8.0, 16.0, 24.0, 32.0]
    n = 40 if quick else 150
    mixes = {"oneshot": MIXES["pure-oneshot"], "mixed": MIXES["balanced"],
             "hetero": MIXES["heterogeneous"]}
    for mix_name, mix in mixes.items():
        for rate in rates:
            for mode in MODES:
                m, w = _serve_point(index, embedder, mode, mix, rate, n,
                                    shed=True)
                emit(f"serving_{mix_name}_{mode}_rate{rate:g}",
                     w["goodput_rps"] * 1e3,  # milli-goodput for CSV scale
                     f"goodput_rps={w['goodput_rps']:.2f}"
                     f"_tput_rps={w['throughput_rps']:.2f}"
                     f"_p95_ms={w['p95_latency_ms']:.1f}"
                     f"_admitted={m.submitted}"
                     f"_shed={m.shed}")
    # past-knee contrast at 2x the top offered load: admission control must
    # keep the p95 of admitted requests bounded where the unbounded queue's
    # tail keeps growing with the backlog
    rate2, n2 = 2.0 * rates[-1], 2 * n
    for mode in (["sequential", "hedra"] if quick else MODES):
        m_shed, w_shed = _serve_point(index, embedder, mode,
                                      MIXES["balanced"], rate2, n2, shed=True)
        m_open, w_open = _serve_point(index, embedder, mode,
                                      MIXES["balanced"], rate2, n2, shed=False)
        emit(f"serving_shed_p95_{mode}_rate{rate2:g}",
             w_shed["p95_latency_ms"] * 1e3,
             f"p95_ms_shed={w_shed['p95_latency_ms']:.1f}"
             f"_p95_ms_open={w_open['p95_latency_ms']:.1f}"
             f"_shed={m_shed.shed}"
             f"_goodput_shed={w_shed['goodput_rps']:.2f}"
             f"_goodput_open={w_open['goodput_rps']:.2f}")
