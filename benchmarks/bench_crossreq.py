"""Cross-request coordination: duplicate-ratio x Zipf-skew x concurrency
sweep of the crossreq layer (global semantic cache + in-flight dedup/fusion
+ popularity-aware replication) against the uncoordinated PR 2 loop.

The workload models trending traffic: ``DuplicateTrafficEmbedder`` makes a
``dup_ratio`` fraction of requests re-issue a canonical query from a small
Zipf pool, with the workflow chosen per canonical query (same query -> same
pipeline).  The serving regime is retrieval-bound (deep clusters, light
generation), where duplicate scans are the dominant waste.

Also verifies correctness: with lossless settings (cache answers off,
triangle-bound early termination) and exact-only fusion, every fused
subscriber's answer must equal an independently executed reference search.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, fixture
from repro import workflows
from repro.core.backends import SimBackend
from repro.core.wavefront import SchedulerConfig
from repro.retrieval import DuplicateTrafficEmbedder, HybridRetrievalEngine
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.workload import WorkloadProfile, poisson_arrivals

# retrieval-bound regime: deep clusters + light generation stages, so the
# p50 is dominated by the segment scans the crossreq layer can coordinate
RET_BOUND = ClusterCostModel(fixed_us=150.0, per_vector_us=20.0, per_query_us=2.0)
NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp"]

CROSSREQ_KNOBS = dict(global_cache_size=256, dedup_threshold=0.95,
                      replication_factor=2)


def _serve(dup_ratio: float, *, crossreq: bool, zipf: float = 1.25,
           nw: int = 2, rate: float = 70.0, n: int = 56, nprobe: int = 24,
           hot_cache: int = 0, near_jitter: float = 0.0):
    index, emb = fixture(zipf=zipf)
    demb = DuplicateTrafficEmbedder(emb, dup_ratio=dup_ratio, pool_size=5,
                                    near_jitter=near_jitter)
    wl = WorkloadProfile(gen_tokens_mean=14.0, gen_tokens_sigma=0.25,
                         prompt_tokens_mean=48.0)
    hybrid = None
    if hot_cache:
        hybrid = HybridRetrievalEngine(index, cache_capacity=hot_cache,
                                       update_interval=10,
                                       transit_substages=1, kernel_impl="ref")
    be = SimBackend(index, demb, hybrid=hybrid, cost_model=RET_BOUND,
                    gen_step_base_us=600.0, gen_step_per_seq_us=20.0)
    kw = dict(CROSSREQ_KNOBS) if crossreq else {}
    s = Server(index, demb, mode="hedra", backend=be, workload=wl,
               nprobe=nprobe, topk=5, num_ret_workers=nw, **kw)
    for i, t in enumerate(poisson_arrivals(rate, n, seed=5)):
        # duplicate requests share the canonical query's workflow
        name = NAMES[demb.canonical_id(i) % len(NAMES)]
        s.add_request(f"q{i}", workflows.build(name), arrival_us=t)
    m = s.run()
    return s, m


def _counters(m) -> str:
    return (f"_gcache={m.global_cache_answers}"
            f"_seeds={m.global_cache_seeds}"
            f"_fused={m.dedup_fanout}"
            f"_saved_ms={m.dedup_saved_us / 1e3:.0f}"
            f"_routes={m.replica_routes}"
            f"_oversized={m.cache_stats.get('oversized_rejects', 0)}"
            f"_stale={m.cache_stats.get('stale_fallbacks', 0)}"
            f"_repl_loads={m.cache_stats.get('replica_loads', 0)}")


def _verify_exact_fusion(index, embedder) -> int:
    """Exact-only fusion under lossless settings: every duplicate request's
    first retrieval output must equal the reference IVF search."""
    demb = DuplicateTrafficEmbedder(embedder, dup_ratio=0.7, pool_size=2)
    cfg = SchedulerConfig.preset(
        "hedra", nprobe=12, topk=5, num_ret_workers=2,
        enable_cache_answer=False, early_term_mode="lossless",
        dedup_threshold=1.0)
    be = SimBackend(index, demb, cost_model=RET_BOUND)
    s = Server(index, demb, backend=be, config=cfg)
    for i, t in enumerate(poisson_arrivals(300.0, 16, seed=7)):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=t)
    m = s.run()
    assert m.finished == 16
    assert m.dedup_fanout > 0, "exact fusion never fired in verify config"
    for r in s.sched.done:
        qv = demb.embed_query(r.request_id, 0)
        _, ref_ids = index.search(qv[None], nprobe=cfg.nprobe, k=5)
        got = r.state["docs"]
        assert got == [int(x) for x in ref_ids[0][: len(got)]], (
            f"request {r.request_id}: fused answer diverged from the "
            f"independently executed search")
    return int(m.dedup_fanout)


def run(quick: bool = True) -> None:
    index, embedder = fixture(zipf=1.25)
    fused = _verify_exact_fusion(index, embedder)
    emit("crossreq_exact_fusion_verified", 0.0, f"fanout={fused}_ok=1")

    dups = [0.0, 0.3, 0.6] if quick else [0.0, 0.3, 0.45, 0.6]
    sweeps = [(1.25, 70.0)] if quick else [(1.25, 70.0), (1.25, 40.0),
                                           (1.1, 70.0)]
    for zipf, rate in sweeps:
        for dup in dups:
            _, m0 = _serve(dup, crossreq=False, zipf=zipf, rate=rate)
            _, m1 = _serve(dup, crossreq=True, zipf=zipf, rate=rate)
            s0, s1 = m0.summary(), m1.summary()
            sp = s0["p50_latency_ms"] / max(s1["p50_latency_ms"], 1e-9)
            emit(f"crossreq_dup{int(dup * 100)}_zipf{zipf}_rps{int(rate)}",
                 s1["p50_latency_ms"] * 1e3,
                 f"p50_off_ms={s0['p50_latency_ms']:.0f}"
                 f"_p50_on_ms={s1['p50_latency_ms']:.0f}"
                 f"_speedup={sp:.2f}x" + _counters(m1))

    # near-duplicate traffic: fused answers come tolerance-bounded from the
    # leader (cosine >= dedup threshold), like an O1 cache answer
    _, m = _serve(0.45, crossreq=True, near_jitter=0.04)
    emit("crossreq_near_dup45", m.summary()["p50_latency_ms"] * 1e3,
         f"near={m.dedup_near}_exact={m.dedup_exact}" + _counters(m))

    # replicated hot-cluster residency on the device cache: replica loads
    # and replica-aware routing under the same skewed workload
    _, m = _serve(0.3, crossreq=True, nw=4, hot_cache=12)
    emit("crossreq_replication_nw4", m.summary()["p50_latency_ms"] * 1e3,
         f"replicated={m.cache_stats.get('replicated_clusters', 0)}"
         + _counters(m))
