"""Paper Fig. 14: concurrent mixed-workflow serving — latency when queries
are randomly interleaved across all five workflow types."""
from __future__ import annotations

import numpy as np

from benchmarks.common import WORKFLOW_NAMES, emit, fixture, load_requests, make_server


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    n = 30 if quick else 100
    rate = 6.0
    for mode in ["sequential", "async", "hedra"]:
        s = make_server(index, embedder, mode,
                        hot_cache=12 if mode == "hedra" else 0)
        load_requests(s, n, rate, names=WORKFLOW_NAMES, seed=7)
        m = s.run()
        summ = m.summary()
        # per-workflow latency breakdown
        per = {}
        for req in s.sched.done:
            per.setdefault(req.graph.name, []).append(req.finish_us - req.arrival_us)
        breakdown = "_".join(
            f"{k}={np.mean(v)/1e3:.0f}ms" for k, v in sorted(per.items()))
        emit(f"concurrent_{mode}", summ["avg_latency_ms"] * 1e3,
             f"p95_ms={summ['p95_latency_ms']:.1f}_{breakdown}")
