"""Paper Fig. 5 + Fig. 16: fine-grained sub-stage partitioning vs coarse
stages — retrieval latency as a function of request rate.

Compares the three pipeline strategies of Fig. 5 on a retrieval-heavy
workload: (a) sequential coarse stages, (b) naive async coarse stages,
(c) HedraRAG dynamic sub-stage partitioning (Eq. 1 time budget).
"""
from __future__ import annotations

from benchmarks.common import emit, fixture, load_requests, make_server


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    rates = [2.0, 6.0] if quick else [1.0, 2.0, 4.0, 8.0, 12.0]
    n = 24 if quick else 80
    for rate in rates:
        for mode in ["sequential", "async", "hedra"]:
            s = make_server(index, embedder, mode, nprobe=24)
            load_requests(s, n, rate, names=["one-shot"], seed=2)
            m = s.run().summary()
            emit(f"partition_{mode}_rate{rate:g}",
                 m["avg_latency_ms"] * 1e3,
                 f"p95_ms={m['p95_latency_ms']:.1f}_rsub={m['substages_ret']}"
                 f"_ret_util={m['ret_util']:.2f}")
