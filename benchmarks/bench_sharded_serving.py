"""Shard-mode serving sweep: goodput vs (shards x workers x zipf skew).

The distributed-retrieval serving path (``SchedulerConfig.index_sharding``)
splits every retrieval sub-stage's probe list by owning cluster-range shard,
scatters the parts to their owning workers and k-way merges the partial
top-k sets in the scheduler.  This sweep answers three questions:

* scaling: streamed goodput (finished-under-SLO per second, warmup
  excluded) of a sharded N-worker pool vs the unsharded pool at the same
  size, at an offered load near the 4-worker saturation knee;
* skew sensitivity: ownership is static (contiguous cluster ranges
  balanced by vector mass), so Zipf-skewed probe traffic concentrates on
  few shards — the sweep contrasts a mild and a heavy zipf exponent;
* residency: with a device hot cache attached, per-worker slab residency
  (``per_owner_resident``) must fall ~N x versus the pool-global slab.

The acceptance bar from the issue: sharded goodput at the knee no worse
than the unsharded 4-worker baseline (``sharded_serving_nw4_*`` vs
``sharded_serving_nw4_off_*`` rows).

Standalone: ``python benchmarks/bench_sharded_serving.py --quick
[--json out.json]`` (the CI smoke job); also runs via
``benchmarks/run.py --only sharded_serving``.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, fixture, make_server  # noqa: E402
from repro.serving.workload import MIXES  # noqa: E402

# offered load near the 4-worker saturation knee of the retrieval-heavy mix
KNEE_RATE = 40.0
MAX_PENDING = 48


def _serve_point(index, embedder, *, nw: int, sharding: bool, rate: float,
                 n: int, hot_cache: int = 0):
    mix = MIXES["retrieval-heavy"]
    s = make_server(index, embedder, "hedra", hot_cache=hot_cache,
                    workload=mix.profile(), num_ret_workers=nw,
                    index_sharding=sharding, max_pending=MAX_PENDING,
                    admission_control=True)
    items = mix.sample(n, rate)
    m = s.serve(items)
    warmup = 0.2 * items[-1].arrival_us
    end = max((f[0] for f in m.finish_log), default=warmup) + 1.0
    return s, m, m.window_summary(warmup, end)


def run(quick: bool = True) -> None:
    n = 50 if quick else 160
    zipfs = [1.25] if quick else [1.05, 1.25, 1.5]
    workers = [1, 2, 4] if quick else [1, 2, 4, 8]
    for zipf in zipfs:
        index, embedder = fixture(zipf=zipf)
        tag = f"zipf{zipf:g}"
        base = None  # unsharded 4-worker goodput (the PR 4 baseline shape)
        for nw in workers:
            for sharding in (False, True):
                s, m, w = _serve_point(index, embedder, nw=nw,
                                       sharding=sharding, rate=KNEE_RATE, n=n)
                mode = "shard" if sharding else "off"
                if not sharding and nw == 4:
                    base = w["goodput_rps"]
                rel = (f"_vs_nw4off={w['goodput_rps'] / base:.2f}x"
                       if base else "")
                emit(f"sharded_serving_nw{nw}_{mode}_{tag}",
                     w["goodput_rps"] * 1e3,
                     f"goodput_rps={w['goodput_rps']:.2f}"
                     f"_p95_ms={w['p95_latency_ms']:.1f}"
                     f"_shed={m.shed}"
                     f"_scatters={m.shard_scatters}"
                     f"_parts={m.shard_parts}"
                     f"_merges={m.shard_merges}{rel}")
        # per-worker device-slab residency: sharded slabs hold ~1/N each
        for nw in ([4] if quick else [2, 4, 8]):
            s, m, w = _serve_point(index, embedder, nw=nw, sharding=True,
                                   rate=KNEE_RATE, n=n, hot_cache=16)
            per = s.backend.hybrid.cache.per_owner_resident()
            emit(f"sharded_residency_nw{nw}_{tag}",
                 max(per.values()) if per else 0,
                 f"per_owner={'/'.join(str(per[w2]) for w2 in sorted(per))}"
                 f"_cap=16_hit={s.backend.hybrid.stats()['hit_rate']:.2f}")


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="",
                    help="write the emitted rows as a JSON record")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick)
    if args.json:
        from benchmarks import common

        with open(args.json, "w") as f:
            json.dump({"rows": common.RESULTS}, f, indent=1)
        print(f"# wrote {args.json} ({len(common.RESULTS)} rows)",
              file=sys.stderr)
