"""Shared benchmark fixtures: corpus, index, embedder, server builders.

The retrieval cost model is calibrated to emulate the paper's regime
(38M-doc Wikipedia, IVF4096, nprobe 128-512: retrieval stages ~10-80 ms,
comparable to generation) while executing exactly on a smaller corpus —
parameters are printed with every run so numbers are interpretable.
"""
from __future__ import annotations

import functools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.backends import SimBackend  # noqa: E402
from repro.core.wavefront import SchedulerConfig  # noqa: E402
from repro.retrieval import (  # noqa: E402
    CorpusConfig,
    HybridRetrievalEngine,
    IVFIndex,
    SyntheticEmbedder,
    make_corpus,
)
from repro.retrieval.ivf import ClusterCostModel  # noqa: E402
from repro.server import Server  # noqa: E402
from repro.serving.workload import poisson_arrivals  # noqa: E402
from repro import workflows  # noqa: E402

# paper-regime emulation: ~300-vector clusters at 8 us/vector -> ~2.5 ms per
# cluster, nprobe 16 -> ~40 ms retrieval stages (between the paper's nprobe
# 128 and 512 operating points when scaled by corpus ratio)
PAPER_COST = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0, per_query_us=2.0)

WORKFLOW_NAMES = ["one-shot", "multistep", "irg", "hyde", "recomp"]


@functools.lru_cache(maxsize=2)
def fixture(n_docs: int = 30_000, dim: int = 64, n_topics: int = 192,
            n_clusters: int = 96, zipf: float = 1.25, seed: int = 0):
    docs, doc_topic, topics = make_corpus(CorpusConfig(
        n_docs=n_docs, dim=dim, n_topics=n_topics, zipf_alpha=zipf,
        doc_noise=0.16, seed=seed))
    index = IVFIndex.build(docs, n_clusters, iters=5)
    # drift tuned so the O1/O2/O3 rates land near the paper's Fig. 9a regime
    embedder = SyntheticEmbedder(topics, zipf_alpha=zipf, inter_drift=0.42,
                                 query_noise=0.32)
    return index, embedder


def make_server(index, embedder, mode: str, *, hot_cache: int = 0,
                nprobe: int = 16, config: SchedulerConfig | None = None,
                seed: int = 0, **kw) -> Server:
    hybrid = None
    if hot_cache:
        hybrid = HybridRetrievalEngine(index, cache_capacity=hot_cache,
                                       update_interval=25, transit_substages=1,
                                       kernel_impl="ref")
    be = SimBackend(index, embedder, hybrid=hybrid, cost_model=PAPER_COST,
                    seed=seed)
    if config is not None:
        return Server(index, embedder, backend=be, config=config)
    return Server(index, embedder, mode=mode, backend=be, nprobe=nprobe, **kw)


def load_requests(server: Server, n: int, rate: float, names=None, seed: int = 1):
    names = names or WORKFLOW_NAMES
    arr = poisson_arrivals(rate, n, seed=seed)
    for i, t in enumerate(arr):
        server.add_request(f"q{i}", workflows.build(names[i % len(names)]),
                           arrival_us=t)


# rows emitted by the current process, harvested by run.py --json
RESULTS: list[dict] = []

# structured side-products (e.g. bench_obs' metrics snapshot / attribution
# summary), embedded under "artifacts" in the run.py --json record
ARTIFACTS: dict = {}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS.append(
        {"name": name, "us_per_call": round(float(us_per_call), 1),
         "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
