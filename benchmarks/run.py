"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` widens sweeps;
``--only <name>`` runs a single module.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODULES = [
    ("engines", "benchmarks.bench_engines"),          # Fig. 4 / 6
    ("partitioning", "benchmarks.bench_partitioning"),  # Fig. 5 / 16
    ("similarity", "benchmarks.bench_similarity"),    # Fig. 7 / 9
    ("hotcache", "benchmarks.bench_hotcache"),        # Fig. 8 / 18
    ("online", "benchmarks.bench_online"),            # Fig. 12
    ("offline", "benchmarks.bench_offline"),          # Fig. 13
    ("concurrent", "benchmarks.bench_concurrent"),    # Fig. 14
    ("multiworker", "benchmarks.bench_multiworker"),  # retrieval-pool scaling
    ("serving", "benchmarks.bench_serving"),          # streaming goodput sweep
    ("sharded_serving", "benchmarks.bench_sharded_serving"),  # shard-mode scatter-gather
    ("faults", "benchmarks.bench_faults"),            # goodput under injected faults
    ("ingress", "benchmarks.bench_ingress"),          # wall-clock closed-loop + replay oracle
    ("obs", "benchmarks.bench_obs"),                  # tracing overhead + attribution
    ("plan", "benchmarks.bench_plan"),                # SoA sub-stage executor
    ("crossreq", "benchmarks.bench_crossreq"),        # cross-request layer
    ("speculation", "benchmarks.bench_speculation"),  # Fig. 17
    ("kernels", "benchmarks.bench_kernels"),          # roofline kernels
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="write a BENCH_*.json-style record (per-module "
                         "us_per_call rows + run metadata) to this path")
    args = ap.parse_args()
    if args.only and args.only not in {name for name, _ in MODULES}:
        ap.error(f"unknown --only module {args.only!r}; choose from "
                 f"{[name for name, _ in MODULES]}")

    print("name,us_per_call,derived")
    import importlib
    import json
    import platform

    from benchmarks import common

    module_times = {}
    for name, mod in MODULES:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        m = importlib.import_module(mod)
        m.run(quick=not args.full)
        module_times[name] = round(time.time() - t0, 1)
        print(f"# {name} done in {module_times[name]:.1f}s", file=sys.stderr)

    if args.json:
        record = {
            "meta": {
                "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
                "python": platform.python_version(),
                "machine": platform.machine(),
                "quick": not args.full,
                "only": args.only or None,
                "module_times_s": module_times,
            },
            "rows": common.RESULTS,
            # structured side-products (bench_obs metrics snapshot /
            # attribution summaries); empty when those modules didn't run
            "artifacts": common.ARTIFACTS,
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {args.json} ({len(common.RESULTS)} rows)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
