"""Observability layer: recording overhead + latency attribution profile.

Three questions, one serving scenario (the paper five as a Poisson stream
on a 4-worker pool):

* **Overhead** — the obs taps must be cheap enough to leave on: identical
  runs with tracing+telemetry off vs on, reported as wall-clock overhead
  per request.  (Virtual-clock behaviour is bit-identical by construction
  — the fingerprint tests pin that; this measures the host-side cost.)
* **Attribution** — where each mode's latency actually goes: per-mode
  queueing / retrieval / generation fractions and the bottleneck
  component from ``Server.attribution_report()``.
* **Recovery structure** — the same profile under a seeded FaultPlan: how
  much of the latency budget retry backoff and fault recovery consume.

The metrics snapshot and attribution summaries land in
``common.ARTIFACTS`` (embedded in ``run.py --json`` records); standalone
``--trace-out``/``--metrics-out`` write the sample trace and snapshot
files the CI smoke uploads as workflow artifacts.

Standalone: ``python benchmarks/bench_obs.py --quick [--json out.json]
[--trace-out trace.json] [--metrics-out metrics.json]``; also runs via
``benchmarks/run.py --only obs``.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (  # noqa: E402
    ARTIFACTS,
    emit,
    fixture,
    load_requests,
    make_server,
)
from repro.obs.trace import validate_trace  # noqa: E402
from repro.serving.faults import FaultPlan  # noqa: E402

NW = 4
RATE = 12.0

# one server kept alive so standalone --trace-out/--metrics-out can export
# from the exact run that was measured
_LAST: dict = {}


def _serve(index, embedder, mode: str, n: int, *, obs: bool,
           fault_plan=None):
    s = make_server(index, embedder, mode, num_ret_workers=NW,
                    tracing=obs, telemetry=obs, fault_plan=fault_plan)
    load_requests(s, n, RATE)
    t0 = time.perf_counter()
    m = s.run()
    return s, m, time.perf_counter() - t0


def run(quick: bool = True) -> None:
    n = 40 if quick else 120
    index, embedder = fixture()
    # overhead: same scenario, taps off vs on (warm both paths once first
    # so one-time imports/JIT don't land on either side of the diff)
    _serve(index, embedder, "hedra", 8, obs=False)
    _serve(index, embedder, "hedra", 8, obs=True)
    _, m_off, wall_off = _serve(index, embedder, "hedra", n, obs=False)
    s_on, m_on, wall_on = _serve(index, embedder, "hedra", n, obs=True)
    assert m_on.finished == m_off.finished
    over_us = (wall_on - wall_off) / max(m_on.finished, 1) * 1e6
    emit("obs_overhead_per_req", max(over_us, 0.0),
         f"wall_off_s={wall_off:.2f}_wall_on_s={wall_on:.2f}"
         f"_spans={len(s_on.sched.obs.spans)}"
         f"_samples={len(s_on.sched.telemetry.samples)}")
    _LAST["server"] = s_on

    # attribution profile per mode
    for mode in ("hedra", "async", "sequential"):
        s, m, _ = ((s_on, m_on, 0.0) if mode == "hedra"
                   else _serve(index, embedder, mode, n, obs=True))
        rep = s.attribution_report()
        fr = rep["fractions"]
        emit(f"obs_attribution_{mode}", fr["queueing"] * 1e6,
             f"queue={fr['queueing']:.3f}"
             f"_ret={fr['retrieval_compute']:.3f}"
             f"_gen={fr['generation_compute']:.3f}"
             f"_bottleneck={rep['bottleneck']}"
             f"_resid={rep['max_rel_residual']:.1e}")
        ARTIFACTS.setdefault("obs_attribution", {})[mode] = {
            k: rep[k] for k in ("finished", "totals_us", "fractions",
                                "bottleneck", "max_rel_residual")}

    # recovery structure under injected faults
    plan = FaultPlan.random(11, NW, n / RATE * 1e6 + 1e6,
                            transient_prob=0.05)
    s_f, m_f, _ = _serve(index, embedder, "hedra", n, obs=True,
                         fault_plan=plan)
    rep = s_f.attribution_report()
    fr = rep["fractions"]
    emit("obs_attribution_faults",
         (fr["retry_hedge_failover"] + fr["fault_recovery"]) * 1e6,
         f"retry={fr['retry_hedge_failover']:.3f}"
         f"_faultrec={fr['fault_recovery']:.3f}"
         f"_deaths={m_f.worker_deaths}_retries={m_f.retries}"
         f"_resid={rep['max_rel_residual']:.1e}")
    ARTIFACTS["obs_attribution_faults"] = {
        k: rep[k] for k in ("finished", "totals_us", "fractions",
                            "bottleneck", "max_rel_residual")}

    # registry snapshot (sampled queue depth / utilization / lifecycle)
    snap = s_on.metrics_snapshot()
    emit("obs_snapshot", len(snap["timeline"]),
         f"samples={len(snap['timeline'])}"
         f"_families={len(snap['metrics'])}"
         f"_prom_lines={len(snap['prometheus'].splitlines())}")
    snap.pop("prometheus", None)  # keep the artifact JSON compact
    ARTIFACTS["obs_metrics_snapshot"] = snap


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="",
                    help="write the emitted rows + artifacts as JSON")
    ap.add_argument("--trace-out", default="",
                    help="export the measured run's Perfetto trace here")
    ap.add_argument("--metrics-out", default="",
                    help="export the measured run's metrics snapshot here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick)
    if args.trace_out:
        trace = _LAST["server"].export_trace(args.trace_out)
        probs = validate_trace(trace)
        assert not probs, probs[:5]
        print(f"# wrote {args.trace_out} "
              f"({len(trace['traceEvents'])} events)", file=sys.stderr)
    if args.metrics_out:
        _LAST["server"].metrics_snapshot(args.metrics_out)
        print(f"# wrote {args.metrics_out}", file=sys.stderr)
    if args.json:
        from benchmarks import common

        with open(args.json, "w") as f:
            json.dump({"rows": common.RESULTS,
                       "artifacts": common.ARTIFACTS}, f, indent=1)
        print(f"# wrote {args.json} ({len(common.RESULTS)} rows)",
              file=sys.stderr)
