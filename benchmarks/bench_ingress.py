"""Closed-loop wall-clock serving: ingress overhead, client scaling, and
the replay-oracle check.

The serving-front-end sweep for the ingress PR: real producer threads
(closed-loop clients / an open-loop stream replayer / the heartbeat pump)
drive the scheduler through ``serving/ingress.py`` at a wall->virtual
speedup, and every point's recorded arrival trace is replayed on the pure
virtual clock — the bit-identity of the per-request event fingerprints is
asserted inline, so completing the sweep *is* the determinism check.
Reported per point:

* client-scaling: virtual-time goodput and p95 latency as the closed-loop
  population grows (offered load adapts to service rate — the knee shows
  as think-time stops hiding service time);
* token budget: arrivals admitted before the shared budget binds;
* ingress overhead: wall seconds burned per virtual second served, plus
  trace-row volume (arrival/heartbeat/tick mix) — the cost of running the
  threaded front-end instead of the batch path;
* replay: wall seconds to re-run the trace through the oracle.

Standalone: ``python benchmarks/bench_ingress.py --quick [--json out.json]``
(the CI smoke job); also runs via ``benchmarks/run.py --only ingress``.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from benchmarks.common import emit, fixture, make_server  # noqa: E402
from repro.serving.ingress import replay_trace  # noqa: E402
from repro.serving.workload import MIXES, ClosedLoopSpec  # noqa: E402

SPEEDUP = 800.0
MIX = "heterogeneous"


def _point(index, embedder, **kw):
    mix = MIXES[MIX]
    return make_server(index, embedder, "hedra", workload=mix.profile(),
                       num_ret_workers=2, **kw)


def _replay_check(mk, server, trace) -> float:
    """Replay the trace on a fresh server; assert bit-identity.  Returns
    replay wall seconds."""
    replica = mk()
    t0 = time.perf_counter()
    replay_trace(replica, trace)
    dt = time.perf_counter() - t0
    assert replica.fingerprints() == server.fingerprints(), \
        "ingress replay diverged from the wall-clock run"
    return dt


def _stats(m):
    lat = np.asarray(m.latencies_us, np.float64)
    p95 = float(np.percentile(lat, 95)) / 1e3 if lat.size else 0.0
    end_us = m.finish_log[-1][0] if m.finish_log else 1.0
    return p95, m.finished / max(end_us / 1e6, 1e-9)


def run(quick: bool = True) -> None:
    index, embedder = fixture()
    mix = MIXES[MIX]
    per_client = 4 if quick else 10
    populations = [1, 4] if quick else [1, 2, 4, 8]

    # ---- client scaling: closed-loop goodput/latency vs population
    for nc in populations:
        spec = ClosedLoopSpec.from_mix(mix, num_clients=nc,
                                       requests_per_client=per_client,
                                       think_time_s=0.02)

        def mk():
            return _point(index, embedder)

        s = mk()
        t0 = time.perf_counter()
        m, trace = s.serve_wallclock(closed_loop=spec, speedup=SPEEDUP,
                                     max_wall_s=120.0)
        wall_s = time.perf_counter() - t0
        replay_s = _replay_check(mk, s, trace)
        p95_ms, goodput = _stats(m)
        virt_s = s.sched.now / 1e6
        emit(f"ingress_closed_c{nc}", wall_s * 1e6,
             f"finished={m.finished}"
             f"_goodput_rps={goodput:.2f}"
             f"_p95_ms={p95_ms:.1f}"
             f"_rows={len(trace.rows)}"
             f"_wall_per_virt={wall_s / max(virt_s, 1e-9):.3f}"
             f"_replay_s={replay_s:.3f}")

    # ---- token budget: the shared budget bounds the run
    spec = ClosedLoopSpec.from_mix(mix, num_clients=4,
                                   requests_per_client=4 * per_client,
                                   think_time_s=0.01,
                                   token_budget=per_client * 600)

    def mk_budget():
        return _point(index, embedder)

    s = mk_budget()
    m, trace = s.serve_wallclock(closed_loop=spec, speedup=SPEEDUP,
                                 max_wall_s=120.0)
    _replay_check(mk_budget, s, trace)
    arrivals = sum(1 for r in trace.rows if r.kind == "arrival")
    emit("ingress_token_budget", arrivals,
         f"arrivals={arrivals}"
         f"_of={4 * 4 * per_client}"
         f"_budget={spec.token_budget}"
         f"_finished={m.finished}")

    # ---- open-loop ingress overhead vs the pure virtual serve
    n = 3 * per_client
    stream = mix.sample(n, rate_per_s=120.0, seed=19)

    def mk_open():
        return _point(index, embedder, external_heartbeats=True,
                      fault_tolerance=True, max_pending=8,
                      admission_control=True)

    s = mk_open()
    t0 = time.perf_counter()
    m, trace = s.serve_wallclock(stream, speedup=SPEEDUP, max_wall_s=120.0)
    wall_s = time.perf_counter() - t0
    replay_s = _replay_check(mk_open, s, trace)
    kinds = {k: sum(1 for r in trace.rows if r.kind == k)
             for k in ("arrival", "heartbeat", "readmit", "tick")}
    virt_s = s.sched.now / 1e6
    emit("ingress_open_loop", wall_s * 1e6,
         f"finished={m.finished}"
         f"_shed_final={m.shed_final}"
         f"_readmitted={m.shed_readmitted}"
         f"_hb_rows={kinds['heartbeat']}"
         f"_readmit_rows={kinds['readmit']}"
         f"_wall_per_virt={wall_s / max(virt_s, 1e-9):.3f}"
         f"_replay_s={replay_s:.3f}")


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="",
                    help="write the emitted rows as a JSON record")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick)
    if args.json:
        from benchmarks import common

        with open(args.json, "w") as f:
            json.dump({"rows": common.RESULTS}, f, indent=1)
        print(f"# wrote {args.json} ({len(common.RESULTS)} rows)",
              file=sys.stderr)
