"""Kernel-level benchmark: fused IVF scan + decode attention.

Wall-clock numbers time the jitted jnp oracle on this CPU (the executable
proxy); the derived column reports the kernel's arithmetic intensity and the
TPU-v5e roofline time so the §Perf analysis can compare implementations.
Pallas kernels themselves are validated in interpret mode (tests/).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.analysis.hlo import HBM_BW, PEAK_FLOPS_BF16


def _time(f, *args, n=10):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    t0 = time.perf_counter()
    for _ in range(n):
        r = f(*args)
        jax.tree.map(lambda x: x.block_until_ready(), r)
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = True) -> None:
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.ivf_scan.ops import ivf_scan

    rng = np.random.default_rng(0)

    for (G, QB, d, C, L, k) in ([(8, 8, 256, 16, 1024, 10)] if quick else
                                [(8, 8, 256, 16, 1024, 10),
                                 (32, 8, 1024, 64, 2048, 20)]):
        q = jnp.asarray(rng.standard_normal((G, QB, d)), jnp.float32)
        slab = jnp.asarray(rng.standard_normal((C, L, d)), jnp.float32)
        valid = jnp.full((C,), L, jnp.int32)
        gc = jnp.asarray(rng.integers(0, C, size=(G,)), jnp.int32)
        us = _time(lambda: ivf_scan(q, gc, slab, valid, k, impl="ref"), n=5)
        flops = 2.0 * G * QB * L * d
        bytes_ = (G * QB * d + G * L * d) * 4 + G * QB * k * 8
        ai = flops / bytes_
        t_tpu = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW) * 1e6
        emit(f"ivf_scan_G{G}_L{L}_d{d}", us,
             f"ai={ai:.1f}_tpu_roofline_us={t_tpu:.1f}")

    for (B, H, KV, dh, S) in ([(8, 16, 8, 128, 4096)] if quick else
                              [(8, 16, 8, 128, 4096),
                               (32, 16, 8, 128, 32768)]):
        q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.bfloat16)
        kc = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.bfloat16)
        vc = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.bfloat16)
        lengths = jnp.full((B,), S, jnp.int32)
        us = _time(lambda: decode_attention(q, kc, vc, lengths, impl="ref"), n=5)
        flops = 4.0 * B * H * S * dh
        bytes_ = 2.0 * B * S * KV * dh * 2 + B * H * dh * 2 * 2
        ai = flops / bytes_
        t_tpu = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW) * 1e6
        emit(f"decode_attn_B{B}_S{S}", us,
             f"ai={ai:.2f}_tpu_roofline_us={t_tpu:.1f}_memory_bound={ai < 240}")
