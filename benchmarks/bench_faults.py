"""Goodput under injected faults: crash rate x stall rate, hedging on/off.

The fault-tolerance sweep for the robustness PR: a 4-worker pool serving
the retrieval-heavy streaming mix near its saturation knee, with seeded
``FaultPlan``s injecting worker crashes (fraction of the pool killed
mid-run), heartbeat-pausing stall windows, and transient per-dispatch
failures.  Reported per point:

* streamed goodput (finished-under-SLO per second, warmup excluded) and
  p95 latency — the serving cost of losing workers / absorbing stalls;
* the recovery counters (re-dispatches, retries, hedged wins, failovers,
  degraded completions) — *how* the pool survived;
* hedging on vs off at the same fault point — what duplicate dispatch of
  SUSPECT stragglers buys (fewer timeouts turning into degraded results).

The liveness bar: every submitted request terminates (finished or shed) at
every fault point — a hang would deadlock the sweep, so completing it *is*
the check.

Standalone: ``python benchmarks/bench_faults.py --quick [--json out.json]``
(the CI smoke job); also runs via ``benchmarks/run.py --only faults``.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, fixture, make_server  # noqa: E402
from repro.serving.faults import FaultPlan  # noqa: E402
from repro.serving.workload import MIXES  # noqa: E402

KNEE_RATE = 40.0
MAX_PENDING = 48
NW = 4


def _serve_point(index, embedder, *, plan, hedge: bool, n: int,
                 sharding: bool = False):
    mix = MIXES["retrieval-heavy"]
    s = make_server(index, embedder, "hedra",
                    workload=mix.profile(), num_ret_workers=NW,
                    index_sharding=sharding, max_pending=MAX_PENDING,
                    admission_control=True, fault_plan=plan,
                    hedge_suspect=hedge)
    items = mix.sample(n, KNEE_RATE)
    m = s.serve(items)
    assert not s.sched.active and not s.sched.pending, "fault sweep hung"
    warmup = 0.2 * items[-1].arrival_us
    end = max((f[0] for f in m.finish_log), default=warmup) + 1.0
    return s, m, m.window_summary(warmup, end)


def run(quick: bool = True) -> None:
    n = 50 if quick else 160
    index, embedder = fixture()
    horizon = 1.5e6 * (n / 50.0)  # faults land inside the serve window
    crash_fracs = [0.0, 0.25] if quick else [0.0, 0.25, 0.5]
    stall_rates = [0.0, 1.0] if quick else [0.0, 1.0, 2.0]
    for crash_frac in crash_fracs:
        for stall_rate in stall_rates:
            for hedge in ((True,) if crash_frac == stall_rate == 0.0
                          else (True, False)):
                plan = FaultPlan.random(
                    17, NW, horizon, crash_frac=crash_frac,
                    stall_rate=stall_rate, stall_factor=6.0,
                    transient_prob=0.05)
                s, m, w = _serve_point(index, embedder, plan=plan,
                                       hedge=hedge, n=n)
                tag = (f"crash{crash_frac:g}_stall{stall_rate:g}"
                       f"_{'hedge' if hedge else 'nohedge'}")
                emit(f"faults_{tag}", w["goodput_rps"] * 1e3,
                     f"goodput_rps={w['goodput_rps']:.2f}"
                     f"_p95_ms={w['p95_latency_ms']:.1f}"
                     f"_shed={m.shed}"
                     f"_deaths={m.worker_deaths}"
                     f"_redisp={m.redispatches}"
                     f"_retries={m.retries}"
                     f"_hwins={m.hedged_wins}"
                     f"_degraded={m.degraded_completions}")
    # shard-mode failover point: crashes include shard owners, orphaned
    # parts fail over to surviving workers (whole-index fallback)
    plan = FaultPlan.random(23, NW, horizon, crash_frac=0.25,
                            stall_rate=0.5, stall_factor=6.0,
                            transient_prob=0.05)
    s, m, w = _serve_point(index, embedder, plan=plan, hedge=True, n=n,
                           sharding=True)
    emit("faults_sharded_crash0.25", w["goodput_rps"] * 1e3,
         f"goodput_rps={w['goodput_rps']:.2f}"
         f"_p95_ms={w['p95_latency_ms']:.1f}"
         f"_deaths={m.worker_deaths}"
         f"_failovers={m.failovers}"
         f"_degraded={m.degraded_completions}")


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="",
                    help="write the emitted rows as a JSON record")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick)
    if args.json:
        from benchmarks import common

        with open(args.json, "w") as f:
            json.dump({"rows": common.RESULTS}, f, indent=1)
        print(f"# wrote {args.json} ({len(common.RESULTS)} rows)",
              file=sys.stderr)
