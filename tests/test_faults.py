"""Fault-tolerant serving: worker lifecycle, deterministic fault injection,
and sub-stage retry/failover.

Covers the recovery contract end to end:

* zero-fault identity — with ``fault_tolerance=True`` and no fault plan the
  per-request event traces are bit-identical to the knobs-off scheduler
  (checked against the committed golden fingerprints);
* crash recovery — in-flight sub-stages on a DEAD worker are fenced and
  re-dispatched; a crashed *shard owner*'s parts fail over to surviving
  workers and ``scatter_gather_search`` parity holds for surviving shards;
* stall handling — a severely stalled worker turns SUSPECT, its in-flight
  job is hedged onto an idle worker, and the first result wins exactly once;
* transient failures — seeded per-dispatch failures retry with exponential
  backoff up to the budget, then complete the request *degraded* (partial
  top-k, flagged) instead of hanging;
* operational lifecycle — drain/rebind/register mid-run, heartbeat fencing;
* the journal temp-file sweep and the deterministic dispatcher tie-breaks.
"""
import json
import os

import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.retrieval.ivf import ClusterCostModel, TopK
from repro.serving import dispatch
from repro.serving.faults import (
    FaultPlan, StallWindow, WorkerCrash, HEARTBEAT_STALL_FACTOR,
)
from repro.serving.lifecycle import (
    DEAD, DRAINING, HEALTHY, JOINING, SUSPECT, WorkerRegistry,
)
from repro.server import Server

RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)


def _server(index, emb, mode="hedra", nw=4, *, sharding=False, plan=None,
            **cfg):
    be = SimBackend(index, emb, cost_model=RET_HEAVY, seed=0,
                    fault_plan=plan)
    return Server(index, emb, mode=mode, backend=be, nprobe=12, topk=5,
                  num_ret_workers=nw, index_sharding=sharding, **cfg)


def _load(server, n=10, name="multistep", spacing=3000.0):
    for i in range(n):
        server.add_request(f"q{i}", workflows.build(name),
                           arrival_us=i * spacing)


def _fingerprints(server):
    return {r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
            for r in server.sched.done}


def _assert_all_terminated(server, n):
    m = server.sched.metrics
    assert len(server.sched.active) == 0
    assert len(server.sched.pending) == 0
    assert m.finished + m.shed == n


# --------------------------------------------------------------- lifecycle


def test_registry_states_and_fencing():
    reg = WorkerRegistry(2, suspect_after_us=150_000.0,
                         dead_after_us=400_000.0)
    assert reg.all_healthy() and reg.effective_pool_size() == 2
    plan = FaultPlan(crashes=(WorkerCrash(0, 100_000.0),))
    assert reg.tick(99_000.0, plan) == []
    # crash at 100k: SUSPECT at 250k, DEAD at 500k — exactly at thresholds
    assert reg.next_transition_us(99_000.0, plan) == 250_000.0
    assert reg.tick(250_000.0, plan) == [(0, HEALTHY, SUSPECT)]
    assert not reg.can_schedule(0) and reg.serving(0)
    assert reg.tick(500_000.0, plan) == [(0, SUSPECT, DEAD)]
    assert not reg.alive(0) and reg.effective_pool_size() == 1
    # fencing: a late heartbeat cannot resurrect a dead worker
    reg.heartbeat(0, 600_000.0)
    assert reg.state_of(0) == DEAD
    # DEAD is terminal for tick; the healthy worker never transitions
    assert reg.tick(900_000.0, plan) == []
    assert reg.state_of(1) == HEALTHY
    timeline = [s for _, s in reg.workers[0].timeline]
    assert timeline == [JOINING, HEALTHY, SUSPECT, DEAD]


def test_registry_stall_suspect_and_recovery():
    reg = WorkerRegistry(1)
    win = StallWindow(0, 50_000.0, 300_000.0, factor=8.0)
    assert win.pauses_heartbeats  # factor >= HEARTBEAT_STALL_FACTOR
    assert StallWindow(0, 0.0, 1.0, factor=1.5).pauses_heartbeats is False
    plan = FaultPlan(stalls=(win,))
    assert reg.tick(150_000.0, plan) == []
    assert reg.tick(200_000.0, plan) == [(0, HEALTHY, SUSPECT)]
    # window ends at 300k: heartbeats resume, SUSPECT recovers
    assert 300_000.0 in [reg.next_transition_us(250_000.0, plan)]
    assert reg.tick(310_000.0, plan) == [(0, SUSPECT, HEALTHY)]
    assert reg.all_healthy()


def test_registry_drain_rebind_and_register():
    reg = WorkerRegistry(2)
    assert reg.drain(0, 10.0)
    assert reg.state_of(0) == DRAINING
    assert not reg.can_schedule(0) and not reg.owner_serves(0)
    assert reg.effective_pool_size() == 1
    assert reg.rebind(0, 20.0)
    assert reg.state_of(0) == HEALTHY and reg.all_healthy()
    # draining worker can still die (crash while held), then drain() fails
    plan = FaultPlan(crashes=(WorkerCrash(0, 30_000.0),))
    reg.drain(0, 25_000.0)
    reg.tick(500_000.0, plan)
    assert reg.state_of(0) == DEAD
    assert reg.drain(0, 600_000.0) is False
    wid = reg.register(700_000.0)
    assert wid == 2 and reg.state_of(wid) == HEALTHY
    with pytest.raises(ValueError):
        reg.register(700_000.0, wid=1)


# ------------------------------------------------------- fault determinism


def test_fault_plan_seeded_determinism():
    a = FaultPlan.random(7, 4, 2_000_000.0, transient_prob=0.1)
    b = FaultPlan.random(7, 4, 2_000_000.0, transient_prob=0.1)
    assert a.describe() == b.describe()
    assert [a.transient_fault(1, s) for s in range(64)] \
        == [b.transient_fault(1, s) for s in range(64)]
    # at most n-1 workers crash: the pool never starts fully dead
    assert len({c.wid for c in a.crashes}) <= 3
    c = FaultPlan.random(8, 4, 2_000_000.0, transient_prob=0.1)
    assert a.describe() != c.describe()


def test_stall_factor_inflates_latency_only_in_window():
    plan = FaultPlan(stalls=(StallWindow(1, 100.0, 200.0, factor=4.0),))
    assert plan.stall_factor(1, 150.0) == 4.0
    assert plan.stall_factor(1, 250.0) == 1.0
    assert plan.stall_factor(0, 150.0) == 1.0
    assert plan.is_empty is False and FaultPlan().is_empty


# -------------------------------------------------------- zero-fault identity


GOLDEN_NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp"]


@pytest.mark.parametrize("mode", ["hedra", "async", "sequential"])
@pytest.mark.parametrize("nw", [1, 4])
def test_ft_enabled_zero_faults_matches_golden_fingerprints(
        mode, nw, small_index, embedder):
    """fault_tolerance=True with no fault plan must leave every per-request
    event trace bit-identical to the committed golden fingerprints — the
    same harness as scripts/make_golden_fingerprints.py, with the
    fault-tolerance machinery armed."""
    import hashlib

    from repro.serving.workload import poisson_arrivals

    golden_path = os.path.join(os.path.dirname(__file__),
                               "golden_fingerprints.json")
    with open(golden_path) as f:
        golden = json.load(f)
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode=mode, backend=be,
               nprobe=12, topk=5, num_ret_workers=nw, fault_tolerance=True)
    for i, t in enumerate(poisson_arrivals(8.0, 20, seed=5)):
        s.add_request(f"q{i}", workflows.build(GOLDEN_NAMES[i % 5]),
                      arrival_us=float(t))
    m = s.run()
    assert m.finished == 20
    fp = {r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
          for r in s.sched.done}
    blob = json.dumps(fp, sort_keys=True).encode()
    assert hashlib.sha256(blob).hexdigest() == golden[f"{mode}-nw{nw}"]


# --------------------------------------------------------- crash recovery


def test_crash_redispatches_inflight_substage(small_index, embedder):
    """A worker crash mid-job fences the lost results and re-dispatches the
    sub-stage on a surviving worker; every request still completes."""
    plan = FaultPlan(crashes=(WorkerCrash(2, 95_000.0),))
    s = _server(small_index, embedder, plan=plan)
    _load(s, 10)
    m = s.run()
    _assert_all_terminated(s, 10)
    assert m.worker_deaths == 1
    assert m.redispatches >= 1
    rep = s.lifecycle_report()
    assert rep["workers"][2]["state"] == DEAD
    assert rep["counters"]["redispatches"] == m.redispatches


def test_acceptance_shard_owner_crash_with_transient_scatter_failures(
        small_index, embedder):
    """The issue's acceptance scenario: kill 1 of 4 workers — a shard owner
    — mid-run with transient scatter failures injected.  Every request must
    terminate (finished, shed, or degraded-complete) and the function-level
    ``scatter_gather_search`` parity must hold for the surviving shards."""
    from repro.retrieval.distributed import ShardMap, scatter_gather_search

    plan = FaultPlan(crashes=(WorkerCrash(1, 80_000.0),),
                     transient_fail_prob=0.1, seed=11)
    s = _server(small_index, embedder, sharding=True, plan=plan)
    _load(s, 10)
    m = s.run()
    _assert_all_terminated(s, 10)
    assert m.worker_deaths == 1
    assert s.sched.lifecycle.state_of(1) == DEAD
    assert m.transient_failures >= 1
    rep = s.shard_report()
    assert rep["failovers"] == m.failovers
    assert rep["degraded_completions"] == m.degraded_completions

    # surviving-shard parity: the scatter-gather path restricted to the
    # surviving shards equals an independent per-cluster merge oracle over
    # the same filtered probe lists
    sm = s.sched.shard_map
    survivors = {w for w in range(sm.n_shards)
                 if s.sched.lifecycle.alive(w)}
    rng = np.random.default_rng(0)
    q = rng.standard_normal((4, small_index.dim)).astype(np.float32)
    D, I = scatter_gather_search(small_index, q, 16, 5, sm,
                                 shards=survivors)
    # oracle: one whole-plan scan over the same filtered probe lists
    from repro.retrieval.plan import PlanBuilder

    probes = small_index.probe_order(q, 16)
    b = PlanBuilder()
    for r in range(q.shape[0]):
        kept = [int(c) for c in probes[r]
                if int(sm.owner[c]) in survivors]
        b.add(q[r], kept, k=5)
    ref = b.build()
    res = ref.finalize(small_index.search_plan(ref))
    np.testing.assert_array_equal(D, res.dists[:, :5])
    np.testing.assert_array_equal(I, res.ids[:, :5])
    # with every shard surviving, the restriction is the identity
    D0, I0 = scatter_gather_search(small_index, q, 16, 5, sm)
    D1, I1 = scatter_gather_search(small_index, q, 16, 5, sm,
                                   shards=set(range(sm.n_shards)))
    np.testing.assert_array_equal(D0, D1)
    np.testing.assert_array_equal(I0, I1)


def test_whole_pool_death_degrades_instead_of_hanging(small_index, embedder):
    plan = FaultPlan(crashes=tuple(WorkerCrash(w, 95_000.0 + w)
                                   for w in range(4)))
    s = _server(small_index, embedder, plan=plan)
    _load(s, 10)
    m = s.run()
    _assert_all_terminated(s, 10)
    assert m.worker_deaths == 4
    assert m.degraded_completions >= 1
    assert m.degraded_drops >= 1
    # degraded requests carry the flag and the event
    degraded = [r for r in s.sched.done if r.state.get("_degraded")]
    assert len(degraded) == m.degraded_completions
    assert all(any(e == "degraded" for _, e, _ in r.events)
               for r in degraded)


# ------------------------------------------------------------ stall/hedging


def test_stall_turns_suspect_and_hedges_first_result_wins(small_index,
                                                          embedder):
    """A 12x stall on the busy worker blows its job past the cost-model
    deadline: the worker turns SUSPECT, the in-flight retrieval group is
    duplicated onto an idle worker, and exactly one copy's result applies."""
    plan = FaultPlan(stalls=(StallWindow(2, 90_000.0, 3_000_000.0,
                                         factor=12.0),))
    s = _server(small_index, embedder, plan=plan)
    _load(s, 10)
    m = s.run()
    _assert_all_terminated(s, 10)
    assert m.worker_suspects >= 1
    assert m.task_timeouts >= 1
    assert m.hedged_dispatches >= 1
    assert m.hedged_wins >= 1
    assert m.hedged_wins <= m.hedged_dispatches
    assert m.degraded_completions == 0  # hedging rescued them, not degrading


def test_hedging_can_be_disabled(small_index, embedder):
    plan = FaultPlan(stalls=(StallWindow(2, 90_000.0, 3_000_000.0,
                                         factor=12.0),))
    s = _server(small_index, embedder, plan=plan, hedge_suspect=False)
    _load(s, 10)
    m = s.run()
    _assert_all_terminated(s, 10)
    assert m.hedged_dispatches == 0


# -------------------------------------------------------- transient retries


def test_transient_failures_retry_then_degrade(small_index, embedder):
    """With every dispatch failing, the per-(request, node) retry budget is
    exhausted and stages complete degraded rather than looping forever."""
    plan = FaultPlan(transient_fail_prob=1.0, seed=3)
    s = _server(small_index, embedder, plan=plan, retry_budget=2,
                retry_backoff_us=5_000.0)
    _load(s, 6)
    m = s.run()
    _assert_all_terminated(s, 6)
    assert m.transient_failures >= 1
    assert m.retries >= 1
    assert m.degraded_drops >= 1
    assert m.degraded_completions >= 1


def test_moderate_transients_recover_cleanly(small_index, embedder):
    plan = FaultPlan(transient_fail_prob=0.15, seed=5)
    s = _server(small_index, embedder, plan=plan)
    _load(s, 10)
    m = s.run()
    _assert_all_terminated(s, 10)
    assert m.retries >= 1
    assert m.finished == 10


# --------------------------------------------------- operational lifecycle


def test_drain_rebind_and_register_mid_run(small_index, embedder):
    s = _server(small_index, embedder, nw=2, fault_tolerance=True)
    _load(s, 6, spacing=2000.0)
    s.step(5_000.0)
    assert s.drain_worker(0)
    assert s.sched.lifecycle.state_of(0) == DRAINING
    s.step(40_000.0)
    wid = s.register_worker()
    assert wid == 2
    assert s.sched.num_ret_workers == 3
    assert s.rebind_worker(0)
    assert s.sched.lifecycle.state_of(0) == HEALTHY
    m = s.run()
    _assert_all_terminated(s, 6)
    assert m.finished == 6
    rep = s.lifecycle_report()
    assert rep["num_workers"] == 3
    states = [st for _, st in rep["workers"][0]["timeline"]]
    assert DRAINING in states and states[-1] == HEALTHY


def test_admission_sees_effective_pool(small_index, embedder):
    """Backlog per-worker estimates divide by the *effective* pool size:
    draining workers shrink it and inflate the backlog estimate."""
    s = _server(small_index, embedder, nw=4, fault_tolerance=True,
                admission_control=True)
    _load(s, 8)
    adm = s.sched.admission
    assert adm.effective_pool() == 4
    full = adm.backlog_us(s.sched.pending + s.sched.active)
    s.drain_worker(2)
    s.drain_worker(3)
    assert adm.effective_pool() == 2
    half = adm.backlog_us(s.sched.pending + s.sched.active)
    assert half >= full * 1.9
    for w in (2, 3):
        s.rebind_worker(w)
    assert adm.effective_pool() == 4
    m = s.run()
    assert m.finished == 8


# ------------------------------------------------------ deterministic chaos


def test_same_seed_same_chaos_fingerprints(small_index, embedder):
    """Replaying the identical FaultPlan seed yields bit-identical event
    traces — the whole recovery path is deterministic."""
    fps = []
    for _ in range(2):
        plan = FaultPlan.random(13, 4, 1_500_000.0, transient_prob=0.1)
        s = _server(small_index, embedder, sharding=True, plan=plan)
        _load(s, 10)
        s.run()
        fps.append(_fingerprints(s))
    assert fps[0] == fps[1]


# ------------------------------------------------ journal temp-file sweep


def test_journal_tmp_sweep_on_start_and_write(small_index, embedder,
                                              tmp_path):
    journal = str(tmp_path / "journal.jsonl")
    stale = journal + ".tmp.99999"
    with open(stale, "w") as f:
        f.write('{"half": "written"')  # crashed mid-write, never replaced
    s = Server(small_index, embedder, mode="hedra", num_ret_workers=1,
               journal_path=journal)
    assert not os.path.exists(stale)  # swept on journal-backed start
    s.add_request("q0", workflows.build("one-shot"), arrival_us=0.0)
    with open(stale, "w") as f:
        f.write("orphan from a previous pid")
    s.run()  # write_journal sweeps after the atomic replace
    assert os.path.exists(journal)
    assert not os.path.exists(stale)
    assert [p for p in os.listdir(tmp_path) if ".tmp." in p] == []
    # the journal itself survived and is readable
    rows = Server.read_journal(journal)
    assert len(rows) == 1 and rows[0]["finished"]


# ----------------------------------------------- dispatcher determinism


def test_least_loaded_deterministic_tie_break():
    d = dispatch.RetrievalDispatcher(num_workers=4, n_clusters=32)
    # all loads equal: lowest wid must win, in any candidate order
    assert d.least_loaded([3, 1, 2, 0]) == 0
    assert d.least_loaded([2, 3]) == 2
    d.note_busy(0, 100.0)  # load on 0
    assert d.least_loaded([0, 1]) == 1
    # equal explicit extra load keeps the wid tie-break
    assert d.least_loaded([3, 2], extra_load={2: 5.0, 3: 5.0}) == 2


def test_add_worker_grows_pool():
    d = dispatch.RetrievalDispatcher(num_workers=2, n_clusters=16)
    wid = d.add_worker()
    assert wid == 2 and d.num_workers == 3
    assert d.least_loaded([0, 1, 2]) == 0
