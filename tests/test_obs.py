"""Observability layer (src/repro/obs/): span tracing, the labeled metrics
registry, and latency attribution.

The load-bearing guarantees:

* obs ON is *passive* — per-request event traces stay bit-identical to the
  knobs-off goldens across every mode × worker count;
* ``Server.export_trace()`` emits structurally valid Chrome trace-event
  JSON covering every journaled request;
* attribution components sum to each request's measured latency within
  1e-6 relative tolerance, fault-injected runs included;

plus the ``Metrics.summary`` satellite fixes (shared percentile helper,
schema version, deterministic key order, window/timeline edge cases).
"""
import json
import os

import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.core.wavefront import SUMMARY_SCHEMA_VERSION, Metrics
from repro.obs.attribution import (
    ATTRIBUTION_COMPONENTS,
    attribution_report,
    sweep,
)
from repro.obs.registry import MetricsRegistry, TelemetrySampler
from repro.obs.trace import request_ids_in_trace, validate_trace
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.faults import FaultPlan
from repro.serving.workload import poisson_arrivals

PAPER_FIVE = ["one-shot", "hyde", "irg", "multistep", "recomp"]
MODES = ["sequential", "async", "hedra"]
RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)
GOLDEN_PATH = os.path.join(os.path.dirname(__file__),
                           "golden_fingerprints.json")


def _trace_hash(server) -> str:
    import hashlib

    fp = {
        r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
        for r in server.sched.done
    }
    return hashlib.sha256(json.dumps(fp, sort_keys=True).encode()).hexdigest()


def _serve_goldens(index, emb, mode, nw, **kw):
    be = SimBackend(index, emb, cost_model=RET_HEAVY, seed=0)
    s = Server(index, emb, mode=mode, backend=be, nprobe=12, topk=5,
               num_ret_workers=nw, **kw)
    for i, t in enumerate(poisson_arrivals(8.0, 20, seed=5)):
        s.add_request(f"q{i}", workflows.build(PAPER_FIVE[i % 5]),
                      arrival_us=float(t))
    return s, s.run()


def _fault_server(index, emb, seed=3, nw=4, sharding=False):
    plan = FaultPlan.random(seed, nw, 3e6, transient_prob=0.05)
    be = SimBackend(index, emb, cost_model=RET_HEAVY, seed=0)
    s = Server(index, emb, mode="hedra", backend=be, nprobe=12, topk=5,
               num_ret_workers=nw, tracing=True, telemetry=True,
               fault_plan=plan, index_sharding=sharding)
    for i, t in enumerate(poisson_arrivals(8.0, 20, seed=5)):
        s.add_request(f"q{i}", workflows.build(PAPER_FIVE[i % 5]),
                      arrival_us=float(t))
    return s, s.run()


# ---------------------------------------------------------------------------
# Passivity: obs ON never moves an event
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_ret_workers", [1, 4])
@pytest.mark.parametrize("mode", MODES)
def test_tracing_on_is_bit_identical_to_goldens(small_index, embedder,
                                                mode, num_ret_workers):
    """Stronger than the issue's knobs-off requirement: even with BOTH obs
    knobs ON the per-request event traces match the goldens bit-for-bit —
    the recorder draws no randomness and writes no scheduler state."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    s, m = _serve_goldens(small_index, embedder, mode, num_ret_workers,
                          tracing=True, telemetry=True)
    assert m.finished == 20
    assert _trace_hash(s) == golden[f"{mode}-nw{num_ret_workers}"]


# ---------------------------------------------------------------------------
# Trace export: structural validity + journal coverage
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_exported_trace_is_valid_and_covers_journal(small_index, embedder,
                                                    mode):
    s, m = _serve_goldens(small_index, embedder, mode, 4, tracing=True)
    trace = s.export_trace()
    assert validate_trace(trace) == []
    assert trace["displayTimeUnit"] == "ms"
    journal = {r.request_id for r in s.sched.done}
    assert journal <= request_ids_in_trace(trace)
    # per-resource tracks are named via metadata events
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "gen engine" in names
    assert "admission queue / scheduler" in names
    assert {f"retrieval worker {w}" for w in range(4)} <= names
    # flow edges exist and pair up (validate_trace checked id pairing)
    assert any(e["ph"] == "s" for e in trace["traceEvents"])


def test_exported_trace_valid_under_faults(small_index, embedder):
    s, m = _fault_server(small_index, embedder, seed=3, sharding=True)
    trace = s.export_trace()
    assert validate_trace(trace) == []
    journal = {r.request_id for r in s.sched.done}
    assert journal <= request_ids_in_trace(trace)
    cats = {e.get("cat") for e in trace["traceEvents"]}
    # fault structure is visible: lifecycle transitions recorded; this plan
    # kills a worker so lost spans and failover/retry flows appear
    assert "lifecycle" in cats


def test_export_trace_writes_file(small_index, embedder, tmp_path):
    s, _ = _serve_goldens(small_index, embedder, "hedra", 1, tracing=True)
    p = tmp_path / "trace.json"
    s.export_trace(str(p))
    on_disk = json.loads(p.read_text())
    assert validate_trace(on_disk) == []


def test_export_trace_requires_knob(small_index, embedder):
    s, _ = _serve_goldens(small_index, embedder, "hedra", 1)
    with pytest.raises(RuntimeError, match="tracing=True"):
        s.export_trace()
    with pytest.raises(RuntimeError, match="telemetry=True"):
        s.metrics_snapshot()
    with pytest.raises(RuntimeError, match="tracing=True"):
        s.attribution_report()


def test_validate_trace_catches_structural_breakage():
    base = {"ph": "X", "pid": 1, "tid": 0, "name": "a", "cat": "c",
            "args": {}}
    ok = {"traceEvents": [dict(base, ts=0.0, dur=1.0),
                          dict(base, ts=2.0, dur=1.0)]}
    assert validate_trace(ok) == []
    bad_order = {"traceEvents": [dict(base, ts=2.0, dur=1.0),
                                 dict(base, ts=0.0, dur=1.0)]}
    assert any("decreases" in p for p in validate_trace(bad_order))
    bad_dur = {"traceEvents": [dict(base, ts=0.0)]}
    assert any("dur" in p for p in validate_trace(bad_dur))
    dangling_flow = {"traceEvents": [
        {"ph": "s", "pid": 1, "tid": 0, "ts": 0.0, "name": "f", "id": 7}]}
    assert any("no finish" in p for p in validate_trace(dangling_flow))
    unbalanced = {"traceEvents": [
        {"ph": "B", "pid": 1, "tid": 0, "ts": 0.0, "name": "b"}]}
    assert any("unbalanced" in p for p in validate_trace(unbalanced))
    missing_key = {"traceEvents": [{"ph": "i", "pid": 1, "tid": 0,
                                    "ts": 0.0}]}
    assert any("missing" in p for p in validate_trace(missing_key))
    assert validate_trace({}) == ["traceEvents missing or not a list"]


# ---------------------------------------------------------------------------
# Attribution: components partition measured latency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_ret_workers", [1, 4])
@pytest.mark.parametrize("mode", MODES)
def test_attribution_sums_to_latency(small_index, embedder, mode,
                                     num_ret_workers):
    s, m = _serve_goldens(small_index, embedder, mode, num_ret_workers,
                          tracing=True)
    rep = s.attribution_report()  # check=True raises beyond 1e-6
    assert rep["finished"] == m.finished == 20
    assert rep["max_rel_residual"] <= 1e-6
    for row in rep["per_request"]:
        assert set(row["components_us"]) == set(ATTRIBUTION_COMPONENTS)
        assert row["latency_us"] == pytest.approx(
            sum(row["components_us"].values()), rel=1e-6)
        assert all(v >= 0.0 for v in row["components_us"].values())
    # fractions are a distribution over components
    assert sum(rep["fractions"].values()) == pytest.approx(1.0)
    assert rep["bottleneck"] in ATTRIBUTION_COMPONENTS
    assert set(rep["by_workflow"]) == set(PAPER_FIVE)


def test_attribution_sums_under_injected_faults(small_index, embedder):
    """The acceptance bar: with crashes/stalls/transients in play the
    decomposition still partitions each latency within 1e-6."""
    for seed in (1, 3):
        s, m = _fault_server(small_index, embedder, seed=seed,
                             sharding=(seed == 3))
        rep = s.attribution_report(rel_tol=1e-6)
        assert rep["finished"] == m.finished
        assert rep["max_rel_residual"] <= 1e-6
        if m.retries:
            assert rep["totals_us"]["retry_hedge_failover"] > 0.0


def test_attribution_report_flags_missing_spans(small_index, embedder):
    s, _ = _serve_goldens(small_index, embedder, "hedra", 1, tracing=True)
    rec = s.sched.obs
    # sabotage one request's record: drop all its work intervals and
    # stretch latency — the residual check must trip
    rid = next(iter(rec.requests))
    rec.requests[rid].intervals = [[0.0, 1.0, "merge"]]
    rec.requests[rid].finish_us = rec.requests[rid].arrival_us + 1e6
    report = attribution_report(rec, check=False)
    assert report["max_rel_residual"] == 0.0  # still partitions (queueing)
    # now break the partition itself: finish before arrival yields zero
    # components against nonzero latency only if latency is negative —
    # instead verify check trips on a hand-built overlap-free mismatch
    rec.requests[rid].intervals = [[0.0, 0.0, "merge"]]
    rec.requests[rid].finish_us = rec.requests[rid].arrival_us  # 0 latency
    attribution_report(rec)  # zero-latency row must not divide by zero


def test_sweep_priority_and_partition():
    # gen (priority) overlapping ret; gap -> queueing; clipped to window
    comps = sweep([[0.0, 10.0, "retrieval_compute"],
                   [5.0, 15.0, "generation_compute"],
                   [30.0, 50.0, "fault_recovery"]], 0.0, 40.0)
    assert comps["retrieval_compute"] == pytest.approx(5.0)
    assert comps["generation_compute"] == pytest.approx(10.0)
    assert comps["queueing"] == pytest.approx(15.0)
    assert comps["fault_recovery"] == pytest.approx(10.0)  # clipped at 40
    assert sum(comps.values()) == pytest.approx(40.0)
    # degenerate window
    assert sum(sweep([[0.0, 5.0, "merge"]], 3.0, 3.0).values()) == 0.0


# ---------------------------------------------------------------------------
# Metrics registry + telemetry sampler
# ---------------------------------------------------------------------------


def test_registry_prometheus_rendering():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_total", "help text", labelnames=("wf",))
    c.inc(wf="hyde")
    c.inc(2, wf="irg")
    g = reg.gauge("repro_depth", "queue depth")
    g.labels().set(7)
    h = reg.histogram("repro_lat_us", "latency", buckets=(10.0, 100.0))
    h.observe(5.0)
    h.observe(50.0)
    h.observe(5000.0)
    text = reg.render()
    assert "# TYPE repro_test_total counter" in text
    assert 'repro_test_total{wf="hyde"} 1' in text
    assert 'repro_test_total{wf="irg"} 2' in text
    assert "repro_depth 7" in text
    # histogram: cumulative buckets + +Inf == count
    assert 'repro_lat_us_bucket{le="10"} 1' in text
    assert 'repro_lat_us_bucket{le="100"} 2' in text
    assert 'repro_lat_us_bucket{le="+Inf"} 3' in text
    assert "repro_lat_us_count 3" in text
    # metric families render sorted by name
    lines = [ln for ln in text.splitlines() if ln.startswith("# TYPE")]
    assert lines == sorted(lines)
    snap = reg.snapshot()
    assert snap["schema_version"] == 1
    assert set(snap["metrics"]) == {"repro_test_total", "repro_depth",
                                    "repro_lat_us"}


def test_registry_rejects_label_and_type_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("x_total", labelnames=("a",))
    with pytest.raises(ValueError, match="expected labels"):
        c.inc(b="nope")
    with pytest.raises(ValueError, match="only go up"):
        c.labels(a="v").inc(-1)
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    assert reg.counter("x_total", labelnames=("a",)) is c  # idempotent


def test_telemetry_sampler_on_serving_run(small_index, embedder):
    s, m = _serve_goldens(small_index, embedder, "hedra", 4,
                          telemetry=True)
    tel = s.sched.telemetry
    assert tel is not None and len(tel.samples) > 2
    ts = [row["t_us"] for row in tel.samples]
    assert ts == sorted(ts)
    # virtual-clock pacing: samples are timestamped at the event that
    # crossed each interval boundary, so at most one sample per interval
    # (plus the finalize() sample at run end)
    assert len(ts) <= s.sched.now / tel.interval_us + 2
    for row in tel.samples:
        assert 0.0 <= row["gen_util"] <= 1.0 + 1e-9
        assert len(row["worker_util"]) == 4
        assert all(0.0 <= u <= 1.0 for u in row["worker_util"])
        assert sum(row["lifecycle"].values()) == 4
    snap = s.metrics_snapshot()
    assert snap["schema_version"] == 1
    assert "# TYPE repro_request_latency_us histogram" in snap["prometheus"]
    fam = snap["metrics"]["repro_requests_finished_total"]
    assert sum(x["value"] for x in fam["samples"]) == m.finished
    # finalize folded the Metrics dataclass counters in
    sched_counters = {x["labels"]["name"]: x["value"] for x in
                      snap["metrics"]["repro_scheduler_counter"]["samples"]}
    assert sched_counters["finished"] == m.finished


def test_telemetry_latency_histogram_totals(small_index, embedder):
    s, m = _serve_goldens(small_index, embedder, "hedra", 1,
                          telemetry=True)
    hist = s.sched.telemetry.m_latency
    total = sum(ch.count for ch in hist.children.values())
    assert total == m.finished
    total_us = sum(ch.sum for ch in hist.children.values())
    assert total_us == pytest.approx(sum(m.latencies_us))


# ---------------------------------------------------------------------------
# Metrics.summary satellites: schema, key order, window edge cases
# ---------------------------------------------------------------------------


def test_summary_schema_version_and_key_order(small_index, embedder):
    s, m = _serve_goldens(small_index, embedder, "hedra", 1)
    summ = m.summary()
    assert summ["schema_version"] == SUMMARY_SCHEMA_VERSION
    assert list(summ) == sorted(summ)
    w = m.window_summary(0.0, m.sim_time_us)
    assert w["schema_version"] == SUMMARY_SCHEMA_VERSION
    assert list(w) == sorted(w)


def test_window_summary_empty_finish_log():
    m = Metrics()
    w = m.window_summary(0.0, 1e6)
    assert w["finished"] == 0
    assert w["throughput_rps"] == 0.0
    assert np.isnan(w["p50_latency_ms"])
    assert np.isnan(w["p95_latency_ms"])
    assert m.goodput_timeline(1e5) == []


def test_window_summary_single_finish():
    m = Metrics()
    m.finish_log.append((5e5, 2e5, True))
    w = m.window_summary(0.0, 1e6)
    assert w["finished"] == 1 and w["finished_under_slo"] == 1
    assert w["p50_latency_ms"] == pytest.approx(200.0)
    assert w["p95_latency_ms"] == pytest.approx(200.0)
    assert w["goodput_rps"] == pytest.approx(1.0)
    # half-open window: a finish at the right edge is excluded
    assert m.window_summary(0.0, 5e5)["finished"] == 0
    assert m.window_summary(5e5, 1e6)["finished"] == 1


def test_window_summary_zero_width_window():
    m = Metrics()
    m.finish_log.append((5e5, 2e5, True))
    w = m.window_summary(5e5, 5e5)
    # degenerate span: no finishes (half-open empty interval), rates are
    # finite (guarded denominator), percentiles NaN
    assert w["finished"] == 0
    assert np.isfinite(w["throughput_rps"])
    assert np.isnan(w["p50_latency_ms"])


def test_goodput_timeline_step_larger_than_span():
    m = Metrics()
    m.finish_log.extend([(1e5, 5e4, True), (2e5, 5e4, True)])
    # window and step both dwarf the 0.1s finish span: still at least one
    # sample (an empty list would read as "no goodput")
    tl = m.goodput_timeline(window_us=1e6, step_us=5e6)
    assert len(tl) == 1
    t_end, rps = tl[0]
    assert rps == pytest.approx(2 / (1e6 / 1e6))
    # single finish, default half-window step
    m2 = Metrics()
    m2.finish_log.append((1e5, 5e4, False))
    tl2 = m2.goodput_timeline(window_us=4e5)
    assert len(tl2) >= 1
    assert all(r == 0.0 for _, r in tl2)  # not under SLO -> zero goodput
