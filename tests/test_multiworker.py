"""N-retrieval-worker executor: dispatch policies, SLO-slack ordering,
per-worker metrics, throughput scaling, and scheduler edge-case regressions."""
import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.core.ragraph import END, START, RAGraph
from repro.core.runtime import GenProgress, RequestContext
from repro.core.similarity import LocalCache
from repro.core.substage import TimeBudget
from repro.core.wavefront import SchedulerConfig, WavefrontScheduler
from repro.retrieval.ivf import ClusterCostModel, TopK
from repro.serving import dispatch
from repro.server import Server
from repro.serving.workload import WorkloadProfile, poisson_arrivals

# deep clusters so a single retrieval worker saturates and the pool matters
RET_BOUND = ClusterCostModel(fixed_us=150.0, per_vector_us=20.0)


def _serve(index, emb, nw, policy="affinity", n=40, rate=40.0, workload=None):
    cfg = SchedulerConfig.preset("hedra", num_ret_workers=nw,
                                 dispatch_policy=policy, nprobe=12, topk=5)
    be = SimBackend(index, emb, cost_model=RET_BOUND)
    s = Server(index, emb, backend=be, config=cfg, workload=workload)
    for i, t in enumerate(poisson_arrivals(rate, n, seed=5)):
        s.add_request(f"q{i}", workflows.build(
            ["one-shot", "hyde", "irg", "multistep", "recomp"][i % 5]),
            arrival_us=t)
    return s, s.run()


# --------------------------------------------------------------- worker pool


def test_multiworker_completes_and_reports_per_worker(small_index, embedder):
    s, m = _serve(small_index, embedder, nw=4)
    assert m.finished == 40
    assert len(m.ret_busy_per_worker) == 4
    assert sum(1 for b in m.ret_busy_per_worker if b > 0) >= 2
    summ = m.summary()
    assert summ["num_ret_workers"] == 4
    assert summ["ret_util_max"] >= summ["ret_util_min"] >= 0.0
    assert summ["ret_worker_skew"] >= 1.0
    # backend tracked per-worker charge too, and it matches the metrics
    rep = s.backend.worker_report()
    assert set(rep) <= set(range(4)) and len(rep) >= 2


def test_multiworker_throughput_scales(small_index, embedder):
    _, m1 = _serve(small_index, embedder, nw=1)
    _, m4 = _serve(small_index, embedder, nw=4)
    r1 = m1.summary()["throughput_rps"]
    r4 = m4.summary()["throughput_rps"]
    assert m1.finished == m4.finished == 40
    assert r4 >= 1.2 * r1, f"4-worker speedup only {r4 / r1:.2f}x"


def test_all_dispatch_policies_serve(small_index, embedder):
    for policy in dispatch.DISPATCH_POLICIES:
        _, m = _serve(small_index, embedder, nw=3, policy=policy, n=15)
        assert m.finished == 15, policy


def test_single_worker_metrics_back_compat(small_index, embedder):
    _, m = _serve(small_index, embedder, nw=1, n=10, rate=4.0)
    assert m.finished == 10
    # total busy time is the sum over the (single) worker pool
    assert m.ret_busy_us == pytest.approx(m.ret_busy_per_worker[0])
    assert m.summary()["ret_worker_skew"] == pytest.approx(1.0)


# ----------------------------------------------------------------- dispatcher


def test_affinity_prefers_history_and_falls_back_least_loaded():
    d = dispatch.RetrievalDispatcher(2, 16, policy="affinity")
    d.note_dispatch(0, [1, 2, 3])
    d.note_busy(0, 500.0)
    # hot clusters follow worker 0's history despite its higher load
    assert d.pick_worker([2], [0, 1]) == 0
    # cold clusters go to the least-loaded worker
    assert d.pick_worker([9], [0, 1]) == 1


def test_round_robin_cycles_and_bad_policy_rejected():
    d = dispatch.RetrievalDispatcher(3, 8, policy="round_robin")
    picks = [d.pick_worker([0], [0, 1, 2]) for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]
    with pytest.raises(ValueError):
        dispatch.RetrievalDispatcher(2, 8, policy="nope")


def test_order_by_slack_tie_break_is_deterministic():
    """Equal slack -> arrival then request_id break the tie, so assembly
    order (and therefore dispatch) is stable under input permutation."""
    g = workflows.build("one-shot")
    budget = TimeBudget()
    cm = ClusterCostModel()
    sizes = np.full(8, 100)
    reqs = [RequestContext(rid, g, {}, arrival_us=0.0, slo_us=1e6)
            for rid in (3, 1, 2, 0)]
    expected = [0, 1, 2, 3]
    for perm in ([0, 1, 2, 3], [3, 2, 1, 0], [2, 0, 3, 1]):
        order = dispatch.order_by_slack(
            [reqs[i] for i in perm], now=0.0, budget=budget, cost_model=cm,
            sizes=sizes, default_slo_us=1e4)
        assert [r.request_id for r in order] == expected
    # arrival breaks ties ahead of request_id
    early = RequestContext(9, g, {}, arrival_us=-5.0, slo_us=1e6 + 5.0)
    order = dispatch.order_by_slack(reqs + [early], now=0.0, budget=budget,
                                    cost_model=cm, sizes=sizes,
                                    default_slo_us=1e4)
    assert order[0].request_id == 9


def test_policies_pick_stable_workers_under_equal_load():
    """Guards the replica-routing refactor: with equal load / no history,
    every policy must resolve ties deterministically (lowest wid)."""
    for policy in ("affinity", "least_loaded"):
        d = dispatch.RetrievalDispatcher(4, 8, policy=policy)
        assert [d.pick_worker([3], [0, 1, 2, 3]) for _ in range(3)] == [0, 0, 0]
        assert d.pick_worker([3], [2, 3]) == 2
    # equal affinity history on two workers -> equal load tie -> lowest wid
    d = dispatch.RetrievalDispatcher(3, 8, policy="affinity")
    d.note_dispatch(1, [5])
    d.note_dispatch(2, [5])
    assert d.pick_worker([5], [1, 2]) == 1
    # round_robin is a deterministic cycle regardless of load
    d = dispatch.RetrievalDispatcher(3, 8, policy="round_robin")
    d.note_busy(0, 1e6)
    assert [d.pick_worker([0], [0, 1, 2]) for _ in range(4)] == [0, 1, 2, 0]


def test_order_by_slack_puts_tight_deadlines_first():
    g = workflows.build("one-shot")
    budget = TimeBudget()
    cm = ClusterCostModel()
    sizes = np.full(8, 100)
    loose = RequestContext(0, g, {}, arrival_us=0.0, slo_us=5e6)
    tight = RequestContext(1, g, {}, arrival_us=0.0, slo_us=1e5)
    late = RequestContext(2, g, {}, arrival_us=0.0)  # falls back to default
    order = dispatch.order_by_slack([loose, tight, late], now=0.0,
                                    budget=budget, cost_model=cm, sizes=sizes,
                                    default_slo_us=1e4)
    assert [r.request_id for r in order] == [2, 1, 0]


# ------------------------------------------------------------ per-request SLO


def test_per_request_slo_counted(small_index, embedder):
    wl = WorkloadProfile(slo_us_mean=1.0)  # impossible deadline
    _, m = _serve(small_index, embedder, nw=2, n=8, rate=4.0, workload=wl)
    assert m.finished == 8
    assert m.slo_violations == 8
    wl2 = WorkloadProfile(slo_us_mean=0.0)  # fall back to the lenient default
    _, m2 = _serve(small_index, embedder, nw=2, n=8, rate=4.0, workload=wl2)
    assert m2.slo_violations == 0


def test_workload_slo_sampling_deterministic():
    wl = WorkloadProfile(slo_us_mean=2e6, slo_us_sigma=0.5)
    assert wl.slo_us(3) == wl.slo_us(3)
    draws = {wl.slo_us(i) for i in range(16)}
    assert len(draws) > 1  # sigma spreads the deadlines


# -------------------------------------------------- stale-progress regression


def _scheduler(index, embedder):
    cfg = SchedulerConfig.preset("hedra", nprobe=8, topk=3)
    be = SimBackend(index, embedder, cost_model=RET_BOUND)
    return WavefrontScheduler(be, index, cfg)


def _ret_done_request(sched, graph, rid=0):
    req = RequestContext(rid, graph, {"input": "x"})
    req.start()
    sched.active.append(req)
    sched._enter_stage(req, 0.0)
    assert req.ret is not None
    # drain the stage: pretend every queued cluster was searched
    req.ret.searched = list(req.ret.cluster_queue)
    req.ret.cluster_queue = []
    req.ret.topk = req.ret.topk.merge(np.array([0.1], np.float32),
                                      np.array([7], np.int64))
    return req


def test_stale_gen_progress_not_restored_on_ret_ret(small_index, embedder):
    """advance() clears req.gen; _finish_ret_stage must not resurrect stale
    generation progress onto a successor that is another retrieval node."""
    g = RAGraph("retret")
    g.add_retrieval(0, query="input", output="d0")
    g.add_retrieval(1, query="d0", output="d1")
    g.add_edge(START, 0).add_edge(0, 1).add_edge(1, END)
    g.validate()
    sched = _scheduler(small_index, embedder)
    req = _ret_done_request(sched, g)
    # stale progress left over from a rolled-back speculation on node 99
    req.gen = GenProgress(target_tokens=16, node_id=99)
    sched._finish_ret_stage(req, now=1.0)
    assert req.current == 1
    assert req.gen is None  # stale progress must not leak onto node 1


def test_gen_progress_restored_on_matching_generation_node(small_index, embedder):
    g = RAGraph("retgen")
    g.add_retrieval(0, query="input", output="d0")
    g.add_generation(1, prompt="answer {d0}")
    g.add_edge(START, 0).add_edge(0, 1).add_edge(1, END)
    g.validate()
    sched = _scheduler(small_index, embedder)
    req = _ret_done_request(sched, g)
    keep = GenProgress(target_tokens=16, node_id=1, generated=4, prefilled=True)
    req.gen = keep
    sched._finish_ret_stage(req, now=1.0)
    assert req.current == 1
    assert req.gen is keep  # progress for the right node survives


# ------------------------------------------------------------ engine admit


def test_scheduler_smoke_with_spec_and_multiworker(small_index, embedder):
    """Speculation machinery must keep working on the worker pool."""
    _, m = _serve(small_index, embedder, nw=4, n=24, rate=12.0)
    assert m.finished == 24
    assert m.spec_gen_attempts >= 0  # counters exist and run() terminated
