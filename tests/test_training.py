"""Training substrate: optimizer, microbatching, checkpoint, data, engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TRAIN_4K, ShapeConfig
from repro.models import lm
from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.data import SyntheticTokenStream
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batch(cfg, B=4, S=32, seed=0):
    k = jax.random.PRNGKey(seed)
    t = jax.random.randint(k, (B, S), 1, cfg.vocab_size)
    return {"tokens": t, "labels": jnp.roll(t, -1, 1)}


def test_train_step_reduces_loss(tiny):
    cfg, params = tiny
    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3, warmup_steps=1)))
    opt = init_opt_state(params)
    batch = _batch(cfg)
    losses = []
    for _ in range(8):
        loss, params, opt, stats = step(params, opt, batch)
        losses.append(float(loss))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


def test_microbatch_equivalence(tiny):
    cfg, params = tiny
    opt = init_opt_state(params)
    batch = _batch(cfg, B=4)
    l1, p1, _, _ = jax.jit(make_train_step(cfg, microbatch=0))(params, opt, batch)
    l2, p2, _, _ = jax.jit(make_train_step(cfg, microbatch=2))(params, opt, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3, f"param divergence {d}"


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    opt = init_opt_state(params)
    state = {"params": params, "opt": opt}
    save_checkpoint(str(tmp_path), 7, state, extra={"note": "x"}, keep=2)
    save_checkpoint(str(tmp_path), 14, state, keep=2)
    assert latest_step(str(tmp_path)) == 14
    step, restored, extra = restore_checkpoint(str(tmp_path), 7, like=state)
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prunes(tmp_path, tiny):
    cfg, params = tiny
    for s in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), s, {"p": params}, keep=2)
    from repro.training.checkpoint import latest_steps

    assert latest_steps(str(tmp_path)) == [3, 4]


def test_data_stream_deterministic_resume():
    cfg = get_config("qwen3-1.7b").reduced()
    shape = ShapeConfig("t", 64, 8, "train")
    ds = SyntheticTokenStream(cfg, shape)
    b1 = ds.batch_at(5)
    b2 = SyntheticTokenStream(cfg, shape).batch_at(5)  # fresh pipeline, same step
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < cfg.vocab_size
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_elastic_runner_roundtrip(tmp_path, tiny):
    from repro.distributed.elastic import ElasticConfig, ElasticRunner
    from repro.launch.mesh import make_host_mesh

    cfg, params = tiny
    ecfg = ElasticConfig(ckpt_dir=str(tmp_path), save_every=2, keep=2)

    def build_step(mesh):
        return jax.jit(make_train_step(cfg))

    def init_fn(mesh):
        return {"params": params, "opt": init_opt_state(params)}

    runner = ElasticRunner(ecfg, make_host_mesh, build_step)
    mesh, step_fn, state, start = runner.resume_or_init(init_fn, lambda m, l: None)
    assert start == 0
    runner.maybe_save(2, state)
    mesh, step_fn, state2, start2 = runner.resume_or_init(init_fn, lambda m, l: None)
    assert start2 == 2
    # straggler detection
    assert not runner.observe_step_time(1.0, 1.0)
    for _ in range(5):
        trig = runner.observe_step_time(10.0, 1.0)
    assert trig


def test_generation_engine_continuous_batching(tiny):
    from repro.serving.engine import GenerationEngine

    cfg, params = tiny
    eng = GenerationEngine(cfg, params, max_batch=3, max_len=96, eos_id=-1)
    a = eng.add_sequence(np.arange(6) % 200 + 1, max_new=5)
    b = eng.add_sequence(np.arange(10) % 200 + 1, max_new=9)
    assert eng.batch_size == 2
    for _ in range(5):
        eng.step()
    assert eng.batch_size == 1  # a finished, slot freed
    c = eng.add_sequence(np.arange(4) % 200 + 1, max_new=3)
    while eng.batch_size:
        eng.step()
    assert len(eng.free_slots) == 3


def test_generation_engine_truncates_long_prompt(tiny):
    """Prompts longer than max_len must left-truncate (keep the suffix)
    instead of crashing on the pad-slot broadcast."""
    from repro.serving.engine import GenerationEngine

    cfg, params = tiny
    eng = GenerationEngine(cfg, params, max_batch=2, max_len=64, eos_id=-1)
    sid = eng.add_sequence(np.arange(200) % 200 + 1, max_new=2)
    # suffix kept, with decode headroom reserved (max_len - max_new)
    assert eng.seqs[sid].prompt_len == 62
    while eng.batch_size:
        eng.step()
    assert len(eng.free_slots) == 2


def test_generation_engine_sampler_not_shared(tiny):
    """Each engine must own its SamplerConfig (no shared mutable default)."""
    from repro.serving.engine import GenerationEngine

    cfg, params = tiny
    e1 = GenerationEngine(cfg, params, max_batch=1, max_len=32)
    e2 = GenerationEngine(cfg, params, max_batch=1, max_len=32)
    assert e1.sampler is not e2.sampler
