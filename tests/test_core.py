"""RAGraph, transforms, budget (Eq.1), similarity, speculation units."""
import numpy as np
import pytest

from repro.core.ragraph import END, START, RAGraph
from repro.core.runtime import RequestContext, RuntimeDAG
from repro.core.similarity import (
    LocalCache,
    answer_from_cache,
    early_termination_possible,
    observation_stats,
    reorder_clusters,
)
from repro.core.speculation import SpeculationPolicy, Speculator
from repro.core.substage import TimeBudget
from repro.retrieval.ivf import TopK
from repro import workflows


def test_ragraph_listing1_construction():
    g = RAGraph()
    g.add_generation(0, prompt="Generate a hypothesis for {input}.", output="hypopara")
    g.add_retrieval(1, topk=5, query="hypopara", output="docs")
    g.add_generation(2, prompt="Answer {query} using {docs}.")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, END)
    g.validate()
    assert g.entry() == 0
    assert g.successor(0, {}) == 1
    assert g.successor(2, {}) is END
    assert g.nodes[0].inputs() == ["input"]


def test_conditional_edges():
    g = RAGraph()
    g.add_generation(0, prompt="Decompose {input}.", output="subquestion")
    g.add_edge(START, 0)
    g.add_edge(0, lambda s: 1 if s.get("loop") else END)
    g.add_retrieval(1, query="subquestion")
    g.add_edge(1, END)
    g.validate()
    assert g.successor(0, {"loop": True}) == 1
    assert g.successor(0, {}) is END


def test_workflow_builders():
    for name in workflows.WORKFLOWS:
        g = workflows.build(name)
        g.validate()
        assert g.entry() is not None


def test_langchain_import_adapter():
    g = RAGraph.from_langchain_steps([
        {"type": "retriever", "query": "input", "topk": 3},
        {"type": "llm", "prompt": "Answer {input}"},
    ])
    g.validate()
    assert g.nodes[0].kind == "retrieval"
    assert g.nodes[1].kind == "generation"


def test_duplicate_node_rejected():
    g = RAGraph()
    g.add_generation(0, prompt="x")
    with pytest.raises(ValueError):
        g.add_generation(0, prompt="y")


# ---------------------------------------------------------------- Eq. (1)


def test_time_budget_closed_form():
    b = TimeBudget(beta_us=200.0, t_retrieval_us=20_000.0)
    mb = b.mb_us
    # interior optimum of the corrected objective
    assert abs(mb - np.sqrt(2 * 20_000 * 200)) < 1e-6
    # delta_l at mb* beats neighbours
    assert b.delta_l(mb) >= b.delta_l(mb * 0.5)
    assert b.delta_l(mb) >= b.delta_l(mb * 2.0)


def test_time_budget_adapts():
    b = TimeBudget(ema=0.5)
    m0 = b.mb_us
    for _ in range(8):
        b.observe_retrieval_stage(200_000.0)
    assert b.mb_us > m0  # longer retrievals -> bigger budget


def test_budget_cluster_admission():
    from repro.retrieval.ivf import ClusterCostModel

    b = TimeBudget(beta_us=100, t_retrieval_us=10_000)
    cm = ClusterCostModel(fixed_us=100, per_vector_us=1.0)
    sizes = np.full(64, 500)
    n = b.clusters_for_budget(list(range(16)), cm, sizes)
    assert 1 <= n <= 16
    # tiny budget still admits at least one cluster (progress guarantee)
    b2 = TimeBudget(beta_us=1e-9, t_retrieval_us=1e-6)
    assert b2.clusters_for_budget(list(range(4)), cm, sizes) == 1


# ------------------------------------------------------------- similarity


def test_reorder_is_permutation(small_index):
    cache = LocalCache()
    cache.home_clusters = {3, 5}
    cache.probed_clusters = {3, 5, 7, 9}
    cache.query_vec = np.zeros(small_index.dim, np.float32)
    cands = [9, 1, 5, 7, 2, 3]
    plan = reorder_clusters(cands, cache)
    assert sorted(plan.order) == sorted(cands)
    assert set(plan.order[: plan.n_home]) <= {3, 5}
    mid = plan.order[plan.n_home: plan.n_home + plan.n_probed]
    assert set(mid) <= {7, 9}


def test_early_termination_is_lossless():
    """When the lower-bound check fires, the skipped clusters provably cannot
    improve the running top-k.  Uses a tight-cluster corpus where the
    triangle-inequality bound has teeth (radius << inter-cluster distance)."""
    from repro.retrieval import CorpusConfig, IVFIndex, make_corpus

    docs, _, _ = make_corpus(CorpusConfig(
        n_docs=6000, dim=32, n_topics=48, doc_noise=0.04, seed=11))
    small_index = IVFIndex.build(docs, 48, iters=6)
    rng = np.random.default_rng(7)
    hits = 0
    for i in range(24):
        q = docs[rng.integers(len(docs))]
        probes = list(small_index.probe_order(q[None], 16)[0])
        tk = TopK.empty(3)
        while probes:
            cid = probes.pop(0)
            d, ids = small_index.search_cluster(q[None], int(cid))
            tk = tk.merge(d[0], ids[0])
            if early_termination_possible(small_index, q, probes, tk):
                hits += 1
                full = TopK(3, tk.dists.copy(), tk.ids.copy())
                for c2 in probes:
                    d2, i2 = small_index.search_cluster(q[None], int(c2))
                    full = full.merge(d2[0], i2[0])
                np.testing.assert_array_equal(tk.ids, full.ids)
                break
    # the mechanism should fire at least sometimes on in-corpus queries
    assert hits >= 1


def test_observation_stats_on_similar_queries(small_index, embedder):
    """Fig. 9a reproduction: locality observations hold for a meaningful
    fraction of drifted query pairs."""
    o = {"o1": 0, "o2": 0, "o3": 0}
    n = 20
    for rid in range(n):
        q0 = embedder.embed_query(rid, 0)
        q1 = embedder.embed_query(rid, 1)
        st = observation_stats(small_index, q0, q1, k=1, k_prime=20, nprobe=12)
        for k in o:
            o[k] += st[k]
    assert o["o3"] >= n * 0.4, f"O3 rate too low: {o}"
    assert o["o2"] >= o["o1"] * 0.5 or o["o2"] >= n * 0.2


def test_cache_answer_conservative(small_index, small_corpus):
    docs, _, _ = small_corpus
    cache = LocalCache()
    q = docs[0]
    D, I = small_index.search(q[None], nprobe=16, k=20)
    tk = TopK(20, D[0].astype(np.float32), I[0])
    cache.update(q, tk, small_index, probed=[0, 1])
    # identical query, plenty of margin -> may answer; drifted far -> must not
    far = q + 10.0
    assert answer_from_cache(cache, far, 3, delta=0.1) is None


# ------------------------------------------------------------- speculation


def test_speculation_validate_and_rollback_counters():
    sp = Speculator(SpeculationPolicy(mode="hedra"))
    assert sp.validate_gen(np.array([1, 2, 3]), np.array([1, 2, 3]))
    assert not sp.validate_gen(np.array([1, 2, 3]), np.array([1, 2, 4]))
    assert sp.stats.validated_gen == 1
    assert sp.stats.rolled_back_gen == 1
    assert sp.stats.gen_accuracy == 0.5


def test_speculation_gating_modes():
    hedra = Speculator(SpeculationPolicy(mode="hedra", tau=0.8))
    assert hedra.throughput_gate(0.5, 1.0)
    assert not hedra.throughput_gate(0.9, 1.0)
    ralm = Speculator(SpeculationPolicy(mode="ralmspec"))
    assert ralm.throughput_gate(0.99, 1.0)  # RaLMSpec always speculates
    off = Speculator(SpeculationPolicy(mode="off"))
    assert not off.throughput_gate(0.0, 1.0)


def test_spec_gen_readiness_by_mode():
    pol = SpeculationPolicy(mode="pipeline")
    sp = Speculator(pol)
    assert not sp.spec_gen_ready(1, 10, 0.1, 1.0)  # conservative baseline
    assert sp.spec_gen_ready(8, 10, 0.1, 1.0)
    sp2 = Speculator(SpeculationPolicy(mode="hedra"))
    assert sp2.spec_gen_ready(4, 10, 0.5, 1.0)
    assert not sp2.spec_gen_ready(4, 10, 50.0, 1.0)  # poor partial top-k


# ------------------------------------------------------------ runtime DAG


def test_dag_invalidation_cascades():
    g = workflows.build("one-shot")
    req = RequestContext(0, g, {"input": "x"})
    dag = RuntimeDAG()
    a = dag.new_subnode(req, "ret", {"clusters": [1]})
    b = dag.new_subnode(req, "gen", {"n_steps": 4}, deps={a.sid}, speculative=True)
    c = dag.new_subnode(req, "gen", {"n_steps": 4}, deps={b.sid}, speculative=True)
    dag.complete(a)
    assert {s.sid for s in dag.ready()} == {b.sid}
    dag.invalidate(b)
    assert b.status == "invalid" and c.status == "invalid"
