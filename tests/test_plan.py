"""SoA retrieval-plan executor: reference equivalence, scoreboard merges,
oversized-cluster residency refusal, snapshot consistency, delta uploads."""
import numpy as np
import pytest

from repro.retrieval import (
    HybridRetrievalEngine,
    IVFIndex,
    TopK,
)
from repro.retrieval.plan import (
    BatchTopK,
    PlanBuilder,
    plan_from_work,
    plan_search,
)


# ------------------------------------------------------------ plan executor


def test_plan_search_matches_reference(small_index):
    rng = np.random.default_rng(0)
    q = rng.standard_normal((12, small_index.dim)).astype(np.float32)
    for nprobe, k in [(1, 1), (6, 5), (16, 10), (48, 3), (8, 20)]:
        D, I = small_index.search(q, nprobe, k)
        D2, I2 = plan_search(small_index, q, nprobe, k)
        np.testing.assert_array_equal(I2, I)
        np.testing.assert_allclose(D2, D, rtol=1e-4, atol=1e-4)


def test_plan_matches_legacy_work_list(small_index):
    rng = np.random.default_rng(1)
    q = rng.standard_normal((6, small_index.dim)).astype(np.float32)
    probes = small_index.probe_order(q, 4)
    work = [(q[i], int(probes[i, j]), TopK.empty(5))
            for i in range(6) for j in range(3)]
    ref = small_index.search_cluster_batch(
        [(a, b, TopK(c.k, c.dists.copy(), c.ids.copy())) for a, b, c in work])
    plan = plan_from_work(work)
    res = plan.finalize(small_index.search_plan(plan))
    for g, r in enumerate(ref):
        tk = res.group_topk(g, r.k)
        np.testing.assert_array_equal(tk.ids, r.ids)
        np.testing.assert_allclose(tk.dists, r.dists, rtol=1e-4, atol=1e-5)


def test_batch_topk_merge_rows_matches_scalar_merge():
    rng = np.random.default_rng(2)
    n, k = 6, 5
    batch = BatchTopK.empty(n, k)
    scalars = [TopK.empty(k) for _ in range(n)]
    for _ in range(4):
        cand_d = rng.random((n, 7)).astype(np.float32)
        cand_i = rng.choice(100_000, (n, 7), replace=False).astype(np.int64)
        batch.merge_rows(np.arange(n), cand_d, cand_i)
        scalars = [tk.merge(cand_d[i], cand_i[i])
                   for i, tk in enumerate(scalars)]
    for i, tk in enumerate(scalars):
        np.testing.assert_array_equal(batch.ids[i], tk.ids)
        np.testing.assert_allclose(batch.dists[i], tk.dists, rtol=1e-6)


def test_finalize_streaks_match_sequential_merge(small_index):
    """Vectorized per-cluster streaks == the scalar merge/compare chain."""
    rng = np.random.default_rng(3)
    q = rng.standard_normal((4, small_index.dim)).astype(np.float32)
    probes = small_index.probe_order(q, 6)
    b = PlanBuilder()
    for i in range(4):
        b.add(q[i], probes[i], k=5, no_improve=1)
    plan = b.build()
    res = plan.finalize(small_index.search_plan(plan))
    for g in range(4):
        tk = TopK.empty(5)
        last_kth, noimp = np.inf, 1
        for cid in probes[g]:
            d, ids = small_index.search_cluster(q[g: g + 1], int(cid))
            tk = tk.merge(d[0], ids[0])
            if tk.kth < last_kth - 1e-12:
                noimp, last_kth = 0, tk.kth
            else:
                noimp += 1
        np.testing.assert_array_equal(res.group_topk(g, 5).ids, tk.ids)
        assert int(res.no_improve[g]) == noimp
        assert float(res.last_kth[g]) == pytest.approx(last_kth)


def test_finalize_paths_agree(small_index, monkeypatch):
    """The dense streak-tensor path and the sequential fallback (used for
    large coarse-mode groups) must produce identical results."""
    import repro.retrieval.plan as plan_mod

    rng = np.random.default_rng(4)
    q = rng.standard_normal((5, small_index.dim)).astype(np.float32)
    probes = small_index.probe_order(q, 7)
    b = PlanBuilder()
    for i in range(5):
        b.add(q[i], probes[i], k=4, last_kth=50.0, no_improve=2)
    plan = b.build()
    results = small_index.search_plan(plan)
    dense = plan.finalize(results)
    monkeypatch.setattr(plan_mod, "_STREAK_TENSOR_MAX", 1)
    seq = plan.finalize(results)
    np.testing.assert_array_equal(dense.ids, seq.ids)
    np.testing.assert_array_equal(dense.dists, seq.dists)
    np.testing.assert_array_equal(dense.no_improve, seq.no_improve)
    np.testing.assert_allclose(dense.last_kth, seq.last_kth)


# -------------------------------------------------- hybrid engine regressions


def _manual_index(sizes, dim=16, seed=7):
    """Hand-built IVFIndex with exact cluster sizes (kmeans would rebalance)."""
    rng = np.random.default_rng(seed)
    n = int(sum(sizes))
    flat = rng.standard_normal((n, dim)).astype(np.float32)
    offsets = np.zeros(len(sizes) + 1, np.int64)
    np.cumsum(sizes, out=offsets[1:])
    cent = np.stack([flat[offsets[i]: offsets[i + 1]].mean(0)
                     for i in range(len(sizes))]).astype(np.float32)
    radii = np.array([
        np.linalg.norm(flat[offsets[i]: offsets[i + 1]] - cent[i], axis=1).max()
        for i in range(len(sizes))], np.float32)
    return IVFIndex(
        centroids=cent, flat=flat,
        flat_norms=(flat**2).sum(-1).astype(np.float32),
        ids=np.arange(n, dtype=np.int64), offsets=offsets, radii=radii)


def _oversized_setup():
    """Index with one giant cluster (> tile_len=128) plus small ones."""
    index = _manual_index([400, 60, 60, 60, 60])
    assert int(index.cluster_sizes().max()) > 128
    return index


def test_oversized_cluster_refused_and_paths_agree():
    """A cluster larger than tile_len must stay on the host path (no silent
    truncation) and host/device results must agree."""
    index = _oversized_setup()
    eng = HybridRetrievalEngine(index, cache_capacity=8, tile_len=128,
                                update_interval=1, transit_substages=0,
                                kernel_impl="ref")
    big_cid = int(np.argmax(index.cluster_sizes()))
    rng = np.random.default_rng(8)
    q = rng.standard_normal((6, index.dim)).astype(np.float32) * 0.05
    # hammer the big cluster so the cache wants it resident
    for _ in range(6):
        work = [(q[i], big_cid, TopK.empty(5)) for i in range(6)]
        res, _ = eng.search_substage(work)
    assert eng.cache.stats.oversized_rejects > 0
    assert not eng.cache.is_resident(big_cid)
    # results equal the host reference (would differ if truncated to 128)
    work = [(q[i], big_cid, TopK.empty(5)) for i in range(6)]
    res, _ = eng.search_substage(work)
    ref = index.search_cluster_batch(
        [(q[i], big_cid, TopK.empty(5)) for i in range(6)])
    for r, rr in zip(res, ref):
        np.testing.assert_array_equal(r.ids, rr.ids)
        np.testing.assert_allclose(r.dists, rr.dists, rtol=1e-4, atol=1e-5)


def test_small_clusters_still_cached_next_to_oversized():
    index = _oversized_setup()
    eng = HybridRetrievalEngine(index, cache_capacity=8, tile_len=128,
                                update_interval=1, transit_substages=0,
                                kernel_impl="ref")
    small_cid = 1  # 60 rows < tile_len
    rng = np.random.default_rng(9)
    q = rng.standard_normal((4, index.dim)).astype(np.float32)
    for _ in range(4):
        eng.search_substage([(q[i], small_cid, TopK.empty(3))
                             for i in range(4)])
    assert eng.cache.is_resident(small_cid)


def test_oversized_cluster_rejected_once_and_slot_backfilled():
    """A refused cluster must not pin a slot across refreshes: it is
    rejected at most once and the slot goes to the next-hottest cluster."""
    index = _oversized_setup()  # cluster 0 oversized, 1-4 small
    eng = HybridRetrievalEngine(index, cache_capacity=2, tile_len=128,
                                update_interval=1, transit_substages=0,
                                kernel_impl="ref")
    rng = np.random.default_rng(12)
    q = rng.standard_normal((4, index.dim)).astype(np.float32)
    # cluster 0 hottest, clusters 1 and 2 steadily warm — capacity 2
    for _ in range(6):
        work = [(q[i], 0, TopK.empty(3)) for i in range(4)]
        work += [(q[0], 1, TopK.empty(3)), (q[1], 2, TopK.empty(3))]
        eng.search_substage(work)
    assert eng.cache.stats.oversized_rejects == 1  # refused once, not per refresh
    assert not eng.cache.is_resident(0)
    # both slots hold loadable clusters despite 0 being the hottest
    assert len(eng.cache.resident_ids) == 2


def test_snapshot_partition_survives_swap(small_index):
    """Charge computed from a dispatch-time snapshot must execute correctly
    (host fallback, exact results) even if the cluster is swapped out before
    results_fn runs — the assembly/completion race from the issue."""
    from repro.core.backends import SimBackend
    from repro.retrieval.synthetic import SyntheticEmbedder

    eng = HybridRetrievalEngine(small_index, cache_capacity=2,
                                update_interval=1, transit_substages=0,
                                kernel_impl="ref")
    rng = np.random.default_rng(10)
    q = rng.standard_normal(small_index.dim).astype(np.float32)
    cid = int(small_index.probe_order(q[None], 1)[0][0])
    other = (cid + 1) % small_index.n_clusters
    # make cid resident
    for _ in range(3):
        eng.search_substage([(q, cid, TopK.empty(4))])
    assert eng.cache.is_resident(cid)
    be = SimBackend(small_index, SyntheticEmbedder(np.eye(small_index.dim,
                                                          dtype=np.float32)),
                    hybrid=eng)
    b = PlanBuilder()
    b.add(q, [cid], k=4)
    charge, results_fn = be.search_charged(b.build(), worker_id=0)
    assert charge > 0
    # swap the snapshot's cluster out before completion
    for _ in range(6):
        eng.search_substage([(q, other, TopK.empty(4))] * 3)
    if eng.cache.is_resident(cid):  # force the race deterministically
        slot = eng.cache.slot_of(cid)
        eng._slot_cid[slot] = -2
    batch = results_fn()
    d, ids = small_index.search_cluster(q[None], cid)
    order = np.argsort(d[0], kind="stable")[:4]
    np.testing.assert_array_equal(batch.ids[0][: len(order)], ids[0][order])
    assert eng.cache.stats.stale_fallbacks > 0


def test_empty_substage_still_ticks_cache_clock():
    """search_substage([]) must advance transits/refresh like any sub-stage."""
    index = _manual_index([60, 60, 60, 60], seed=13)
    eng = HybridRetrievalEngine(index, cache_capacity=2, tile_len=128,
                                update_interval=1, transit_substages=2,
                                kernel_impl="ref")
    rng = np.random.default_rng(13)
    q = rng.standard_normal(index.dim).astype(np.float32)
    eng.search_substage([(q, 0, TopK.empty(3))])  # refresh -> 0 in transit
    assert not eng.cache.is_resident(0)
    for _ in range(2):
        eng.search_substage([])
    assert eng.cache.is_resident(0)


def test_delta_upload_instead_of_full_invalidation():
    """Cluster swaps must delta-update the device mirror, not rebuild it."""
    index = _manual_index([60, 60, 60, 60, 60], seed=11)
    eng = HybridRetrievalEngine(index, cache_capacity=4, tile_len=128,
                                update_interval=1, transit_substages=0,
                                kernel_impl="ref")
    rng = np.random.default_rng(11)
    q = rng.standard_normal((4, index.dim)).astype(np.float32)
    # phase 1: clusters {0, 1} become resident and are device-scanned
    for _ in range(3):
        eng.search_substage([(q[i], i % 2, TopK.empty(3)) for i in range(4)])
    assert eng.cache.stats.hits > 0
    assert eng.stats()["uploads"]["full"] == 1  # one initial mirror build
    # phase 2: cluster 4 gets hot, swaps in (capacity 4 forces an eviction)
    # -> later device scans ride a per-slot delta, never a full re-upload
    for _ in range(6):
        eng.search_substage([(q[i], 4, TopK.empty(3)) for i in range(4)])
    assert eng.cache.is_resident(4)
    up = eng.stats()["uploads"]
    assert up["full"] == 1  # never rebuilt from scratch
    assert up["delta"] >= 1 and up["delta_slots"] >= 1
    # device results after the delta match the host reference
    res, timing = eng.search_substage([(q[0], 4, TopK.empty(3))])
    ref = index.search_cluster_batch([(q[0], 4, TopK.empty(3))])
    np.testing.assert_array_equal(res[0].ids, ref[0].ids)
    assert timing.n_device_items == 1
