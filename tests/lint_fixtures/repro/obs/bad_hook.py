"""Planted: hooks/obs-mutation — a hook writing into a passed-in job dict,
mutating scheduler state, and mutating through a local alias; reads and
recorder-owned state stay legal."""


class Recorder:
    def __init__(self):
        self.rows = []

    def on_job(self, job, sched):
        job["_obs_span"] = 1  # PLANTED: write into a passed-in object
        sched.active.append(job)  # PLANTED: mutator on scheduler state
        q = sched.dispatcher
        q.submit(job)  # PLANTED: mutator through a param alias
        depth = len(sched.active)  # ok: read
        self.rows.append(depth)  # ok: recorder-owned state
        return depth
