"""Negative: outside the virtual-clock zone the wall clock is legal."""
import time


def stamp():
    return time.time()  # not in repro/core|serving|crossreq|obs: allowed
