"""Planted: hooks/unguarded-hook — a hook call outside its knob guard;
`if`-guarded and short-circuit-guarded calls stay legal."""


class Scheduler:
    def __init__(self, tracing):
        self.obs = object() if tracing else None
        self.telemetry = None

    def finish(self, req, now):
        self.obs.request_finished(req, now)  # PLANTED: no knob guard
        if self.obs is not None:
            self.obs.request_submitted(req, now)  # ok: guarded
        self.telemetry is not None and self.telemetry.maybe_sample(
            self, now)  # ok: short-circuit guard
