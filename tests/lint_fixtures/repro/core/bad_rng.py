"""Planted: determinism/unseeded-rng — global-state draw and an
entropy-seeded constructor; seeded constructors stay legal."""
import random

import numpy as np


def draw():
    x = random.random()  # PLANTED: module-level global RNG
    rng = np.random.default_rng()  # PLANTED: entropy-seeded constructor
    good = np.random.default_rng(0)
    also_good = random.Random(1234)
    return x, rng, good, also_good
