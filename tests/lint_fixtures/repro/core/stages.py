"""Negative: the registry file itself is exempt from registry/kind-branch
— per-kind behaviour lives here by design."""


def spec_for(node):
    if node.kind == "generation":  # exempt file: not a finding
        return "gen"
    if node.kind in ("retrieval", "rerank"):  # exempt file: not a finding
        return "ret"
    return "other"
