"""Planted: determinism/set-iteration — a set loop feeding a heap push, a
dict-view loop feeding dispatch selection, and a hash-order comprehension;
sorted() wrapping and order-insensitive set folds stay legal."""
import heapq


def schedule(ids, workers, dispatcher, heap):
    pending = set(ids)
    for rid in pending:  # PLANTED: set iteration into an ordering sink
        heapq.heappush(heap, rid)
    for w in workers.values():  # PLANTED: dict view into dispatch selection
        dispatcher.pick_worker(w)
    exposed = [rid for rid in pending]  # PLANTED: hash order escapes
    seen = set()
    for rid in pending:  # ok: order-insensitive fold
        seen.add(rid)
    for rid in sorted(pending):  # ok: sanitized
        heapq.heappush(heap, rid)
    return exposed, seen
