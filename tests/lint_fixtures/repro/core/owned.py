"""Planted: ownership/cross-domain-write and ownership/cross-domain-call —
server-domain code reaching past its scheduler handle; declared handoffs,
exposed read surfaces, and plain reads stay legal."""
from repro.core.ownership import handoff, owned_by


class Metrics:
    def report(self):
        return {}


@owned_by("scheduler", expose=("metrics",))
class Sched:
    def __init__(self):
        self.now = 0.0
        self.metrics = Metrics()

    @handoff("server")
    def add_request(self, req):
        return True

    def internal_step(self):
        return self.now


@owned_by("server")
class Front:
    def __init__(self):
        self.sched = Sched()

    def bad_write(self):
        self.sched.now = 5.0  # PLANTED: write past the handle

    def bad_call(self):
        return self.sched.internal_step()  # PLANTED: not a handoff

    def fine(self, req):
        self.sched.add_request(req)  # ok: declared @handoff("server")
        snap = self.sched.metrics.report()  # ok: exposed read surface
        return snap, self.sched.now  # ok: plain read
