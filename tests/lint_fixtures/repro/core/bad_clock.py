"""Planted: determinism/wall-clock — one positive, one suppressed."""
import time


def measure():
    t0 = time.time()  # PLANTED: wall-clock in the virtual-clock zone
    t1 = time.perf_counter()  # repro-lint: disable=wall-clock -- sanctioned
    return t0, t1
