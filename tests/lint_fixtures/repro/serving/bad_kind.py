"""Planted: registry/kind-branch — direct comparison, aliased membership
test, and a match statement; registry dispatch stays legal."""
from repro.core import stages


def route(node):
    if node.kind == "generation":  # PLANTED: kind comparison
        return 1
    k = node.kind
    if k in ("retrieval", "rerank"):  # PLANTED: aliased membership test
        return 2
    match node.kind:
        case "rewrite":  # PLANTED: match on a stage kind
            return 3
        case _:
            pass
    return stages.spec(node.kind)  # ok: registry dispatch
