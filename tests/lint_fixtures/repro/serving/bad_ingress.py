"""Planted: the wall-clock ingress policy boundary.  Serving code outside
serving/ingress.py must not read real time (determinism/wall-clock), and
the scheduler thread must cross into producer-owned queue state only via
declared @handoff points — a direct write or a non-handoff call past the
handle is an ownership violation."""
import time

from repro.core.ownership import handoff, owned_by


@owned_by("ingress")
class Queue:
    def __init__(self):
        self.items = []
        self.closed = False

    @handoff("server")
    def drain(self):
        out, self.items = self.items, []
        return out

    def internal_compact(self):
        return len(self.items)


@owned_by("server")
class Loop:
    def __init__(self):
        self.queue = Queue()

    def stamp(self):
        return time.monotonic()  # PLANTED: wall read outside ingress.py

    def bad_write(self):
        self.queue.closed = True  # PLANTED: write past the producer handle

    def bad_call(self):
        return self.queue.internal_compact()  # PLANTED: not a handoff

    def fine(self):
        return self.queue.drain()  # ok: declared @handoff("server")
