"""Negative: this path *is* the sanctioned wall-clock boundary
(policy.wallclock_ingress_paths) — producer-side stamping reads real time
here by design and must stay silent, with no inline suppressions."""
import time


class Stamper:
    def __init__(self):
        self.t0 = time.monotonic()  # ok: inside the ingress carve-out

    def now_us(self):
        return (time.monotonic() - self.t0) * 1e6  # ok: same carve-out
