"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ivf_scan.ivf_scan import ivf_scan_pallas
from repro.kernels.ivf_scan.ref import ivf_scan_ref


@pytest.mark.parametrize("G,QB,d,C,L,k,lb", [
    (2, 8, 32, 4, 512, 5, 256),
    (4, 8, 64, 6, 1024, 10, 512),
    (1, 16, 128, 3, 256, 20, 128),
    (3, 8, 48, 5, 384, 1, 128),
])
def test_ivf_scan_shapes(G, QB, d, C, L, k, lb):
    rng = np.random.default_rng(G * 100 + k)
    q = jnp.asarray(rng.standard_normal((G, QB, d)), jnp.float32)
    slab = jnp.asarray(rng.standard_normal((C, L, d)), jnp.float32)
    valid = jnp.asarray(rng.integers(1, L + 1, size=(C,)), jnp.int32)
    gc = jnp.asarray(rng.integers(0, C, size=(G,)), jnp.int32)
    dr, ir = ivf_scan_ref(q, gc, slab, valid, k)
    dp, ip = ivf_scan_pallas(q, gc, slab, valid, k, lb=lb, interpret=True)
    dr, ir, dp, ip = map(np.asarray, (dr, ir, dp, ip))
    fin = np.isfinite(dr)
    assert np.array_equal(fin, np.isfinite(dp))
    np.testing.assert_allclose(dr[fin], dp[fin], rtol=1e-4, atol=1e-5)
    assert np.array_equal(ir, ip)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ivf_scan_dtypes(dtype):
    rng = np.random.default_rng(0)
    G, QB, d, C, L, k = 2, 8, 64, 4, 512, 8
    q = jnp.asarray(rng.standard_normal((G, QB, d)), dtype)
    slab = jnp.asarray(rng.standard_normal((C, L, d)), dtype)
    valid = jnp.asarray(rng.integers(1, L + 1, size=(C,)), jnp.int32)
    gc = jnp.asarray(rng.integers(0, C, size=(G,)), jnp.int32)
    dr, _ = ivf_scan_ref(q, gc, slab, valid, k)
    dp, _ = ivf_scan_pallas(q, gc, slab, valid, k, lb=256, interpret=True)
    fin = np.isfinite(np.asarray(dr))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(dr)[fin], np.asarray(dp)[fin],
                               rtol=tol, atol=tol)


def test_ivf_scan_duplicate_distances():
    """k-pass selection must pick the first index on ties (stable order)."""
    G, QB, d, C, L, k = 1, 8, 16, 1, 256, 4
    q = jnp.zeros((G, QB, d), jnp.float32)
    slab = jnp.ones((C, L, d), jnp.float32)  # all rows identical
    valid = jnp.asarray([L], jnp.int32)
    gc = jnp.asarray([0], jnp.int32)
    dp, ip = ivf_scan_pallas(q, gc, slab, valid, k, lb=128, interpret=True)
    assert np.array_equal(np.asarray(ip)[0, 0], np.arange(k))


@pytest.mark.parametrize("B,H,KV,dh,S,sb", [
    (2, 8, 4, 64, 512, 256),
    (2, 16, 8, 128, 1024, 512),
    (1, 10, 1, 256, 512, 128),   # MQA, head pad
    (2, 32, 32, 96, 256, 256),   # MHA, odd head dim
])
def test_decode_attention_shapes(B, H, KV, dh, S, sb):
    rng = np.random.default_rng(B * 10 + H)
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, S + 1, size=(B,)), jnp.int32)
    ref = decode_attention_ref(q, k, v, lengths)
    out = decode_attention(q, k, v, lengths, impl="interpret", sb=sb)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), rtol=2e-5, atol=2e-5)


def test_decode_attention_bf16():
    rng = np.random.default_rng(5)
    B, H, KV, dh, S = 2, 8, 4, 64, 512
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.bfloat16) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.bfloat16)
    lengths = jnp.asarray([S, S // 2], jnp.int32)
    ref = decode_attention_ref(q, k, v, lengths).astype(jnp.float32)
    out = decode_attention(q, k, v, lengths, impl="interpret", sb=256).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), rtol=3e-2, atol=3e-2)


def test_decode_attention_length_one():
    """Edge: a sequence with exactly one valid cache entry."""
    B, H, KV, dh, S = 2, 4, 4, 64, 256
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.float32)
    lengths = jnp.asarray([1, S], jnp.int32)
    ref = decode_attention_ref(q, k, v, lengths)
    out = decode_attention(q, k, v, lengths, impl="interpret", sb=128)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# topk_merge kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Q,k,m,qb", [
    (16, 5, 12, 8), (8, 10, 10, 8), (24, 20, 4, 8), (8, 1, 16, 8),
])
def test_topk_merge_shapes(Q, k, m, qb):
    from repro.kernels.topk_merge.ref import topk_merge_ref
    from repro.kernels.topk_merge.topk_merge import topk_merge_pallas

    rng = np.random.default_rng(Q + k)
    rd = np.sort(rng.random((Q, k)).astype(np.float32), axis=1)
    rd[:, k // 2:] = np.inf  # half-filled scoreboards
    ri = rng.integers(0, 1_000_000, (Q, k)).astype(np.int32)
    cd = rng.random((Q, m)).astype(np.float32)
    ci = (rng.integers(0, 1_000_000, (Q, m)) + 2_000_000).astype(np.int32)
    dr, ir = topk_merge_ref(jnp.asarray(rd), jnp.asarray(ri),
                            jnp.asarray(cd), jnp.asarray(ci))
    dp, ip = topk_merge_pallas(jnp.asarray(rd), jnp.asarray(ri),
                               jnp.asarray(cd), jnp.asarray(ci),
                               qb=qb, interpret=True)
    dr, ir, dp, ip = map(np.asarray, (dr, ir, dp, ip))
    with warnings.catch_warnings():
        # inf - inf on the padding lanes used to fire "invalid value
        # encountered in subtract"; mask padding before differencing and
        # keep the block warning-free
        warnings.simplefilter("error", RuntimeWarning)
        fin = np.isfinite(dr)
        assert np.array_equal(fin, np.isfinite(dp))
        np.testing.assert_allclose(dr[fin], dp[fin], rtol=1e-6)
        # ids must match wherever distances are unique (padding masked out)
        d_masked = np.where(fin, dr, np.float32(np.finfo(np.float32).max))
        uniq = fin & (np.abs(np.diff(np.pad(d_masked, ((0, 0), (1, 0)),
                                            constant_values=-1),
                                     axis=1)) > 1e-9)
        np.testing.assert_array_equal(ir[uniq], ip[uniq])


def test_topk_merge_semantics_match_topk_class():
    """Kernel merge == retrieval.TopK.merge on the same data."""
    from repro.kernels.topk_merge.ops import topk_merge
    from repro.retrieval.ivf import TopK

    rng = np.random.default_rng(3)
    k, m = 6, 9
    tk = TopK.empty(k).merge(rng.random(5).astype(np.float32),
                             np.arange(5, dtype=np.int64))
    cd = rng.random(m).astype(np.float32)
    ci = np.arange(100, 100 + m, dtype=np.int64)
    want = tk.merge(cd, ci)
    dp, ip = topk_merge(jnp.asarray(tk.dists[None]),
                        jnp.asarray(tk.ids[None].astype(np.int32)),
                        jnp.asarray(cd[None]),
                        jnp.asarray(ci[None].astype(np.int32)),
                        impl="interpret")
    got_d, got_i = np.asarray(dp)[0], np.asarray(ip)[0]
    fin = np.isfinite(want.dists)
    np.testing.assert_allclose(got_d[fin], want.dists[fin], rtol=1e-6)
    np.testing.assert_array_equal(got_i[fin], want.ids[fin].astype(np.int32))
