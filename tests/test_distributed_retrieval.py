"""Sharded-index retrieval: correctness on a trivial mesh, multi-device
equivalence in a subprocess (host-platform device override must precede jax
init, so the 8-device check runs isolated), and — when the test process
itself was launched with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(``REPRO_KEEP_XLA_FLAGS=1``, the CI shard job) — the same all-gather/merge
path in-process with real shards."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_sharded_search_single_shard_matches_reference():
    from repro.retrieval.distributed import make_sharded_search, reference_search

    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    Q, C, L, d, k = 6, 8, 128, 32, 5
    q = jnp.asarray(rng.standard_normal((Q, d)), jnp.float32)
    slab = jnp.asarray(rng.standard_normal((C, L, d)), jnp.float32)
    valid = jnp.asarray(rng.integers(1, L + 1, (C,)), jnp.int32)
    f = make_sharded_search(mesh, k)
    with mesh:
        dist, rows = f(q, slab, valid)
    dref, rref = reference_search(q, slab, valid, k)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dref), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(rref))


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.retrieval.distributed import make_sharded_search, reference_search

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(1)
Q, C, L, d, k = 4, 16, 128, 32, 6
q = jnp.asarray(rng.standard_normal((Q, d)), jnp.float32)
slab = jnp.asarray(rng.standard_normal((C, L, d)), jnp.float32)
valid = jnp.asarray(rng.integers(1, L + 1, (C,)), jnp.int32)
f = make_sharded_search(mesh, k)
with mesh:
    dist, rows = f(q, slab, valid)
dref, rref = reference_search(q, slab, valid, k)
assert np.allclose(np.asarray(dist), np.asarray(dref), rtol=1e-5), "dist mismatch"
assert np.array_equal(np.asarray(rows), np.asarray(rref)), "rows mismatch"
print("OK")
"""


def test_sharded_search_8way_equivalence():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC, src],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "XLA_FLAGS": "",
             "REPRO_KEEP_XLA_FLAGS": "0"},
    )
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs >=4 devices (run the suite with "
                           "REPRO_KEEP_XLA_FLAGS=1 XLA_FLAGS="
                           "--xla_force_host_platform_device_count=4)")
def test_sharded_search_multidevice_inprocess():
    """With a forced multi-device CPU platform, make_sharded_search's
    all-gather + k-way merge runs with *real* shards in this process (not a
    1-device mesh), and still matches the single-device oracle — and the
    serving-path ShardMap split mirrors the mesh's contiguous tile ranges."""
    from repro.retrieval.distributed import (
        ShardMap, make_sharded_search, reference_search,
    )

    n_dev = min(4, jax.device_count())
    mesh = jax.make_mesh((n_dev,), ("data",))
    rng = np.random.default_rng(2)
    Q, C, L, d, k = 5, 4 * n_dev, 128, 32, 6
    q = jnp.asarray(rng.standard_normal((Q, d)), jnp.float32)
    slab = jnp.asarray(rng.standard_normal((C, L, d)), jnp.float32)
    valid = jnp.asarray(rng.integers(1, L + 1, (C,)), jnp.int32)
    f = make_sharded_search(mesh, k)
    with mesh:
        dist, rows = f(q, slab, valid)
    dref, rref = reference_search(q, slab, valid, k)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dref), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(rref))
    # the mesh shards C/n_dev contiguous tiles per chip; ShardMap.build over
    # equal-sized tiles produces the same contiguous ranges
    sm = ShardMap.build(np.full(C, L), n_dev)
    np.testing.assert_array_equal(
        sm.bounds, np.arange(0, C + 1, C // n_dev))
