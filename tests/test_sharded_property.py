"""Hypothesis property suite for shard-mode scatter-gather merging:
random cluster->shard assignments, probe lists, and k — the shard-split +
k-way merge must equal the whole-index ``BatchTopK`` fold for all seeds,
including empty-shard and all-probes-on-one-shard corners."""
import numpy as np
import pytest

from repro.retrieval.distributed import ShardMap
from repro.retrieval.plan import (
    BatchTopK,
    PlanBuilder,
    gather_scatter_rows,
    make_gather_plan,
)

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@st.composite
def _shard_cases(draw):
    n_clusters = draw(st.integers(4, 16))
    n_shards = draw(st.integers(1, 5))
    # arbitrary assignment; empty shards and one-shard pileups included
    owner = draw(st.lists(st.integers(0, n_shards - 1),
                          min_size=n_clusters, max_size=n_clusters))
    k = draw(st.integers(1, 8))
    n_probes = draw(st.integers(1, n_clusters))
    probes = draw(st.permutations(list(range(n_clusters))))[:n_probes]
    seed = draw(st.integers(0, 2**16))
    return n_clusters, n_shards, owner, k, probes, seed


_INDEX_MEMO: dict = {}


def _property_index(n_clusters: int, seed: int):
    """Small IVF index per (n_clusters, seed) — memoised so hypothesis
    examples don't pay a fresh kmeans/jit each draw."""
    from repro.retrieval import CorpusConfig, IVFIndex, make_corpus

    key = (n_clusters, seed)
    if key not in _INDEX_MEMO:
        docs, _, _ = make_corpus(CorpusConfig(
            n_docs=64 * n_clusters, dim=8, n_topics=n_clusters, seed=seed))
        _INDEX_MEMO[key] = IVFIndex.build(docs, n_clusters, iters=2)
    return _INDEX_MEMO[key]


@settings(max_examples=30, deadline=None)
@given(case=_shard_cases())
def test_shard_split_merge_equals_whole_index_fold(case):
    """Property: for random cluster->shard assignments, probe lists, and k,
    scanning per-shard parts and k-way merging the partial rows equals the
    whole-index ``BatchTopK`` fold — including empty shards and
    all-probes-on-one-shard corners."""
    n_clusters, n_shards, owner, k, probes, seed = case
    index = _property_index(n_clusters, seed % 3)
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(index.dim).astype(np.float32)
    sm = ShardMap.from_owner(owner, n_shards=n_shards)

    # whole-index fold: one plan over the full probe list
    whole = make_gather_plan(q, probes, k=k)
    ref = whole.finalize(index.search_plan(whole))

    # shard fold: per-part scans scattered into a gather board
    gather = make_gather_plan(q, probes, k=k)
    board = BatchTopK.empty(len(probes), gather.k)
    owners = sm.owner_of(probes)
    for shard, part in sm.split(probes):
        pb = PlanBuilder()
        pb.add(q, part, k=k)
        partial = pb.build()
        rows = index.search_plan(partial)
        gather_scatter_rows(board, np.flatnonzero(owners == shard),
                            rows, 0, len(part))
    res = gather.finalize(board)
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.dists, ref.dists)
    np.testing.assert_array_equal(res.no_improve, ref.no_improve)
    np.testing.assert_array_equal(res.last_kth, ref.last_kth)
