"""Property suite for repro-lint: the report is a pure function of file
*contents* — invariant under scan-order permutation — and inline
suppressions round-trip (suppressing exactly one finding's line removes
exactly that line's findings for that rule and nothing else).

Runs under hypothesis when it is installed (CI installs it explicitly);
otherwise falls back to a fixed seeded sweep of the same properties so the
suite never silently skips."""
import json
import os
import shutil
import tempfile

import numpy as np
import pytest

from repro.analysis.lint import run_lint
from repro.analysis.lint.framework import iter_py_files

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # local envs without hypothesis: seeded sweep instead
    HAVE_HYPOTHESIS = False

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "lint_fixtures")
FALLBACK_SEEDS = list(range(24))


def _property(n_examples):
    """Decorator: hypothesis-driven seeds when available, a fixed
    parametrized sweep otherwise.  The wrapped test takes ``seed`` last."""
    if HAVE_HYPOTHESIS:
        return lambda fn: settings(
            max_examples=n_examples, deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )(given(seed=st.integers(0, 2**32 - 1))(fn))
    return lambda fn: pytest.mark.parametrize(
        "seed", FALLBACK_SEEDS[:n_examples])(fn)


def _fixture_files():
    return list(iter_py_files([os.path.join(FIXTURES, "repro")]))


# ---------------------------------------------------------------------------
# Scan-order invariance: the report depends on contents, not traversal
# ---------------------------------------------------------------------------


@_property(30)
def test_report_invariant_under_file_reordering(seed):
    rng = np.random.default_rng(seed)
    files = _fixture_files()
    baseline = run_lint(files, root=FIXTURES).to_json()
    shuffled = [files[i] for i in rng.permutation(len(files))]
    assert run_lint(shuffled, root=FIXTURES).to_json() == baseline


@_property(12)
def test_report_invariant_under_duplicate_paths(seed):
    rng = np.random.default_rng(seed)
    files = _fixture_files()
    baseline = run_lint(files, root=FIXTURES).to_json()
    dup = files + [files[int(rng.integers(len(files)))]]
    shuffled = [dup[i] for i in rng.permutation(len(dup))]
    assert run_lint(shuffled, root=FIXTURES).to_json() == baseline


# ---------------------------------------------------------------------------
# Suppression round-trip: disabling one finding removes exactly it
# ---------------------------------------------------------------------------


def _key(f):
    return (f.path, f.line, f.rule)


@_property(30)
def test_suppression_removes_exactly_the_chosen_finding(seed):
    rng = np.random.default_rng(seed)
    base = run_lint(_fixture_files(), root=FIXTURES)
    assert base.findings
    chosen = base.findings[int(rng.integers(len(base.findings)))]
    with tempfile.TemporaryDirectory() as tmp:
        root = os.path.join(tmp, "scan")
        shutil.copytree(FIXTURES, root)
        target = os.path.join(root, chosen.path)
        lines = open(target, encoding="utf-8").read().splitlines(True)
        idx = chosen.line - 1
        eol = "\n" if lines[idx].endswith("\n") else ""
        lines[idx] = (lines[idx].rstrip("\n")
                      + f"  # repro-lint: disable={chosen.short_rule}" + eol)
        with open(target, "w", encoding="utf-8") as fh:
            fh.writelines(lines)
        after = run_lint([os.path.join(root, "repro")], root=root)
    # exactly the chosen line's findings for that rule moved to suppressed
    removed = {_key(f) for f in base.findings} - {_key(f)
                                                  for f in after.findings}
    assert removed == {_key(chosen)}
    assert _key(chosen) in {_key(f) for f in after.suppressed}


@_property(12)
def test_suppression_report_is_json_round_trip_stable(seed):
    rng = np.random.default_rng(seed)
    files = _fixture_files()
    shuffled = [files[i] for i in rng.permutation(len(files))]
    report = run_lint(shuffled, root=FIXTURES)
    d = json.loads(report.to_json())
    assert d == report.to_dict()
    assert d["summary"]["total"] == len(report.findings)
    assert d["summary"]["suppressed"] == len(report.suppressed)
