"""Example smoke test: the end-to-end real-execution driver must run with
the cross-request layer enabled (``--crossreq``) on tiny shapes."""
import importlib.util
import os
import sys

import pytest


def _load_example(name: str):
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"examples_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.slow
def test_serve_rag_e2e_smoke_with_crossreq(capsys):
    mod = _load_example("serve_rag_e2e")
    mod.main(["--smoke", "--crossreq", "--n-requests", "4"])
    out = capsys.readouterr().out
    assert "real-execution RAG serving" in out
    assert "crossreq report:" in out
