"""repro-lint self-tests: every rule fires on its planted fixture (the CI
acceptance gate — a planted violation per rule must fail the build),
negatives stay silent, the real package scans clean with an empty baseline,
the policy's kind set tracks the live stage registry, and the behaviour
fixed by the linter's findings stays fixed (recorder passivity, wid-ordered
lifecycle transitions)."""
import json
import os
import subprocess
import sys
from types import SimpleNamespace

import pytest

import repro
from repro.analysis.lint import ALL_RULES, run_lint
from repro.analysis.lint.policy import DEFAULT_POLICY
from repro.core import stages
from repro.obs.trace import TraceRecorder
from repro.serving.lifecycle import DEAD, HEALTHY, WorkerRegistry

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "lint_fixtures")


@pytest.fixture(scope="module")
def fixture_report():
    return run_lint([os.path.join(FIXTURES, "repro")], root=FIXTURES)


@pytest.fixture(scope="module")
def repo_report():
    pkg_dir = os.path.abspath(list(repro.__path__)[0])
    return run_lint([pkg_dir], root=os.path.dirname(pkg_dir))


# ---------------------------------------------------------------------------
# The CI acceptance gate: one planted violation per rule must be caught
# ---------------------------------------------------------------------------


def test_every_rule_fires_on_its_fixture(fixture_report):
    fired = {f.rule for f in fixture_report.findings}
    assert fired == set(ALL_RULES)


EXPECTED = {
    ("repro/core/bad_clock.py", "determinism/wall-clock"): 1,
    ("repro/core/bad_rng.py", "determinism/unseeded-rng"): 2,
    ("repro/core/bad_set_iter.py", "determinism/set-iteration"): 3,
    ("repro/serving/bad_kind.py", "registry/kind-branch"): 3,
    ("repro/obs/bad_hook.py", "hooks/obs-mutation"): 3,
    ("repro/core/wavefront.py", "hooks/unguarded-hook"): 1,
    ("repro/core/owned.py", "ownership/cross-domain-write"): 1,
    ("repro/core/owned.py", "ownership/cross-domain-call"): 1,
    ("repro/serving/bad_ingress.py", "determinism/wall-clock"): 1,
    ("repro/serving/bad_ingress.py", "ownership/cross-domain-write"): 1,
    ("repro/serving/bad_ingress.py", "ownership/cross-domain-call"): 1,
}


def test_exact_fixture_finding_counts(fixture_report):
    got: dict = {}
    for f in fixture_report.findings:
        got[(f.path, f.rule)] = got.get((f.path, f.rule), 0) + 1
    assert got == EXPECTED


def test_negative_files_stay_silent(fixture_report):
    silent = ("repro/core/stages.py", "repro/util/ok_clock.py",
              "repro/serving/ingress.py")
    assert not [f for f in fixture_report.findings if f.path in silent]


def test_inline_suppression_is_honoured(fixture_report):
    # bad_clock.py line 7 carries `# repro-lint: disable=wall-clock`
    assert not [f for f in fixture_report.findings
                if f.path == "repro/core/bad_clock.py" and f.line == 7]
    assert [f for f in fixture_report.suppressed
            if f.path == "repro/core/bad_clock.py" and f.line == 7]


def test_findings_are_sorted_and_json_stable(fixture_report):
    keys = [(f.path, f.line, f.col, f.rule) for f in fixture_report.findings]
    assert keys == sorted(keys)
    d = fixture_report.to_dict()
    assert d["schema_version"] == 1
    assert sum(d["summary"]["by_rule"].values()) == len(
        fixture_report.findings)
    assert json.loads(fixture_report.to_json()) == d


# ---------------------------------------------------------------------------
# The repo itself is clean (the hard CI gate) with an empty baseline
# ---------------------------------------------------------------------------


def test_repo_scans_clean(repo_report):
    assert repo_report.ok, repo_report.render_text()
    assert repo_report.findings == []


def test_repo_suppressions_are_justified(repo_report):
    # the only sanctioned suppressions today are RealBackend's measured-
    # execution wall-clock reads; anything new must be wall-clock too or
    # this pin forces a review
    assert {f.rule for f in repo_report.suppressed} <= {
        "determinism/wall-clock"}
    assert all(f.path == "repro/core/backends.py"
               for f in repo_report.suppressed)


def test_policy_kinds_match_live_registry():
    assert set(DEFAULT_POLICY.stage_kinds) == set(stages.STAGE_REGISTRY)


# ---------------------------------------------------------------------------
# CLI surface (what CI invokes)
# ---------------------------------------------------------------------------


def _cli(*args):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(HERE), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(HERE))


def test_cli_clean_repo_exits_zero(tmp_path):
    report = tmp_path / "repro-lint-report.json"
    proc = _cli("--format", "json", "--report", str(report))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(report.read_text())
    assert data["findings"] == []


def test_cli_fixture_violations_exit_one(tmp_path):
    report = tmp_path / "report.json"
    proc = _cli("--root", FIXTURES, "--report", str(report),
                os.path.join(FIXTURES, "repro"))
    assert proc.returncode == 1
    data = json.loads(report.read_text())
    assert {f["rule"] for f in data["findings"]} == set(ALL_RULES)


def test_cli_list_rules():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    assert proc.stdout.split() == list(ALL_RULES)


# ---------------------------------------------------------------------------
# Regressions pinned by the linter's real findings on this repo
# ---------------------------------------------------------------------------


def test_trace_recorder_never_mutates_job_dicts():
    """The attribution span/row stash lives in recorder-owned side tables,
    not on the scheduler's job dicts (the hooks/obs-mutation finding this
    linter was built to catch)."""
    rec = TraceRecorder()
    req = SimpleNamespace(request_id=1, arrival_us=0.0, slo_us=0.0,
                          graph=SimpleNamespace(name="wf"), state={})
    job = {"reqs": [req], "n_steps": 4, "end": 100.0}
    before = dict(job)
    rec.gen_job(job, now=0.0)
    assert job == before  # record-only: no keys added, none changed
    assert id(job) in rec._job_spans and id(job) in rec._job_rows


def test_lifecycle_transitions_are_wid_ordered():
    """tick() reports transitions in canonical wid order even when workers
    were registered out of wid order (the set-iteration/ordering class of
    bug the determinism rule polices)."""
    reg = WorkerRegistry(0)
    for wid in (7, 2, 9, 0):
        reg.register(0.0, wid=wid)
    assert all(reg.state_of(w) == HEALTHY for w in (7, 2, 9, 0))
    plan = SimpleNamespace(crash_at=lambda wid: 0.0, stalls=[],
                           heartbeat_pause_start=lambda wid, now: None)
    out = reg.tick(1e9, plan)
    assert [t[0] for t in out] == [0, 2, 7, 9]
    assert all(t[2] == DEAD for t in out)
