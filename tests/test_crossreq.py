"""Cross-request coordination layer: global semantic cache, in-flight
dedup/fusion, popularity-aware replication, and the scheduler integration
(disabled == bit-identical, enabled == faster + correct)."""
import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.core.wavefront import SchedulerConfig
from repro.crossreq import (
    CrossRequestCoordinator,
    FusionPass,
    GlobalCache,
    PopularityTracker,
    ReplicaMap,
)
from repro.retrieval import DuplicateTrafficEmbedder, HybridRetrievalEngine
from repro.retrieval.hotcache import HotClusterCache
from repro.retrieval.ivf import ClusterCostModel, TopK
from repro.serving import dispatch
from repro.server import Server
from repro.serving.workload import WorkloadProfile, poisson_arrivals

RET_BOUND = ClusterCostModel(fixed_us=150.0, per_vector_us=20.0, per_query_us=2.0)
NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp"]

CROSSREQ = dict(global_cache_size=128, dedup_threshold=0.95,
                replication_factor=2)


def _serve(index, emb, *, dup=0.45, crossreq=True, nw=2, n=40, rate=70.0,
           hybrid=None, config=None, near_jitter=0.0, **cfg_kw):
    demb = DuplicateTrafficEmbedder(emb, dup_ratio=dup, pool_size=4,
                                    near_jitter=near_jitter)
    wl = WorkloadProfile(gen_tokens_mean=14.0, gen_tokens_sigma=0.25,
                         prompt_tokens_mean=48.0)
    be = SimBackend(index, demb, hybrid=hybrid, cost_model=RET_BOUND,
                    gen_step_base_us=600.0, gen_step_per_seq_us=20.0)
    kw = dict(CROSSREQ) if crossreq else {}
    kw.update(cfg_kw)
    if config is not None:
        s = Server(index, demb, backend=be, config=config, workload=wl)
    else:
        s = Server(index, demb, mode="hedra", backend=be, workload=wl,
                   nprobe=16, topk=5, num_ret_workers=nw, **kw)
    for i, t in enumerate(poisson_arrivals(rate, n, seed=5)):
        name = NAMES[demb.canonical_id(i) % len(NAMES)]
        s.add_request(f"q{i}", workflows.build(name), arrival_us=t)
    return s, demb, s.run()


# ------------------------------------------------------------- GlobalCache


def _topk(dists, ids, k=None):
    d = np.asarray(dists, np.float32)
    i = np.asarray(ids, np.int64)
    return TopK(k or len(d), d, i)


def test_global_cache_exact_hit_and_miss(small_index):
    gc = GlobalCache(8)
    q = np.random.default_rng(0).standard_normal(small_index.dim).astype(np.float32)
    assert gc.answer(q, 3, 8) is None
    gc.insert(q, _topk([0.1, 0.2, 0.3, 0.4], small_index.ids[:4]),
              small_index, [0, 1, 2], nprobe=8)
    hit = gc.answer(q, 3, 8)
    assert hit is not None
    d, i = hit
    np.testing.assert_allclose(d, [0.1, 0.2, 0.3])
    # different nprobe -> different key -> no exact fast path from the hash
    assert gc.stats.exact_hits == 1
    # far-away query: no answer, no seed
    q2 = -q
    assert gc.answer(q2, 3, 8) is None
    assert gc.seed(q2) is None


def test_global_cache_seed_returns_localcache_duck(small_index):
    gc = GlobalCache(8)
    q = np.random.default_rng(1).standard_normal(small_index.dim).astype(np.float32)
    gc.insert(q, _topk([0.1, 0.2], small_index.ids[:2]), small_index,
              [3, 4, 5], nprobe=8)
    near = (q + 0.01).astype(np.float32)
    ent = gc.seed(near)
    assert ent is not None and not ent.empty
    assert ent.probed_clusters == {3, 4, 5}
    assert len(ent.home_clusters) >= 1
    from repro.core.similarity import reorder_clusters

    plan = reorder_clusters([5, 4, 9], ent)
    assert plan.order[-1] == 9  # unseen cluster ordered last


def test_global_cache_eviction_is_popularity_weighted(small_index):
    gc = GlobalCache(2)
    rng = np.random.default_rng(2)
    qs = rng.standard_normal((3, small_index.dim)).astype(np.float32) * 10
    tk = _topk([0.1], small_index.ids[:1])
    gc.insert(qs[0], tk, small_index, [0], nprobe=8)
    gc.insert(qs[1], tk, small_index, [0], nprobe=8)
    for _ in range(5):  # make entry 0 popular
        assert gc.answer(qs[0], 1, 8) is not None
    gc.insert(qs[2], tk, small_index, [0], nprobe=8)  # evicts the cold one
    assert gc.answer(qs[0], 1, 8) is not None  # popular entry survived
    assert gc.stats.evictions == 1
    assert len(gc) == 2


def test_global_cache_near_answer_via_ball_bound(small_index):
    """With a wide (k'-style) entry, a near-but-not-identical query is
    answered through the answer_from_cache triangle/ball bound."""
    gc = GlobalCache(8)
    q = np.zeros(small_index.dim, np.float32)
    q[0] = 1.0
    # 20-wide entry: k tight results, then a big gap before the tail
    dists = np.concatenate([np.linspace(0.01, 0.05, 5),
                            np.linspace(4.0, 5.0, 15)]).astype(np.float32)
    gc.insert(q, _topk(dists, small_index.ids[:20]), small_index,
              [0, 1], nprobe=8)
    near = q.copy()
    near[1] = 0.01  # inside answer_delta_frac * ||q||, clearly not exact
    hit = gc.answer(near, 3, 8)
    assert hit is not None
    assert gc.stats.near_answers == 1
    np.testing.assert_array_equal(hit[1], small_index.ids[:3])


def test_stage_publishes_wide_entries(small_index, embedder):
    """Stages publish top-k' (wider than the request k) entries, so the
    ball-bound near-answer path has margin to work with."""
    s, _, m = _serve(small_index, embedder, dup=0.0, n=10, rate=10.0,
                     dedup_threshold=0.0, replication_factor=1)
    gc = s.sched.crossreq.global_cache
    assert len(gc) > 0
    widths = [int((e.ids >= 0).sum()) for e in gc._entries if e is not None]
    assert max(widths) > 8, f"entries not widened: {widths}"


def test_global_cache_same_key_refreshes_in_place(small_index):
    gc = GlobalCache(4)
    q = np.random.default_rng(3).standard_normal(small_index.dim).astype(np.float32)
    tk = _topk([0.5], small_index.ids[:1])
    gc.insert(q, tk, small_index, [0], nprobe=8)
    gc.insert(q, tk, small_index, [1], nprobe=8)
    assert len(gc) == 1
    assert gc.stats.refreshes == 1


# -------------------------------------------------------------- FusionPass


class _FakeReq:
    def __init__(self, rid, qv, k=5, nprobe=8):
        class _R:
            pass

        self.request_id = rid
        self.ret = _R()
        self.ret.query_vec = np.asarray(qv, np.float32)
        self.ret.k = k
        self.ret.nprobe = nprobe


def test_fusion_exact_and_near_subscribe():
    q = np.array([1.0, 0.0, 0.0], np.float32)
    lead, dup = _FakeReq(0, q), _FakeReq(1, q.copy())
    near = _FakeReq(2, np.array([0.999, 0.04, 0.0], np.float32))
    far = _FakeReq(3, np.array([0.0, 1.0, 0.0], np.float32))
    fp = FusionPass(0.95)
    assert fp.try_subscribe(lead, allow_near=True) is None
    fp.register_leader(lead)
    assert fp.try_subscribe(dup, allow_near=True) == "exact"
    assert fp.try_subscribe(near, allow_near=True) == "near"
    assert fp.try_subscribe(far, allow_near=True) is None
    assert fp.fanout(0) == 3
    subs = fp.complete_leader(0)
    assert [(s.request_id, kind) for s, kind in subs] == [(1, "exact"), (2, "near")]
    assert fp.complete_leader(0) == []  # group is gone
    assert fp.n_inflight_leaders == 0


def test_fusion_threshold_one_is_exact_only_and_k_bucketed():
    q = np.array([1.0, 0.0], np.float32)
    fp = FusionPass(1.0)
    fp.register_leader(_FakeReq(0, q))
    assert fp.try_subscribe(_FakeReq(1, q * 0.999), allow_near=True) is None
    assert fp.try_subscribe(_FakeReq(2, q.copy()), allow_near=True) == "exact"
    # same vector, different k -> different bucket, no fusion
    assert fp.try_subscribe(_FakeReq(3, q.copy(), k=9), allow_near=True) is None
    with pytest.raises(ValueError):
        FusionPass(0.0)


# -------------------------------------------- PopularityTracker / ReplicaMap


def test_replica_map_from_tracker_spreads_owners():
    tr = PopularityTracker(16)
    tr.record([3] * 10 + [7] * 6 + [1] * 3)
    rm = ReplicaMap(4, 2, hot_fraction=0.2)
    rm.refresh_from_tracker(tr)
    o3, o7 = rm.owners(3), rm.owners(7)
    assert len(o3) == len(o7) == 2
    assert o3 != o7  # rank-spread: adjacent hot clusters on disjoint primaries
    assert rm.owners_for([3, 7]) == set(o3) | set(o7)
    assert rm.owners(15) is None
    # factor 1 -> no replication at all
    rm1 = ReplicaMap(4, 1)
    rm1.refresh_from_tracker(tr)
    assert rm1.n_replicated == 0


def test_dispatcher_routes_to_replica_holders():
    tr = PopularityTracker(8)
    rm = ReplicaMap(4, 2, hot_fraction=0.25)
    d = dispatch.RetrievalDispatcher(4, 8, policy="affinity",
                                     tracker=tr, replica_map=rm)
    d.note_dispatch(0, [2, 2, 2, 2])  # worker 0 hoards cluster 2
    rm.refresh_from_tracker(tr)
    holders = rm.owners(2)
    assert holders is not None and len(holders) == 2
    d.note_busy(holders[0], 1000.0)
    # replica routing picks the least-loaded holder, not the affinity owner
    assert d.pick_worker([2], list(range(4))) == holders[1]
    assert d.replica_routes == 1
    # unmapped cluster falls through to the affinity policy
    assert d.pick_worker([5], [1, 2]) in (1, 2)


def test_hotcache_replication_stages_copies_on_distinct_owners():
    loads = []
    cache = HotClusterCache(16, capacity=8, update_interval=1,
                            transit_substages=0, replication=2, num_owners=4,
                            loader=lambda cid, slot: loads.append((cid, slot)) or True)
    cache.tracker.record([3] * 20 + [5] * 10 + [7] * 5 + [1] * 2)
    cache.end_substage()  # triggers refresh
    cache.end_substage()  # clears the (zero-length) transits
    slots = cache._replica_slots
    hot = [c for c, s in slots.items() if len(s) > 1]
    assert hot, "no cluster got a second replica"
    for cid in hot:
        owners = cache.replica_owners(cid)
        assert len(owners) == len(slots[cid])  # replicas on distinct owners
    assert cache.stats.replica_loads >= 1
    assert cache.stats.swaps == len(loads)


def test_replica_copies_pay_transit_latency():
    """A replica staged for an already-visible cluster is not routable until
    transit_substages have passed (the primary stays visible throughout)."""
    cache = HotClusterCache(16, capacity=8, update_interval=1,
                            transit_substages=2, replication=2, num_owners=4)
    cache.tracker.record([3] * 20 + [5] * 10)
    cache.end_substage()  # refresh: primary + replica staged, all in transit
    hot = [c for c, s in cache._replica_slots.items() if len(s) > 1]
    assert hot
    cid = hot[0]
    # the cluster's primary load is itself still in transit: no holders yet
    assert cache.replica_owners(cid) == []
    assert cid not in cache.replicated_ids
    for _ in range(3):
        cache.end_substage()
    assert len(cache.replica_owners(cid)) == 2  # both copies now visible
    assert cid in cache.replicated_ids


def test_hotcache_shared_tracker_supersedes_local_ranking():
    shared = PopularityTracker(8)
    shared.record([6] * 50)
    cache = HotClusterCache(8, capacity=2, update_interval=1,
                            transit_substages=0, shared_tracker=shared)
    cache.tracker.record([1] * 50)  # local access EMA says 1 is hot
    cache.end_substage()
    assert 6 in cache._resident  # but the shared histogram won
    assert 1 not in cache._resident


# -------------------------------------------------------- scheduler: gating


def test_crossreq_disabled_by_default_and_bit_stable(small_index, embedder):
    s, _, m = _serve(small_index, embedder, crossreq=False, n=20)
    assert s.sched.crossreq is None
    assert s.sched.dispatcher.tracker is None
    assert m.global_cache_answers == m.dedup_fanout == m.replica_routes == 0
    assert s.crossreq_report() == {}
    # determinism: the identical run reproduces latencies exactly
    s2, _, m2 = _serve(small_index, embedder, crossreq=False, n=20)
    assert m.latencies_us == m2.latencies_us


def test_crossreq_zero_knobs_equal_default(small_index, embedder):
    _, _, m0 = _serve(small_index, embedder, crossreq=False, n=20)
    _, _, m1 = _serve(small_index, embedder, crossreq=True, n=20,
                      global_cache_size=0, dedup_threshold=0.0,
                      replication_factor=1)
    assert m0.latencies_us == m1.latencies_us


# ----------------------------------------------------- scheduler: enabled


def test_crossreq_serves_duplicates_faster(small_index, embedder):
    _, _, m0 = _serve(small_index, embedder, crossreq=False)
    _, _, m1 = _serve(small_index, embedder, crossreq=True)
    assert m1.finished == m0.finished == 40
    assert m1.dedup_fanout > 0
    p0 = m0.summary()["p50_latency_ms"]
    p1 = m1.summary()["p50_latency_ms"]
    assert p0 / p1 >= 1.2, f"crossreq speedup only {p0 / p1:.2f}x"


def test_exact_fusion_identical_to_independent_search(small_index, embedder):
    """Acceptance: fused-group answers == independently executed searches
    for exact duplicates (lossless settings isolate the fusion path)."""
    cfg = SchedulerConfig.preset(
        "hedra", nprobe=12, topk=5, num_ret_workers=2,
        enable_cache_answer=False, early_term_mode="lossless",
        dedup_threshold=1.0)
    demb = DuplicateTrafficEmbedder(embedder, dup_ratio=0.7, pool_size=2)
    be = SimBackend(small_index, demb, cost_model=RET_BOUND)
    s = Server(small_index, demb, backend=be, config=cfg)
    for i, t in enumerate(poisson_arrivals(300.0, 16, seed=7)):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=t)
    m = s.run()
    assert m.finished == 16
    assert m.dedup_fanout > 0
    for r in s.sched.done:
        qv = demb.embed_query(r.request_id, 0)
        _, ref = small_index.search(qv[None], nprobe=12, k=5)
        got = r.state["docs"]
        assert got == [int(x) for x in ref[0][: len(got)]]


def test_near_fusion_tolerance_bounded(small_index, embedder):
    """Near-duplicate fan-out answers are within the triangle bound of the
    subscriber's own reference search."""
    s, demb, m = _serve(small_index, embedder, dup=0.6, near_jitter=0.05,
                        dedup_threshold=0.9, n=32, rate=150.0)
    assert m.finished == 32
    assert m.dedup_near > 0
    checked = 0
    for r in s.sched.done:
        if not demb.is_duplicate(r.request_id):
            continue
        out = r.state.get("docs")
        if not out:
            continue
        qv = demb.embed_query(r.request_id, 0)
        dref, _ = small_index.search(qv[None], nprobe=16, k=5)
        ref_kth = float(np.sqrt(max(dref[0][min(len(out), 5) - 1], 0.0)))
        # any fused/cached answer comes from a query within the dedup ball;
        # returned docs' true distances obey d <= d_ref_k + 2 * delta
        canon = demb.base.embed_query(demb.canonical_id(r.request_id), 0)
        delta = float(np.linalg.norm(qv - canon)) + 0.35  # ball + answer slack
        rows = np.nonzero(np.isin(small_index.ids, out))[0]
        true_d = np.linalg.norm(small_index.flat[rows] - qv[None, :], axis=1)
        assert float(true_d.max()) <= ref_kth + 2.0 * delta + 1e-3
        checked += 1
    assert checked > 0


def test_global_cache_answers_repeat_queries(small_index, embedder):
    _, _, m = _serve(small_index, embedder, dup=0.6, rate=25.0, n=40,
                     dedup_threshold=0.0)  # isolate the global cache
    assert m.global_cache_answers > 0
    assert m.dedup_fanout == 0
    summ = m.summary()
    assert summ["global_cache_answers"] == m.global_cache_answers


def test_replication_with_hybrid_cache(small_index, embedder):
    hyb = HybridRetrievalEngine(small_index, cache_capacity=12,
                                update_interval=10, transit_substages=1,
                                kernel_impl="ref")
    s, _, m = _serve(small_index, embedder, nw=4, hybrid=hyb, n=40)
    assert m.finished == 40
    assert hyb.cache.replication == 2  # coordinator attached replication
    assert hyb.cache.shared_tracker is s.sched.crossreq.tracker
    st = hyb.stats()
    assert st["replica_loads"] > 0
    assert m.cache_stats["replica_loads"] == st["replica_loads"]
    summ = m.summary()
    assert summ["cache_replica_loads"] == st["replica_loads"]
    rep = s.crossreq_report()
    assert "dedup" in rep and "global_cache" in rep


def test_all_modes_complete_with_crossreq(small_index, embedder):
    for mode in ["sequential", "async", "hedra"]:
        demb = DuplicateTrafficEmbedder(embedder, dup_ratio=0.5, pool_size=3)
        be = SimBackend(small_index, demb, cost_model=RET_BOUND)
        s = Server(small_index, demb, mode=mode, backend=be, nprobe=12,
                   topk=5, **CROSSREQ)
        for i, t in enumerate(poisson_arrivals(20.0, 12, seed=3)):
            s.add_request(f"q{i}", workflows.build(NAMES[i % len(NAMES)]),
                          arrival_us=t)
        m = s.run()
        assert m.finished == 12, mode


# ------------------------------------------------- single-source bookkeeping


def test_metrics_mirror_dispatcher_completed_us(small_index, embedder):
    s, _, m = _serve(small_index, embedder, crossreq=False, nw=3, n=20)
    rep = s.sched.dispatcher.report()
    assert m.ret_busy_per_worker == rep["completed_us"]
    # everything dispatched also completed (run drained)
    assert rep["busy_us"] == pytest.approx(rep["completed_us"])
