"""Hypothesis property test: plan executor == reference IVF search."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.retrieval.plan import plan_search  # noqa: E402

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@given(
    k=st.integers(1, 16),
    nprobe=st.integers(1, 48),
    n_q=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_search_plan_equals_reference_search(small_index, k, nprobe, n_q, seed):
    """Property: plan-based search == reference ``IVFIndex.search`` for any
    (nprobe, k, query batch): identical ids, distances within 1e-4."""
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((n_q, small_index.dim)).astype(np.float32)
    D, I = small_index.search(q, nprobe, k)
    D2, I2 = plan_search(small_index, q, nprobe, k)
    np.testing.assert_array_equal(I2, I)
    finite = np.isfinite(D)
    np.testing.assert_allclose(D2[finite], D[finite], rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.isfinite(D2), finite)
