import os
import sys

# smoke tests must see exactly 1 device (the dry-run sets its own flags in a
# separate process); make sure nothing leaks in — unless the run *asks* for
# a forced multi-device host platform (REPRO_KEEP_XLA_FLAGS=1, used by CI to
# exercise the sharded all-gather/merge paths with real shards)
if os.environ.get("REPRO_KEEP_XLA_FLAGS") != "1":
    os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_corpus():
    from repro.retrieval import CorpusConfig, make_corpus

    cfg = CorpusConfig(n_docs=12000, dim=48, n_topics=96, zipf_alpha=1.2, seed=0)
    return make_corpus(cfg)


@pytest.fixture(scope="session")
def small_index(small_corpus):
    from repro.retrieval import IVFIndex

    docs, _, _ = small_corpus
    return IVFIndex.build(docs, 48, iters=4)


@pytest.fixture(scope="session")
def embedder(small_corpus):
    from repro.retrieval import SyntheticEmbedder

    _, _, topics = small_corpus
    return SyntheticEmbedder(topics)
