"""Property suite for fault-tolerant serving: random FaultPlans over random
workflow mixes, modes, and sharding settings must never hang a request —
every submitted request ends finished, shed, or degraded-complete — and the
whole chaos run is deterministic (same seed, same per-request event traces).

Runs under hypothesis when installed (CI installs it explicitly); otherwise
falls back to a fixed seeded sweep of the same properties so the suite never
silently skips."""
import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.faults import FaultPlan

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # local envs without hypothesis: seeded sweep instead
    HAVE_HYPOTHESIS = False

RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)
FALLBACK_SEEDS = list(range(24))
NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp",
         "rerank", "multiquery", "hybrid", "compress", "pipeline"]
MODES = ["hedra", "async", "sequential"]


def _property(n_examples):
    """Decorator: hypothesis-driven seeds when available, a fixed
    parametrized sweep otherwise.  The wrapped test takes ``seed`` last."""
    if HAVE_HYPOTHESIS:
        return lambda fn: settings(
            max_examples=n_examples, deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )(given(seed=st.integers(0, 2**32 - 1))(fn))
    return lambda fn: pytest.mark.parametrize(
        "seed", FALLBACK_SEEDS[:n_examples])(fn)


def _chaos_run(index, emb, seed):
    """One randomized chaos serve.  Returns (server, metrics, n_submitted)."""
    rng = np.random.default_rng(seed)
    nw = int(rng.integers(1, 5))
    mode = MODES[int(rng.integers(0, len(MODES)))]
    sharding = bool(rng.integers(0, 2)) and nw > 1
    n = int(rng.integers(4, 9))
    plan = FaultPlan.random(
        int(rng.integers(0, 2**31)), nw, 1_200_000.0,
        crash_frac=float(rng.uniform(0.0, 0.5)),
        stall_rate=float(rng.uniform(0.0, 1.0)),
        stall_factor=float(rng.uniform(2.0, 10.0)),
        transient_prob=float(rng.uniform(0.0, 0.3)))
    be = SimBackend(index, emb, cost_model=RET_HEAVY, seed=0,
                    fault_plan=plan)
    s = Server(index, emb, mode=mode, backend=be, nprobe=12, topk=5,
               num_ret_workers=nw, index_sharding=sharding,
               retry_backoff_us=float(rng.uniform(2_000.0, 40_000.0)),
               retry_budget=int(rng.integers(1, 4)),
               hedge_suspect=bool(rng.integers(0, 2)))
    for i in range(n):
        s.add_request(f"q{i}", workflows.build(
            NAMES[int(rng.integers(0, len(NAMES)))]),
            arrival_us=float(rng.uniform(0.0, 60_000.0) + i * 2_000.0))
    m = s.run()
    return s, m, n


@_property(18)
def test_every_request_terminates_under_chaos(small_index, embedder, seed):
    """Liveness: no combination of crashes, stalls, and transient failures
    may strand a request — the run drains, nothing stays active or pending,
    and finished + shed covers every submission (degraded completions are
    finished requests, counted once)."""
    s, m, n = _chaos_run(small_index, embedder, seed)
    assert s.sched.active == []
    assert s.sched.pending == []
    assert m.finished + m.shed == n
    assert m.degraded_completions <= m.finished
    assert m.hedged_wins <= m.hedged_dispatches
    # every done request carries a terminal finish event
    for r in s.sched.done:
        assert r.finished
        if r.state.get("_degraded"):
            assert any(e == "degraded" for _, e, _ in r.events)


@_property(8)
def test_same_seed_identical_event_traces(small_index, embedder, seed):
    """Determinism: replaying the same chaos seed reproduces every
    per-request event trace bit-for-bit (and therefore every counter)."""
    runs = []
    for _ in range(2):
        s, m, _ = _chaos_run(small_index, embedder, seed)
        fp = {r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
              for r in s.sched.done}
        runs.append((fp, m.summary()))
    assert runs[0][0] == runs[1][0]
    assert runs[0][1] == runs[1][1]
