"""IVF index, k-means, hot cache, hybrid engine."""
import numpy as np
import pytest

from repro.retrieval import (
    ClusterCostModel,
    HotClusterCache,
    HybridRetrievalEngine,
    IVFIndex,
    TopK,
    plan_memory_split,
)


def test_kmeans_assignment_is_argmin(small_corpus):
    import jax

    from repro.retrieval.kmeans import assign_clusters, kmeans

    docs, _, _ = small_corpus
    cent, asn = kmeans(jax.random.PRNGKey(0), docs[:4000], 16, iters=3)
    cent, asn = np.asarray(cent), np.asarray(asn)
    d = ((docs[:4000, None, :] - cent[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(asn, d.argmin(1))


def test_ivf_recall_vs_bruteforce(small_index, small_corpus):
    docs, _, _ = small_corpus
    rng = np.random.default_rng(1)
    q = docs[rng.choice(len(docs), 24)] + 0.03 * rng.standard_normal((24, docs.shape[1])).astype(np.float32)
    D, I = small_index.search(q, nprobe=12, k=10)
    bf = (q**2).sum(-1, keepdims=True) - 2 * q @ docs.T + (docs**2).sum(-1)[None]
    bf_top = np.argsort(bf, axis=1)[:, :10]
    recall = np.mean([len(set(I[i]) & set(bf_top[i])) / 10 for i in range(24)])
    assert recall > 0.55, f"recall {recall}"
    # full-probe search == brute force
    D2, I2 = small_index.search(q[:4], nprobe=small_index.n_clusters, k=5)
    np.testing.assert_array_equal(I2, bf_top[:4, :5])


def test_ivf_full_probe_distances_sorted(small_index, small_corpus):
    docs, _, _ = small_corpus
    D, I = small_index.search(docs[:3], nprobe=8, k=6)
    assert np.all(np.diff(D, axis=1) >= -1e-6)
    assert np.all(I >= 0)


def test_topk_merge_properties():
    rng = np.random.default_rng(2)
    tk = TopK.empty(5)
    seen = {}
    for _ in range(6):
        d = rng.random(7).astype(np.float32)
        ids = rng.choice(10_000, 7, replace=False)
        for dist, i in zip(d, ids):
            seen[i] = min(dist, seen.get(i, np.inf))
        tk = tk.merge(d, ids)
    expect = sorted(seen.items(), key=lambda kv: kv[1])[:5]
    np.testing.assert_allclose(tk.dists, [v for _, v in expect], rtol=1e-6)
    assert list(tk.ids) == [k for k, _ in expect]


def test_doc_cluster_roundtrip(small_index):
    rng = np.random.default_rng(3)
    docs = rng.choice(small_index.ids, 64, replace=False)
    cl = small_index.doc_cluster(docs)
    for d, c in zip(docs, cl):
        lo, hi = small_index.offsets[c], small_index.offsets[c + 1]
        assert d in small_index.ids[lo:hi]


def test_cluster_tensor_padding(small_index):
    cids = [0, 1, 5]
    slab, valid, ids = small_index.cluster_tensor(cids, pad_to=128)
    assert slab.shape[1] % 128 == 0
    for j, c in enumerate(cids):
        assert valid[j] == small_index.cluster_size(c)
        assert (ids[j, valid[j]:] == -1).all()
        np.testing.assert_array_equal(
            slab[j, : valid[j]],
            small_index.flat[small_index.offsets[c]: small_index.offsets[c + 1]],
        )


def test_hot_cache_transit_and_update():
    cache = HotClusterCache(32, capacity=4, update_interval=2, transit_substages=2)
    for _ in range(6):
        for c in [1, 2, 3, 4]:
            cache.lookup(c)
        cache.end_substage()
    # after updates, hot clusters become resident (transit respected)
    assert set(cache.resident_ids) <= {1, 2, 3, 4}
    assert len(cache.resident_ids) > 0
    assert cache.stats.swaps >= 4
    # cold cluster never resident
    assert not cache.is_resident(31)


def test_hot_cache_adapts_to_shift():
    cache = HotClusterCache(16, capacity=2, update_interval=2,
                            transit_substages=0, decay=0.5)
    for _ in range(8):
        cache.lookup(0); cache.lookup(1); cache.end_substage()
    assert set(cache.resident_ids) == {0, 1}
    for _ in range(16):
        cache.lookup(7); cache.lookup(9); cache.end_substage()
    assert set(cache.resident_ids) == {7, 9}


def test_eq2_memory_split():
    # generation throughput saturates at 2 GB KV; retrieval constant
    t_gen = lambda kv, rps: min(kv / 1e9, 2.0)
    t_ret = lambda rps: 1.5
    kv, cache = plan_memory_split(
        4_000_000_000, t_gen=t_gen, t_ret=t_ret, rps_g=1, rps_r=1,
        kv_candidates=[1_000_000_000, 1_500_000_000, 2_000_000_000, 3_000_000_000],
    )
    assert kv == 1_500_000_000  # smallest KV whose T_G >= T_R
    assert cache == 4_000_000_000 - kv


def test_hybrid_engine_matches_host_path(small_index, small_corpus):
    docs, _, _ = small_corpus
    rng = np.random.default_rng(4)
    q = docs[rng.choice(len(docs), 8)]
    eng = HybridRetrievalEngine(small_index, cache_capacity=8,
                                update_interval=1, transit_substages=0,
                                kernel_impl="ref")
    # warm the cache on some clusters
    probes = small_index.probe_order(q, 4)
    for _ in range(4):
        work = [(q[i], int(probes[i, j]), TopK.empty(5))
                for i in range(8) for j in range(2)]
        res, _ = eng.search_substage(work)
    # device-path results must equal the host path exactly
    work = [(q[i], int(probes[i, 0]), TopK.empty(5)) for i in range(8)]
    res, timing = eng.search_substage(work)
    ref = small_index.search_cluster_batch(
        [(q[i], int(probes[i, 0]), TopK.empty(5)) for i in range(8)])
    for r, rr in zip(res, ref):
        np.testing.assert_array_equal(r.ids, rr.ids)
        np.testing.assert_allclose(r.dists, rr.dists, rtol=1e-4, atol=1e-5)
    assert timing.n_device_items > 0  # cache actually used


def test_cost_model_monotone(small_index):
    cm = ClusterCostModel.calibrate(small_index, n_samples=8)
    assert cm.per_vector_us > 0
    assert cm.cost_us(1000) > cm.cost_us(10)
