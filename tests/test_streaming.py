"""Streaming serving runtime: batch/streaming fingerprint equivalence,
heap-based admission, admission-control shedding, windowed metrics, mix
specs, and the atomic JSONL journal."""
import json
import os

import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.core.runtime import RequestContext
from repro.core.wavefront import Metrics, SchedulerConfig, WavefrontScheduler
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving import dispatch
from repro.serving.workload import (
    MIXES,
    MixSpec,
    WorkloadProfile,
    poisson_arrivals,
)

NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp"]
RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)


def _server(index, emb, mode="hedra", nw=1, workload=None, **cfg):
    be = SimBackend(index, emb, cost_model=RET_HEAVY, seed=0)
    return Server(index, emb, mode=mode, backend=be, workload=workload,
                  nprobe=12, topk=5, num_ret_workers=nw, **cfg)


def _fingerprints(server) -> dict:
    """request_id -> full event log (times, events, payload reprs)."""
    return {
        r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
        for r in server.sched.done
    }


# ------------------------------------------------- batch/streaming identity


@pytest.mark.parametrize("mode", ["hedra", "async", "sequential"])
@pytest.mark.parametrize("nw", [1, 4])
def test_submit_matches_preloaded_fingerprints(small_index, embedder, mode, nw):
    """Mid-run submit() at the same arrival times must produce per-request
    event fingerprints identical to the pre-loaded batch path, across
    modes and worker counts."""
    arr = poisson_arrivals(8.0, 20, seed=5)
    s1 = _server(small_index, embedder, mode, nw)
    for i, t in enumerate(arr):
        s1.add_request(f"q{i}", workflows.build(NAMES[i % 5]), arrival_us=t)
    m1 = s1.run()
    s2 = _server(small_index, embedder, mode, nw)
    for i, t in enumerate(arr):
        s2.step(float(t))
        s2.submit(f"q{i}", NAMES[i % 5], arrival_us=float(t))
    m2 = s2.run()
    assert m1.finished == m2.finished == 20
    assert _fingerprints(s1) == _fingerprints(s2)


def test_serve_stream_matches_preloaded(small_index, embedder):
    """Server.serve over tuple items == pre-loaded batch run."""
    arr = poisson_arrivals(6.0, 15, seed=7)
    s1 = _server(small_index, embedder)
    for i, t in enumerate(arr):
        s1.add_request(f"q{i}", workflows.build(NAMES[i % 5]), arrival_us=t)
    s1.run()
    s2 = _server(small_index, embedder)
    s2.serve((float(t), f"q{i}", NAMES[i % 5]) for i, t in enumerate(arr))
    assert _fingerprints(s1) == _fingerprints(s2)


def test_submit_at_exact_event_time_matches_preloaded(small_index, embedder):
    """A mid-run submission whose arrival coincides *exactly* with a
    completion event must still join the assembly cycle it would have
    joined pre-loaded: step() stops at the horizon before the next
    admission+assembly phase.  (Poisson arrivals never produce exact ties,
    so this corner needs its own construction.)"""
    probe = _server(small_index, embedder)
    for i in range(3):
        probe.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=0.0)
    probe.run()
    times = sorted({t for r in probe.sched.done
                    for t, _, _ in r.events if t > 0})
    tie = times[len(times) // 2]  # an actual event instant of the run
    s1 = _server(small_index, embedder)
    for i in range(3):
        s1.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=0.0)
    s1.add_request("q3", workflows.build("one-shot"), arrival_us=tie)
    s1.run()
    s2 = _server(small_index, embedder)
    for i in range(3):
        s2.submit(f"q{i}", "one-shot", arrival_us=0.0)
    s2.step(tie)
    s2.submit("q3", "one-shot", arrival_us=tie)
    s2.run()
    assert _fingerprints(s1) == _fingerprints(s2)


def test_tied_submissions_match_preloaded(small_index, embedder):
    """Several stream items carrying the *same* arrival timestamp must be
    admitted and assembled together, as the batch path admits equal
    arrivals in one cycle: step() at an already-reached horizon defers
    admission instead of cycling between the tied submissions."""
    arrivals = [1000.0, 5000.0, 5000.0, 5000.0, 9000.0]
    s1 = _server(small_index, embedder, nw=2)
    for i, t in enumerate(arrivals):
        s1.add_request(f"q{i}", workflows.build(NAMES[i % 5]), arrival_us=t)
    s1.run()
    s2 = _server(small_index, embedder, nw=2)
    s2.serve((t, f"q{i}", NAMES[i % 5]) for i, t in enumerate(arrivals))
    assert _fingerprints(s1) == _fingerprints(s2)


def test_step_leaves_inflight_work_and_resumes(small_index, embedder):
    """step() to a horizon must not complete jobs ending after it; run()
    afterwards finishes them with the same results as one-shot run()."""
    s = _server(small_index, embedder)
    for i in range(6):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=0.0)
    s.step(1.0)  # admits + dispatches, nothing can finish this early
    assert s.sched.now == 1.0
    assert s.sched.metrics.finished == 0
    assert s.sched.active  # in flight
    m = s.run()
    assert m.finished == 6


# ----------------------------------------------------------- heap admission


def test_add_request_order_invariant(small_index, embedder):
    """The arrival heap admits in (arrival_us, request_id) order no matter
    the insertion order — results match the sorted-insertion run."""
    arr = poisson_arrivals(8.0, 12, seed=3)
    order = np.random.default_rng(0).permutation(12)
    s1 = _server(small_index, embedder)
    reqs = {}
    for i, t in enumerate(arr):  # build all so request ids match
        reqs[i] = (f"q{i}", workflows.build(NAMES[i % 5]), float(t))
    for i in range(12):
        s1.add_request(*reqs[i])
    s1.run()
    s2 = _server(small_index, embedder)
    built = {}
    for i in range(12):
        built[i] = s2._build_request(reqs[i][0], reqs[i][1], reqs[i][2])
    for i in order:  # shuffled insertion of identical request objects
        s2.sched.add_request(built[int(i)])
    s2.run()
    assert _fingerprints(s1) == _fingerprints(s2)


def test_pending_property_is_arrival_ordered(small_index, embedder):
    s = _server(small_index, embedder)
    for i, t in enumerate([30.0, 10.0, 20.0]):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=t)
    assert [r.arrival_us for r in s.sched.pending] == [10.0, 20.0, 30.0]


# ------------------------------------------------------- admission control


def test_bounded_queue_sheds_and_is_deterministic(small_index, embedder):
    mix = MIXES["balanced"]
    runs = []
    for _ in range(2):
        s = _server(small_index, embedder, workload=mix.profile(),
                    max_pending=4, admission_control=True)
        m = s.serve(mix.sample(40, 200.0))
        shed_ids = sorted(set(range(40))
                          - {r.request_id for r in s.sched.done})
        runs.append((shed_ids, m.shed_queue_full, m.shed_infeasible,
                     _fingerprints(s)))
        assert m.shed > 0
        assert m.finished + m.shed == 40
        assert m.submitted == m.finished
    assert runs[0] == runs[1]  # fixed seed -> identical shed set + events


def test_infeasible_deadline_shed(small_index, embedder):
    """A request whose SLO cannot cover even its isolated service lower
    bound is rejected at submit time."""
    wl = WorkloadProfile(slo_class_us={"multistep": 1.0})  # 1 us deadline
    s = _server(small_index, embedder, workload=wl, admission_control=True)
    assert s.submit("q0", "multistep", arrival_us=0.0) is None
    assert s.sched.metrics.shed_infeasible == 1
    # a feasible one still gets in
    assert s.submit("q1", "one-shot", arrival_us=0.0) is not None
    m = s.run()
    assert m.finished == 1 and m.submitted == 1


def test_admission_disabled_admits_everything(small_index, embedder):
    s = _server(small_index, embedder)
    assert s.sched.admission is None
    for i in range(30):
        assert s.add_request(f"q{i}", workflows.build("one-shot")) == i
    assert s.run().finished == 30


def test_admission_lower_bound_scales_with_graph():
    cfg = SchedulerConfig.preset("hedra", admission_control=True)
    from repro.core.substage import TimeBudget

    ac = dispatch.AdmissionController(cfg, TimeBudget(), ClusterCostModel(),
                                      np.array([100, 200, 300]))
    one = RequestContext(0, workflows.build("one-shot"), {})
    multi = RequestContext(1, workflows.build("multistep"), {})
    assert ac.lower_bound_us(multi) > ac.lower_bound_us(one) > 0.0


def test_submit_rejects_stale_arrival(small_index, embedder):
    """The virtual clock cannot honor a past arrival stamp; silently
    rewriting it would corrupt latency/SLO accounting."""
    s = _server(small_index, embedder)
    s.add_request("q0", workflows.build("one-shot"), arrival_us=0.0)
    s.step(1000.0)
    with pytest.raises(ValueError, match="in the past"):
        s.submit("late", "one-shot", arrival_us=500.0)
    assert s.submit("ok", "one-shot", arrival_us=1000.0) is not None


def test_future_arrivals_not_shed_by_present_load(small_index, embedder):
    """Load-based gates (queue bound, in-flight backlog) only judge
    requests due *now*: a pre-loaded batch spread over future arrival
    times must not be shed against load that will have drained by then."""
    s = _server(small_index, embedder, max_pending=4, admission_control=True)
    # 20 future-dated arrivals, far more than max_pending, spread out at a
    # trivially sustainable rate: all must be admitted
    for i in range(20):
        rid = s.add_request(f"q{i}", workflows.build("one-shot"),
                            arrival_us=1.0 + i * 1e6)
        assert rid is not None
    m = s.run()
    assert m.finished == 20 and m.shed == 0


# ------------------------------------------------------ per-class SLO tiers


def test_slo_class_tiers_applied(small_index, embedder):
    wl = WorkloadProfile(slo_us_mean=9e6,
                         slo_class_us={"one-shot": 1e6, "irg": 5e6})
    s = _server(small_index, embedder, workload=wl)
    a = s.add_request("a", workflows.build("one-shot"))
    b = s.add_request("b", workflows.build("irg"))
    c = s.add_request("c", workflows.build("hyde"))  # no tier -> sampled
    by_id = {r.request_id: r
             for r in s.sched.pending}
    assert by_id[a].slo_us == 1e6
    assert by_id[b].slo_us == 5e6
    assert by_id[c].slo_us == 9e6


def test_mix_spec_sampling_deterministic_and_weighted():
    mix = MixSpec("m", weights={"one-shot": 3.0, "irg": 1.0},
                  slo_tiers_us={"one-shot": 1e6})
    a = mix.sample(200, 10.0)
    b = mix.sample(200, 10.0)
    assert [(x.arrival_us, x.workflow) for x in a] == \
        [(x.arrival_us, x.workflow) for x in b]
    assert all(a[i].arrival_us < a[i + 1].arrival_us for i in range(199))
    counts = {n: sum(1 for x in a if x.workflow == n)
              for n in ("one-shot", "irg")}
    assert counts["one-shot"] > counts["irg"]
    prof = mix.profile()
    assert prof.slo_class_us == {"one-shot": 1e6}
    with pytest.raises(ValueError):
        MixSpec("empty").sample(5, 1.0)


# --------------------------------------------------------- windowed metrics


def test_window_summary_excludes_idle_time():
    m = Metrics()
    # three finishes between t=10s and t=12s, then the run idles to 100s
    for t, lat, ok in [(10e6, 1e5, True), (11e6, 2e5, True),
                       (12e6, 9e6, False)]:
        m.finish_log.append((t, lat, ok))
        m.latencies_us.append(lat)
        m.finished += 1
    m.sim_time_us = 100e6
    s = m.summary()
    assert s["throughput_rps"] == pytest.approx(3 / 100.0)
    assert s["goodput_rps"] == pytest.approx(2 / 100.0)
    # steady-state window [first finish, last finish] ignores the idle tail
    assert s["steady_throughput_rps"] == pytest.approx(3 / 2.0, rel=1e-6)
    assert s["steady_goodput_rps"] == pytest.approx(2 / 2.0, rel=1e-6)
    w = m.window_summary(10.5e6, 12.5e6)
    assert w["finished"] == 2
    assert w["finished_under_slo"] == 1
    assert w["goodput_rps"] == pytest.approx(1 / 2.0)
    assert w["p50_latency_ms"] > 0


def test_goodput_timeline_slides():
    m = Metrics()
    for t in range(10):  # one good finish per second from t=0..9s
        m.finish_log.append((t * 1e6, 1e5, True))
    tl = m.goodput_timeline(window_us=2e6, step_us=1e6)
    assert len(tl) >= 8
    mid = [g for _, g in tl[1:-1]]
    assert all(g == pytest.approx(1.0) for g in mid)
    # a finish span shorter than the window still yields one sample
    short = Metrics()
    short.finish_log = [(0.0, 1e5, True), (0.9e6, 1e5, True)]
    tl2 = short.goodput_timeline(window_us=2e6)
    assert len(tl2) >= 1
    assert tl2[0][1] == pytest.approx(2 / 2.0)


def test_steady_rates_fall_back_on_degenerate_span():
    """All finishes at one event instant (e.g. one generation batch
    completing together) must not divide by a ~0 window."""
    m = Metrics()
    for _ in range(2):
        m.finish_log.append((1e6, 5e5, True))
        m.latencies_us.append(5e5)
        m.finished += 1
    m.sim_time_us = 10e6
    s = m.summary()
    assert s["steady_throughput_rps"] == pytest.approx(s["throughput_rps"])
    assert s["steady_goodput_rps"] == pytest.approx(s["goodput_rps"])


def test_redated_pending_request_admitted_at_live_arrival(small_index, embedder):
    """Mutating a queued request's arrival_us (journal-recovery deferral
    pattern) must defer its admission — the heap re-keys lazily instead of
    admitting at the stale stamp."""
    s = _server(small_index, embedder)
    s.add_request("q0", workflows.build("one-shot"), arrival_us=0.0)
    s.add_request("q1", workflows.build("one-shot"), arrival_us=0.0)
    deferred = s.sched.pending[1]
    deferred.arrival_us = 5e6  # re-date after queuing
    m = s.run()
    assert m.finished == 2
    late = next(r for r in s.sched.done if r.request_id == deferred.request_id)
    assert late.events[0][0] >= 5e6  # first event at the live arrival
    assert all(lat >= 0 for lat in m.latencies_us)


def test_batch_summary_fields_unchanged(small_index, embedder):
    """Batch runs keep the legacy fields; the new ones coexist."""
    s = _server(small_index, embedder)
    for i in range(8):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=0.0)
    summ = s.run().summary()
    for k in ("finished", "avg_latency_ms", "throughput_rps", "gen_util",
              "slo_violations"):
        assert k in summ
    assert summ["submitted"] == 8
    assert summ["shed"] == 0
    assert summ["steady_throughput_rps"] >= summ["throughput_rps"]


# ----------------------------------------------------------- atomic journal


def test_journal_is_jsonl_and_atomic(tmp_path, small_index, embedder):
    p = str(tmp_path / "journal.jsonl")
    s = _server(small_index, embedder, journal_path=p)
    for i in range(4):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=0.0)
    s.run()
    # no stray temp files left behind by the write-then-rename
    assert os.listdir(tmp_path) == ["journal.jsonl"]
    with open(p) as f:
        lines = [l for l in f.read().splitlines() if l.strip()]
    assert len(lines) == 4
    assert all(json.loads(l)["finished"] for l in lines)
    assert Server.replay_unfinished(p) == []


def test_replay_tolerates_partial_trailing_row(tmp_path, small_index, embedder):
    p = str(tmp_path / "journal.jsonl")
    s = _server(small_index, embedder, journal_path=p)
    for i in range(3):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=0.0)
    s.run()
    with open(p) as f:
        whole = f.read()
    # crash mid-append: the last row is cut off half way
    with open(p, "w") as f:
        f.write(whole[: whole.rfind('"request_id"') + 5])
    rows = Server.read_journal(p)
    assert len(rows) == 2  # intact prefix survives, partial tail dropped
    # a partial row in the *middle* is corruption, not a crash tail
    with open(p, "w") as f:
        lines = whole.splitlines()
        f.write(lines[0][:20] + "\n" + lines[1] + "\n")
    with pytest.raises(json.JSONDecodeError):
        Server.read_journal(p)


def test_read_journal_accepts_legacy_array(tmp_path):
    p = str(tmp_path / "legacy.json")
    rows = [{"request_id": 0, "finished": True},
            {"request_id": 1, "finished": False}]
    with open(p, "w") as f:
        json.dump(rows, f)
    assert Server.read_journal(p) == rows
    assert [r["request_id"] for r in Server.replay_unfinished(p)] == [1]
