"""Hypothesis property-based tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.analysis.hlo import collective_stats
from repro.core.substage import TimeBudget
from repro.retrieval.ivf import TopK

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@given(
    st.lists(
        st.tuples(
            st.lists(st.floats(0, 1e6, allow_nan=False, width=32), min_size=1, max_size=12),
            st.integers(0, 10_000),
        ),
        min_size=1,
        max_size=8,
    ),
    st.integers(1, 8),
)
def test_topk_merge_equals_global_sort(batches, k):
    """Any merge order of candidate batches == global top-k of the union."""
    tk = TopK.empty(k)
    all_d, all_i = [], []
    base = 0
    for dists, seed in batches:
        ids = np.arange(base, base + len(dists))  # unique ids
        base += len(dists)
        tk = tk.merge(np.asarray(dists, np.float32), ids)
        all_d.extend(dists)
        all_i.extend(ids)
    order = np.argsort(np.asarray(all_d, np.float32), kind="stable")[:k]
    expect_d = np.asarray(all_d, np.float32)[order]
    got = tk.dists[tk.ids >= 0]
    np.testing.assert_allclose(got, expect_d[: len(got)], rtol=1e-6)


@given(st.floats(1.0, 1e7), st.floats(0.1, 1e5))
def test_eq1_budget_is_argmax(t_ret, beta):
    """mb* = sqrt(2 t beta) maximises the corrected Delta_l objective."""
    b = TimeBudget(beta_us=beta, t_retrieval_us=t_ret,
                   min_budget_us=0.0, max_budget_us=1e12)
    mb = b.mb_us
    tol = 1e-9 * max(1.0, abs(b.delta_l(mb)), t_ret, beta)
    for factor in (0.5, 0.9, 1.1, 2.0):
        assert b.delta_l(mb) >= b.delta_l(mb * factor) - tol


@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 16))
def test_budget_monotone_in_inputs(t_scale, beta_scale, _):
    b1 = TimeBudget(beta_us=10.0 * beta_scale, t_retrieval_us=1000.0 * t_scale,
                    min_budget_us=0, max_budget_us=1e12)
    b2 = TimeBudget(beta_us=10.0 * beta_scale, t_retrieval_us=2000.0 * t_scale,
                    min_budget_us=0, max_budget_us=1e12)
    assert b2.mb_us >= b1.mb_us  # more retrieval work -> larger sub-stages


@given(
    st.lists(
        st.tuples(
            st.sampled_from(["all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute"]),
            st.sampled_from(["f32", "bf16", "s32"]),
            st.lists(st.integers(1, 64), min_size=1, max_size=3),
        ),
        min_size=0,
        max_size=10,
    )
)
def test_collective_parser_sums_operands(ops):
    """Parser must sum operand bytes exactly on synthetic HLO."""
    bytes_of = {"f32": 4, "bf16": 2, "s32": 4}
    lines = ["HloModule m", "ENTRY main {"]
    expect = 0
    for i, (op, dt, dims) in enumerate(ops):
        shape = f"{dt}[{','.join(map(str, dims))}]"
        n = int(np.prod(dims)) * bytes_of[dt]
        lines.append(f"  %p{i} = {shape} parameter({i})")
        lines.append(f"  %c{i} = {shape} {op}(%p{i}), replica_groups={{}}")
        expect += n
    lines.append("}")
    stats = collective_stats("\n".join(lines))
    assert stats.total_bytes == expect
    # bf16 correction only halves the f32 part
    f32_expected = sum(
        int(np.prod(d)) * 4 for op, dt, d in ops if dt == "f32"
    )
    assert stats.f32_bytes == f32_expected


@given(st.integers(2, 2048), st.integers(1, 32))
def test_moe_capacity_padding(T, k):
    from repro.configs import get_config
    from repro.models.layers import moe_capacity

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    C = moe_capacity(cfg, T)
    assert C % 8 == 0
    assert C * cfg.n_experts >= T * cfg.moe_top_k  # capacity_factor >= 1


@given(st.lists(st.integers(0, 47), min_size=1, max_size=40))
def test_access_tracker_top_is_sorted(accesses):
    from repro.retrieval.hotcache import AccessTracker

    tr = AccessTracker(48)
    tr.record(np.asarray(accesses))
    top = tr.top(8)
    freqs = tr.freq[top]
    assert (np.diff(freqs) <= 1e-12).all()
