"""Gradient compression: quantisation fidelity, error feedback, sharded sum."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.compression import (
    ErrorFeedback,
    _dequantize_blocks,
    _quantize_blocks,
    dcn_bytes_saved,
    quantization_residual,
)


def test_block_quantization_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    q, s, pad = _quantize_blocks(x, 256)
    y = _dequantize_blocks(q, s, pad, x.shape)
    # per-block absmax scaling: error <= scale/2 = absmax/254
    err = np.abs(np.asarray(x - y))
    assert err.max() <= float(np.abs(np.asarray(x)).max()) / 127.0


def test_error_feedback_accumulates_to_truth():
    """With error feedback, the *sum* of sent gradients converges to the sum
    of true gradients (the EF guarantee)."""
    rng = np.random.default_rng(1)
    true = [jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)
            for _ in range(20)]
    ef = ErrorFeedback.init(true[0])
    sent_total = jnp.zeros_like(true[0])
    true_total = jnp.zeros_like(true[0])
    for g in true:
        send, ef = ErrorFeedback.apply(g, ef)
        sent_total = sent_total + send
        true_total = true_total + g
    resid = np.abs(np.asarray(sent_total - true_total))
    # leftover is at most one quantisation step
    assert resid.max() <= float(np.abs(np.asarray(true_total)).max()) / 64.0


def test_dcn_bytes_saved_reports_gain():
    r = dcn_bytes_saved(1_000_000_000, n_pods=2)
    assert r["saving"] > 1.5


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.training.compression import compressed_psum_leaf

mesh = jax.make_mesh((2,), ("pod",))
rng = np.random.default_rng(2)
x = jnp.asarray(rng.standard_normal((2, 515)) * 0.02, jnp.float32)

f = shard_map(lambda v: compressed_psum_leaf(v[0], "pod"),
              mesh=mesh, in_specs=(P("pod", None),), out_specs=P(None),
              check_rep=False)
with mesh:
    got = f(x)
want = np.asarray(x).sum(0)
err = np.abs(np.asarray(got) - want).max()
tol = 2 * np.abs(np.asarray(x)).max() / 127.0
assert err <= tol, (err, tol)
print("OK")
"""


def test_compressed_psum_2pod_subprocess():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROC, src],
                       capture_output=True, text=True, timeout=300,
                       env={**os.environ, "XLA_FLAGS": ""})
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
