"""Sharding-rule validity (spec construction; no multi-device execution)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ALL_SHAPES, SHAPES_BY_NAME, shape_applicable
from repro.distributed import sharding as sh
from repro.launch import specs as S


class FakeMesh:
    """Minimal stand-in exposing shape/axis_names (no devices needed)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axes_of(spec):
    out = []
    for s in spec:
        if s is None:
            continue
        out.extend(s if isinstance(s, tuple) else (s,))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("layout", ["tp", "dp_only"])
def test_param_specs_divide_and_unique(arch, mesh, layout):
    cfg = get_config(arch)
    pshape = S.params_spec(cfg)

    def check(path, leaf):
        spec = sh.param_spec(cfg, mesh, path, leaf, layout)
        assert len(spec) == len(leaf.shape), (path, spec, leaf.shape)
        axes = _axes_of(spec)
        assert len(axes) == len(set(axes)), f"axis reused: {path} {spec}"
        for dim, s in zip(leaf.shape, spec):
            if s is None:
                continue
            size = int(np.prod([mesh.shape[a] for a in (s if isinstance(s, tuple) else (s,))]))
            assert dim % size == 0, f"{path}: dim {dim} not divisible by {s}"

    jax.tree_util.tree_map_with_path(check, pshape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES_BY_NAME))
def test_decode_state_specs_valid(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if shape.kind != "decode" or not shape_applicable(cfg, shape)[0]:
        pytest.skip("n/a")
    st = S.decode_state_spec(cfg, shape)

    def check(path, leaf):
        spec = sh.decode_state_spec(cfg, MULTI, shape.global_batch, path, leaf)
        assert len(spec) == len(leaf.shape), (path, spec, leaf.shape)
        axes = _axes_of(spec)
        assert len(axes) == len(set(axes)), f"axis reused: {path} {spec}"
        for dim, s in zip(leaf.shape, spec):
            if s is None:
                continue
            size = int(np.prod([MULTI.shape[a] for a in (s if isinstance(s, tuple) else (s,))]))
            assert dim % size == 0, f"{path}: dim {dim} % {size} != 0"

    jax.tree_util.tree_map_with_path(check, st)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_cells(arch):
    cfg = get_config(arch)
    for shape in ALL_SHAPES:
        if not shape_applicable(cfg, shape)[0]:
            continue
        ispec = S.input_specs(cfg, shape)
        assert "params" in ispec
        if shape.kind == "train":
            assert ispec["batch"]["tokens"].shape == (shape.global_batch, shape.seq_len)
        elif shape.kind == "decode":
            assert ispec["tokens"].shape == (shape.global_batch,)
            assert "state" in ispec


def test_layout_dp_only_drops_model_axis():
    cfg = get_config("qwen3-1.7b")
    pshape = S.params_spec(cfg)
    leaves = jax.tree_util.tree_flatten_with_path(pshape)[0]
    path, leaf = next((p, l) for p, l in leaves
                      if sh._path_names(p)[-1] == "wq")
    spec_tp = sh.param_spec(cfg, SINGLE, path, leaf, "tp")
    spec_dp = sh.param_spec(cfg, SINGLE, path, leaf, "dp_only")
    assert "model" in _axes_of(spec_tp)
    # dp_only uses model axis only as part of the fsdp pool
    for s in spec_dp:
        if isinstance(s, tuple):
            assert set(s) <= {"data", "model"}


def test_mesh_construction_functions_importable():
    # importing mesh.py must not touch device state; host mesh works on 1 CPU
    from repro.launch.mesh import make_host_mesh

    m = make_host_mesh()
    assert "data" in m.axis_names
