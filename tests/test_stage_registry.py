"""Stage-registry behaviour (core/stages.py): golden fingerprint identity
on the paper five, registry dispatch semantics, graph validation, the new
stage workflows end-to-end, and journal-backed crash recovery."""
import json
import os

import numpy as np
import pytest

from repro import workflows
from repro.core import stages
from repro.core.backends import SimBackend
from repro.core.ragraph import END, START, RAGraph
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.workload import MIXES, poisson_arrivals

PAPER_FIVE = ["one-shot", "hyde", "irg", "multistep", "recomp"]
STAGE_FIVE = ["rerank", "multiquery", "hybrid", "compress", "pipeline"]
MODES = ["sequential", "async", "hedra"]

# the golden-fingerprint fixture (scripts/make_golden_fingerprints.py)
RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)
GOLDEN_PATH = os.path.join(os.path.dirname(__file__),
                           "golden_fingerprints.json")


# ---------------------------------------------------------------------------
# Golden fingerprints: the refactor must not move a single event
# ---------------------------------------------------------------------------


def _trace_hash(server) -> str:
    import hashlib

    fp = {
        r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
        for r in server.sched.done
    }
    return hashlib.sha256(json.dumps(fp, sort_keys=True).encode()).hexdigest()


@pytest.mark.parametrize("num_ret_workers", [1, 4])
@pytest.mark.parametrize("mode", MODES)
def test_paper_workflow_fingerprints_bit_identical(small_index, embedder,
                                                   mode, num_ret_workers):
    """Per-request event traces of the five paper workflows are pinned:
    any stage/scheduler refactor must reproduce the goldens bit-for-bit
    for graphs built only from the original two node kinds."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode=mode, backend=be, nprobe=12,
               topk=5, num_ret_workers=num_ret_workers)
    for i, t in enumerate(poisson_arrivals(8.0, 20, seed=5)):
        s.add_request(f"q{i}", workflows.build(PAPER_FIVE[i % 5]),
                      arrival_us=float(t))
    m = s.run()
    assert m.finished == 20
    assert _trace_hash(s) == golden[f"{mode}-nw{num_ret_workers}"]


# ---------------------------------------------------------------------------
# Registry dispatch
# ---------------------------------------------------------------------------


def test_registry_covers_every_node_kind():
    assert set(stages.STAGE_REGISTRY) == {
        "generation", "retrieval", "rerank", "rewrite", "compress"}
    for name in PAPER_FIVE + STAGE_FIVE:
        g = workflows.build(name)
        for node in g.nodes.values():
            sp = stages.spec_for(node)
            assert sp.kind == node.kind
            assert sp is stages.spec(node.kind)


def test_unknown_kind_raises_actionable_keyerror():
    with pytest.raises(KeyError, match="bm25.*known kinds.*register_stage"):
        stages.spec("bm25")


def test_capability_flags_replace_kind_branches():
    gen = stages.spec("generation")
    assert gen.resource == stages.GEN
    assert gen.splittable and gen.emits_partial_queries
    assert gen.supports_spec_start and not gen.accepts_probe_warmup
    ret = stages.spec("retrieval")
    assert ret.resource == stages.HOST
    assert ret.splittable and ret.accepts_probe_warmup
    assert not ret.emits_partial_queries and not ret.supports_spec_start
    for kind in ("rerank", "rewrite", "compress"):
        sp = stages.spec(kind)
        assert sp.resource == stages.HOST and sp.splittable
        assert not sp.emits_partial_queries
        assert not sp.accepts_probe_warmup
        assert not sp.supports_spec_start


def test_host_stage_cost_profiles_feed_admission():
    """Admission's per-kind lower bound comes from the registered cost
    profile — positive, and at least the fixed cost plus one unit."""
    for kind in ("rerank", "compress"):
        sp = stages.spec(kind)
        lb = sp.min_service_us(None)
        assert lb == sp.profile.fixed_us + sp.profile.unit_us > 0.0


def test_exact_fusion_dedups_identical_stage_requests(small_index, embedder):
    """Duplicate rerank-workflow requests arriving together fuse at *both*
    stages: the retrieval wave fuses on the query signature, and the rerank
    wave fuses on the registry's exact (qv, candidates, keep) signature —
    every request still finishes with the same doc list."""
    from repro.retrieval.synthetic import DuplicateTrafficEmbedder

    demb = DuplicateTrafficEmbedder(embedder, dup_ratio=1.0, pool_size=1)
    be = SimBackend(small_index, demb, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, demb, mode="hedra", backend=be,
               dedup_threshold=1.0)  # exact-only
    for i in range(6):
        s.add_request(f"q{i}", workflows.build("rerank"), arrival_us=0.0)
    m = s.run()
    assert m.finished == 6
    # more fusions than the single retrieval wave can account for means the
    # rerank stage's own signature fused too
    assert m.dedup_exact > 5
    outs = [tuple(r.state["docs"]) for r in s.sched.done]
    assert len(set(outs)) == 1


def test_host_stage_fusion_is_exact_only():
    """Rerank signatures carry no unit vector, so near-match (cosine)
    fusion is structurally impossible for them — only byte-exact keys
    fuse, even at a permissive threshold."""
    import dataclasses as dc

    from repro.core.ragraph import RerankNode
    from repro.core.runtime import StageProgress
    from repro.crossreq.dedup import FusionPass

    def make_req(rid, qv, cands):
        req = type("Req", (), {})()
        req.request_id = rid
        req.node = RerankNode(1, docs="cands", keep=5)
        req.state = {"cands": list(cands)}
        req.stage = StageProgress(kind="rerank", work_queue=[list(cands)],
                                  total_units=1,
                                  payload={"qv": np.asarray(qv, np.float32)})
        return req

    sp = stages.spec("rerank")
    qv = np.arange(4, dtype=np.float32)
    lead = make_req(0, qv, [3, 1, 2])
    sig = sp.fusion_signature(None, lead)
    assert sig.unit_vec is None and sig.bucket[0] == "rerank"
    pool = FusionPass(threshold=0.5)  # permissive near threshold
    pool.register_leader(lead, sig)
    # byte-identical stage -> exact subscribe
    twin = make_req(1, qv, [3, 1, 2])
    assert pool.try_subscribe(twin, sp.fusion_signature(None, twin),
                              allow_near=True) == "exact"
    # nearly identical query vector, same candidates -> NO near fallback
    near = make_req(2, qv + 1e-4, [3, 1, 2])
    assert pool.try_subscribe(near, sp.fusion_signature(None, near),
                              allow_near=True) is None
    # different candidate order -> different key
    perm = make_req(3, qv, [1, 2, 3])
    assert pool.try_subscribe(perm, sp.fusion_signature(None, perm),
                              allow_near=True) is None


# ---------------------------------------------------------------------------
# Graph validation (Server admission rejects malformed graphs)
# ---------------------------------------------------------------------------


def test_validate_rejects_missing_start_edge():
    g = RAGraph("bad")
    g.add_generation(0, prompt="Answer {input}.")
    g.add_edge(0, END)
    with pytest.raises(ValueError, match="missing START edge"):
        g.validate()


def test_validate_rejects_edge_to_unknown_node():
    g = RAGraph("bad")
    g.add_generation(0, prompt="Answer {input}.")
    g.add_edge(START, 0)
    g.add_edge(0, 7)
    with pytest.raises(ValueError, match="edge to unknown node 7"):
        g.validate()


def test_validate_rejects_unreachable_node():
    g = RAGraph("bad")
    g.add_generation(0, prompt="Answer {input}.")
    g.add_retrieval(1, query="input", output="docs")
    g.add_edge(START, 0)
    g.add_edge(0, END)
    g.add_edge(1, END)
    with pytest.raises(ValueError, match=r"nodes \[1\] unreachable"):
        g.validate()


def test_validate_rejects_dangling_node():
    g = RAGraph("bad")
    g.add_retrieval(0, query="input", output="docs")
    g.add_generation(1, prompt="Answer {input} using {docs}.")
    g.add_edge(START, 0)
    g.add_edge(0, 1)  # node 1 has no onward edge
    with pytest.raises(ValueError, match=r"nodes \[1\] have no outgoing"):
        g.validate()


def test_validate_rejects_unknown_template_input():
    g = RAGraph("bad")
    g.add_generation(0, prompt="Answer {input} using {context}.")
    g.add_edge(START, 0)
    g.add_edge(0, END)
    with pytest.raises(ValueError, match="reads 'context'.*no node produces"):
        g.validate()


def test_validate_accepts_listing1_query_alias():
    g = RAGraph("ok")
    g.add_retrieval(0, query="input", output="docs")
    g.add_generation(1, prompt="Answer {query} using {docs}.")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, END)
    g.validate()


def test_server_admission_runs_validate(small_index, embedder):
    g = RAGraph("bad")
    g.add_generation(0, prompt="Answer {nope}.")
    g.add_edge(START, 0)
    g.add_edge(0, END)
    s = Server(small_index, embedder, mode="hedra",
               backend=SimBackend(small_index, embedder))
    with pytest.raises(ValueError, match="no node produces"):
        s.add_request("q", g)


# ---------------------------------------------------------------------------
# New stage workflows end-to-end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("name", STAGE_FIVE)
def test_stage_workflows_complete_all_modes(small_index, embedder, mode,
                                            name):
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode=mode, backend=be, nprobe=12,
               topk=5)
    for i, t in enumerate(poisson_arrivals(6.0, 6, seed=4)):
        s.add_request(f"q{i}", workflows.build(name), arrival_us=float(t))
    m = s.run()
    assert m.finished == 6, f"{name}/{mode} finished {m.finished}"
    for r in s.sched.done:
        assert r.state.get("answer")


def test_rerank_keeps_subset_of_candidates(small_index, embedder):
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode="hedra", backend=be)
    s.add_request("q0", workflows.build("rerank", topk=24, keep=5))
    m = s.run()
    assert m.finished == 1
    r = s.sched.done[0]
    assert len(r.state["docs"]) == 5
    assert set(r.state["docs"]) <= set(r.state["cands"])
    assert m.stage_tasks > 0


def test_compress_ratio_bounds_kept_context(small_index, embedder):
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode="hedra", backend=be)
    s.add_request("q0", workflows.build("compress", topk=16, ratio=0.25))
    m = s.run()
    assert m.finished == 1
    r = s.sched.done[0]
    assert len(r.state["docs"]) == max(1, round(len(r.state["cands"]) * 0.25))
    assert set(r.state["docs"]) <= set(r.state["cands"])


def test_hybrid_lexical_fusion_rescores(small_index, embedder):
    """lexical_weight > 0 must engage the rrf rescoring path; weight 0 must
    stay bit-identical to the pure dense stage."""
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode="hedra", backend=be)
    s.add_request("q0", workflows.build("hybrid", lexical_weight=0.5))
    m = s.run()
    assert m.finished == 1
    assert m.lexical_fusions == 1
    be2 = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s2 = Server(small_index, embedder, mode="hedra", backend=be2)
    s2.add_request("q0", workflows.build("hybrid", lexical_weight=0.0))
    m2 = s2.run()
    assert m2.lexical_fusions == 0


def test_multiquery_merges_variant_topk(small_index, embedder):
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode="hedra", backend=be)
    s.add_request("q0", workflows.build("multiquery", n_queries=3, topk=5))
    m = s.run()
    assert m.finished == 1
    docs = s.sched.done[0].state["docs"]
    assert len(docs) == len(set(docs))  # k-way merge deduplicates
    assert len(docs) >= 5  # variants contribute beyond a single top-k


def test_heterogeneous_mix_serves_end_to_end(small_index, embedder):
    mix = MIXES["heterogeneous"]
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode="hedra", backend=be, nprobe=12,
               topk=5, workload=mix.profile())
    m = s.serve(mix.sample(40, 8.0))
    assert m.finished == 40
    assert m.stage_tasks > 0  # registry host stages actually dispatched
    assert m.lexical_fusions > 0  # hybrid class engaged its fusion path
    assert m.summary()["slo_violations"] == 0


# ---------------------------------------------------------------------------
# Journal recovery: ids and event prefixes survive a restart
# ---------------------------------------------------------------------------


def _crashy_run(tmp_path, index, emb, journal="journal.jsonl"):
    """A run cut off mid-flight: journal holds a mix of done/undone rows."""
    p = str(tmp_path / journal)
    be = SimBackend(index, emb, cost_model=RET_HEAVY, seed=0)
    s = Server(index, emb, mode="hedra", backend=be, journal_path=p)
    names = PAPER_FIVE + STAGE_FIVE
    for i, t in enumerate(poisson_arrivals(8.0, 10, seed=9)):
        s.add_request(f"q{i}", workflows.build(names[i % len(names)]),
                      arrival_us=float(t))
    m = s.run(max_time_us=1.2e6)
    assert 0 < m.finished < 10, "cutoff must leave a mix of done/undone"
    return p, s


def test_restart_recovers_unfinished_with_original_ids(tmp_path, small_index,
                                                       embedder):
    p, s1 = _crashy_run(tmp_path, small_index, embedder)
    unfinished = Server.replay_unfinished(p)
    expect_ids = {row["request_id"] for row in unfinished}
    assert expect_ids
    # journal-backed construction re-admits automatically
    s2 = Server(small_index, embedder, mode="hedra",
                backend=SimBackend(small_index, embedder,
                                   cost_model=RET_HEAVY, seed=0),
                journal_path=p)
    assert set(s2.recovered_ids) == expect_ids  # original ids preserved
    # pre-crash event prefixes carried over
    live = {r.request_id: r for r in s2.sched.active + s2.sched.pending}
    for row in unfinished:
        req = live[row["request_id"]]
        assert [list(ev) for ev in req.events] == \
            [list(ev) for ev in row["events"]]
    m2 = s2.run()
    assert m2.finished == len(unfinished)
    # the post-restart trace keeps its pre-crash prefix
    by_id = {r.request_id: r for r in s2.sched.done}
    for row in unfinished:
        if not row["events"]:
            continue
        got = by_id[row["request_id"]].events
        assert list(got[0]) == list(row["events"][0])
    # fresh admissions never collide with recovered ids
    rid = s2.add_request("fresh", workflows.build("one-shot"))
    assert rid not in expect_ids


def test_readmit_remaps_only_on_live_collision(tmp_path, small_index,
                                               embedder):
    p, _ = _crashy_run(tmp_path, small_index, embedder)
    unfinished = Server.replay_unfinished(p)
    row = unfinished[0]
    s2 = Server(small_index, embedder, mode="hedra",
                backend=SimBackend(small_index, embedder,
                                   cost_model=RET_HEAVY, seed=0))
    # occupy the row's original id with a live request
    taken = None
    while taken != row["request_id"]:
        taken = s2.add_request("occupier", workflows.build("one-shot"))
        assert taken is not None and taken <= row["request_id"]
    ids = s2.readmit([row])
    assert len(ids) == 1 and ids[0] is not None
    assert ids[0] != row["request_id"]  # collision: remapped fresh
    m = s2.run()
    assert m.finished == row["request_id"] + 2


def test_finished_rows_are_never_readmitted(tmp_path, small_index, embedder):
    p, s1 = _crashy_run(tmp_path, small_index, embedder)
    done_ids = {r.request_id for r in s1.sched.done}
    s2 = Server(small_index, embedder, mode="hedra",
                backend=SimBackend(small_index, embedder,
                                   cost_model=RET_HEAVY, seed=0),
                journal_path=p)
    assert not (set(s2.recovered_ids) & done_ids)
