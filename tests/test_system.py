"""End-to-end serving behaviour: modes, invariants, fault tolerance."""
import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.core.wavefront import SchedulerConfig
from repro.retrieval import HybridRetrievalEngine
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.workload import poisson_arrivals

NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp"]

# cost model emulating a paper-scale corpus (retrieval comparable to gen)
RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0)


def _run(mode, idx, emb, n=30, rate=4.0, hybrid=None, **cfg):
    be = SimBackend(idx, emb, hybrid=hybrid, cost_model=RET_HEAVY)
    s = Server(idx, emb, mode=mode, backend=be, nprobe=12, topk=5, **cfg)
    for i, t in enumerate(poisson_arrivals(rate, n, seed=5)):
        s.add_request(f"q{i}", workflows.build(NAMES[i % len(NAMES)]), arrival_us=t)
    return s, s.run()


def test_all_modes_complete_all_requests(small_index, embedder):
    for mode in ["sequential", "async", "hedra"]:
        _, m = _run(mode, small_index, embedder, n=20)
        assert m.finished == 20, f"{mode} finished {m.finished}"


def test_hedra_beats_coarse_baselines(small_index, embedder):
    res = {m: _run(m, small_index, embedder, n=30)[1].summary()
           for m in ["sequential", "async", "hedra"]}
    assert res["hedra"]["avg_latency_ms"] < res["sequential"]["avg_latency_ms"]
    assert res["hedra"]["avg_latency_ms"] < res["async"]["avg_latency_ms"] * 1.05


def test_speculation_improves_or_matches(small_index, embedder):
    from repro.core.speculation import SpeculationPolicy

    base_cfg = SchedulerConfig.preset("hedra",
                                      speculation=SpeculationPolicy(mode="off"))
    s0, m0 = _run("hedra", small_index, embedder, n=24, config=base_cfg)
    s1, m1 = _run("hedra", small_index, embedder, n=24)
    assert m1.spec_gen_attempts > 0
    # validated speculation should not make latency worse (paper: overlap is
    # free; rollback costs nothing vs the sequential plan)
    assert m1.summary()["avg_latency_ms"] <= m0.summary()["avg_latency_ms"] * 1.10


def test_results_lossless_without_cache_answers(small_index, embedder):
    """With O1 cache answers disabled, every retrieval output must equal the
    reference IVF search — reordering, sub-staging and early termination are
    result-preserving transformations."""
    from repro.core.wavefront import SchedulerConfig

    cfg = SchedulerConfig.preset("hedra", enable_cache_answer=False,
                                 early_term_mode="lossless")
    s, m = _run("hedra", small_index, embedder, n=12, config=cfg)
    # direct check: re-run one request's first retrieval by hand
    req = s.sched.done[0]
    node = next(n for n in req.graph.nodes.values() if n.kind == "retrieval")
    qv = s.backend.query_embedding(req, 0)
    D, I = small_index.search(qv[None], nprobe=cfg.nprobe, k=node.topk)
    first_ret_out = None
    for nid, n in sorted(req.graph.nodes.items()):
        if n.kind == "retrieval":
            first_ret_out = req.state.get(n.output)
            break
    assert first_ret_out is not None
    assert list(I[0][: len(first_ret_out)]) == first_ret_out


def test_straggler_mitigation_counts(small_index, embedder):
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY,
                    straggler_prob=0.3, straggler_factor=10.0, seed=3)
    s = Server(small_index, embedder, mode="hedra", backend=be)
    for i, t in enumerate(poisson_arrivals(4.0, 16, seed=6)):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=t)
    m = s.run()
    assert m.finished == 16
    assert m.straggler_redispatches > 0


def test_journal_replay(tmp_path, small_index, embedder):
    p = str(tmp_path / "journal.json")
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY)
    s = Server(small_index, embedder, mode="hedra", backend=be, journal_path=p)
    for i in range(6):
        s.add_request(f"q{i}", workflows.build("one-shot"), arrival_us=0.0)
    s.run()
    unfinished = Server.replay_unfinished(p)
    assert unfinished == []  # all done -> nothing to replay
    # simulate crash: journal with pending requests
    s2 = Server(small_index, embedder, mode="hedra",
                backend=SimBackend(small_index, embedder), journal_path=p)
    s2.add_request("qx", workflows.build("one-shot"), arrival_us=1e12)
    s2.sched.pending[0].arrival_us = 1e12
    s2.write_journal(p)
    assert len(Server.replay_unfinished(p)) == 1


def test_journal_roundtrip_with_midrun_completion(tmp_path, small_index, embedder):
    """write_journal -> replay_unfinished round-trip on a run cut off
    mid-flight: completed requests are journaled as finished (with their
    event history) and excluded from replay; in-flight/pending ones are
    returned for re-admission, and re-admitting them drains the backlog."""
    p = str(tmp_path / "journal.json")
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY)
    s = Server(small_index, embedder, mode="hedra", backend=be, journal_path=p)
    for i, t in enumerate(poisson_arrivals(8.0, 10, seed=9)):
        s.add_request(f"q{i}", workflows.build(NAMES[i % len(NAMES)]),
                      arrival_us=t)
    # stop the clock early so some requests complete and some do not
    m = s.run(max_time_us=1.0e6)
    assert 0 < m.finished < 10, "cutoff must leave a mix of done/undone"
    rows = Server.read_journal(p)
    assert len(rows) == 10
    by_id = {r["request_id"]: r for r in rows}
    done_ids = {r.request_id for r in s.sched.done}
    for rid, row in by_id.items():
        assert row["finished"] == (rid in done_ids)
        if row["finished"]:
            assert row["finish_us"] >= 0
            assert any(e == "ret_stage_start" for _, e, _p in row["events"])
        assert row["input"] == f"q{rid}"
        assert row["graph"] in NAMES
    unfinished = Server.replay_unfinished(p)
    assert {r["request_id"] for r in unfinished} == set(by_id) - done_ids
    # round-trip: re-admit the unfinished rows into a fresh server
    s2 = Server(small_index, embedder, mode="hedra",
                backend=SimBackend(small_index, embedder,
                                   cost_model=RET_HEAVY))
    for row in unfinished:
        s2.add_request(row["input"], workflows.build(row["graph"]),
                       arrival_us=0.0)
    m2 = s2.run()
    assert m2.finished == len(unfinished)


def test_hot_cache_integration(small_index, embedder):
    hyb = HybridRetrievalEngine(small_index, cache_capacity=10,
                                update_interval=10, transit_substages=1,
                                kernel_impl="ref")
    _, m = _run("hedra", small_index, embedder, n=30, hybrid=hyb)
    assert m.finished == 30
    st = hyb.stats()
    assert st["hits"] + st["misses"] > 0
    assert st["hit_rate"] > 0.0  # skewed workload must produce hits


def test_mixed_concurrent_workflows_slo(small_index, embedder):
    _, m = _run("hedra", small_index, embedder, n=40, rate=8.0)
    s = m.summary()
    assert s["finished"] == 40
    assert s["slo_violations"] == 0  # 10s SLO at this scale
