"""Property suite for the observability layer: over random chaos serving
runs (random modes, worker counts, workflow mixes, FaultPlans) the span
recorder must always produce a structurally valid Chrome trace that covers
every journaled request, the latency attribution must partition each
finished request's measured latency exactly, and turning tracing on must
never perturb the run.  Plus a pure-function property: the priority sweep
partitions any random interval soup over any window.

Runs under hypothesis when installed (CI installs it explicitly); otherwise
falls back to a fixed seeded sweep of the same properties so the suite never
silently skips."""
import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.obs.attribution import ATTRIBUTION_COMPONENTS, sweep
from repro.obs.trace import request_ids_in_trace, validate_trace
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.faults import FaultPlan

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # local envs without hypothesis: seeded sweep instead
    HAVE_HYPOTHESIS = False

RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)
FALLBACK_SEEDS = list(range(24))
NAMES = ["one-shot", "hyde", "irg", "multistep", "recomp",
         "rerank", "multiquery", "hybrid", "compress", "pipeline"]
MODES = ["hedra", "async", "sequential"]


def _property(n_examples):
    """Decorator: hypothesis-driven seeds when available, a fixed
    parametrized sweep otherwise.  The wrapped test takes ``seed`` last."""
    if HAVE_HYPOTHESIS:
        return lambda fn: settings(
            max_examples=n_examples, deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )(given(seed=st.integers(0, 2**32 - 1))(fn))
    return lambda fn: pytest.mark.parametrize(
        "seed", FALLBACK_SEEDS[:n_examples])(fn)


def _chaos_run(index, emb, seed, *, obs=True):
    """One randomized chaos serve with the obs layer on.  Returns
    (server, metrics, n_submitted)."""
    rng = np.random.default_rng(seed)
    nw = int(rng.integers(1, 5))
    mode = MODES[int(rng.integers(0, len(MODES)))]
    sharding = bool(rng.integers(0, 2)) and nw > 1
    n = int(rng.integers(4, 9))
    plan = FaultPlan.random(
        int(rng.integers(0, 2**31)), nw, 1_200_000.0,
        crash_frac=float(rng.uniform(0.0, 0.5)),
        stall_rate=float(rng.uniform(0.0, 1.0)),
        stall_factor=float(rng.uniform(2.0, 10.0)),
        transient_prob=float(rng.uniform(0.0, 0.3)))
    be = SimBackend(index, emb, cost_model=RET_HEAVY, seed=0,
                    fault_plan=plan)
    s = Server(index, emb, mode=mode, backend=be, nprobe=12, topk=5,
               num_ret_workers=nw, index_sharding=sharding,
               retry_backoff_us=float(rng.uniform(2_000.0, 40_000.0)),
               retry_budget=int(rng.integers(1, 4)),
               hedge_suspect=bool(rng.integers(0, 2)),
               tracing=obs, telemetry=obs)
    for i in range(n):
        s.add_request(f"q{i}", workflows.build(
            NAMES[int(rng.integers(0, len(NAMES)))]),
            arrival_us=float(rng.uniform(0.0, 60_000.0) + i * 2_000.0))
    m = s.run()
    return s, m, n


@_property(14)
def test_chaos_trace_valid_and_attribution_partitions(small_index, embedder,
                                                      seed):
    """Under arbitrary crashes/stalls/transients the exported trace stays
    structurally valid, every journaled request appears in it, and the
    attribution components sum to each measured latency within 1e-6."""
    s, m, n = _chaos_run(small_index, embedder, seed)
    trace = s.export_trace()
    assert validate_trace(trace) == []
    journal = {r.request_id for r in s.sched.done}
    assert journal <= request_ids_in_trace(trace)
    rep = s.attribution_report(rel_tol=1e-6)  # raises on any violation
    assert rep["finished"] == m.finished
    assert rep["max_rel_residual"] <= 1e-6
    for row in rep["per_request"]:
        assert all(v >= -1e-9 for v in row["components_us"].values())
    # sampler saw the run too: monotone virtual timestamps, consistent
    # lifecycle head-counts
    tel = s.sched.telemetry
    ts = [row["t_us"] for row in tel.samples]
    assert ts == sorted(ts)
    for row in tel.samples:
        assert sum(row["lifecycle"].values()) == s.sched.num_ret_workers


@_property(6)
def test_obs_on_never_perturbs_chaos_run(small_index, embedder, seed):
    """Passivity under chaos: the same seed with the obs layer off yields
    bit-identical per-request event traces — recording draws no randomness
    and mutates no scheduler state, faults included."""
    fps = []
    for obs in (True, False):
        s, m, _ = _chaos_run(small_index, embedder, seed, obs=obs)
        fps.append({r.request_id:
                    [(float(t), e, repr(p)) for t, e, p in r.events]
                    for r in s.sched.done})
    assert fps[0] == fps[1]


@_property(20)
def test_sweep_partitions_any_interval_soup(seed):
    """Pure-function property: for random overlapping intervals and a random
    window, the priority sweep's components are non-negative and sum to the
    window width exactly (uncovered time charged to queueing)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(0, 12))
    comps = [c for c in ATTRIBUTION_COMPONENTS if c != "queueing"]
    rows = []
    for _ in range(n):
        s0 = float(rng.uniform(-50.0, 150.0))
        rows.append([s0, s0 + float(rng.uniform(0.0, 80.0)),
                     comps[int(rng.integers(0, len(comps)))]])
    start = float(rng.uniform(-20.0, 60.0))
    end = start + float(rng.uniform(0.0, 120.0))
    out = sweep(rows, start, end)
    assert set(out) == set(ATTRIBUTION_COMPONENTS)
    assert all(v >= 0.0 for v in out.values())
    np.testing.assert_allclose(sum(out.values()), end - start,
                               rtol=1e-9, atol=1e-9)
