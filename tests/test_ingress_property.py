"""Property suite for the wall-clock ingress replay oracle: random mixes,
rates, speedups, front-end shapes (open-loop stream vs closed-loop
clients) and optional chaos plans — every threaded run's recorded trace
must replay on the pure virtual clock to bit-identical per-request event
fingerprints, with counter conservation holding on both sides.

Runs under hypothesis when installed (CI installs it explicitly);
otherwise falls back to a fixed seeded sweep of the same properties so
the suite never silently skips."""
import numpy as np
import pytest

from repro.server import Server
from repro.serving.faults import FaultPlan
from repro.serving.ingress import ArrivalTrace, replay_trace
from repro.serving.workload import MIXES, ClosedLoopSpec

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # local envs without hypothesis: seeded sweep instead
    HAVE_HYPOTHESIS = False

FALLBACK_SEEDS = list(range(8))
MIX_NAMES = ["heterogeneous", "balanced", "interactive-heavy",
             "pure-oneshot"]


def _property(n_examples):
    if HAVE_HYPOTHESIS:
        return lambda fn: settings(
            max_examples=n_examples, deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )(given(seed=st.integers(0, 2**32 - 1))(fn))
    return lambda fn: pytest.mark.parametrize(
        "seed", FALLBACK_SEEDS[:n_examples])(fn)


def _wall_run(index, emb, seed):
    """One randomized threaded serve.  Returns (server, metrics, trace,
    server_factory)."""
    rng = np.random.default_rng(seed)
    mix = MIXES[MIX_NAMES[int(rng.integers(0, len(MIX_NAMES)))]]
    nw = int(rng.integers(1, 4))
    chaos = bool(rng.integers(0, 2))
    external_hb = bool(rng.integers(0, 2))
    speedup = float(rng.uniform(400.0, 2000.0))
    plan_seed = int(rng.integers(0, 2**31))

    def mk():
        plan = None
        if chaos:
            plan = FaultPlan.random(plan_seed, nw, 800_000.0,
                                    crash_frac=0.3, stall_rate=1e-6,
                                    transient_prob=0.05)
        return Server(index, emb, mode="hedra", nprobe=8,
                      workload=mix.profile(), num_ret_workers=nw,
                      fault_plan=plan,
                      external_heartbeats=external_hb,
                      fault_tolerance=external_hb or chaos)

    s = mk()
    if rng.integers(0, 2):  # closed loop
        spec = ClosedLoopSpec.from_mix(
            mix, num_clients=int(rng.integers(1, 4)),
            requests_per_client=int(rng.integers(2, 5)),
            think_time_s=float(rng.uniform(0.005, 0.03)),
            seed=int(rng.integers(0, 2**31)))
        m, trace = s.serve_wallclock(closed_loop=spec, speedup=speedup,
                                     max_wall_s=90.0)
    else:
        stream = mix.sample(int(rng.integers(4, 13)),
                            rate_per_s=float(rng.uniform(50.0, 400.0)),
                            seed=int(rng.integers(0, 2**31)))
        m, trace = s.serve_wallclock(stream, speedup=speedup,
                                     max_wall_s=90.0)
    return s, m, trace, mk


@_property(6)
def test_record_replay_fingerprint_identity(small_index, embedder, seed):
    s1, m1, trace, mk = _wall_run(small_index, embedder, seed)
    # liveness: every admitted request ended finished; conservation holds
    assert m1.submitted == m1.finished
    n_arrivals = sum(1 for r in trace.rows if r.kind == "arrival")
    assert m1.submitted + m1.shed_final == n_arrivals
    # the oracle: replay the trace on a fresh server over the virtual clock
    s2 = mk()
    m2 = replay_trace(s2, trace)
    assert s2.fingerprints() == s1.fingerprints()
    assert m2.summary() == m1.summary()


@_property(3)
def test_replay_survives_json_round_trip(small_index, embedder, seed):
    s1, _m1, trace, mk = _wall_run(small_index, embedder, seed)
    rt = ArrivalTrace.from_dict(trace.to_dict())
    assert [r.__dict__ for r in rt.rows] == [r.__dict__ for r in trace.rows]
    s2 = mk()
    replay_trace(s2, rt)
    assert s2.fingerprints() == s1.fingerprints()
