"""Shard-aware serving: scatter-gather parity harness.

Covers the distributed-retrieval serving path end to end:

* function-level bit-identity: ``scatter_gather_search`` == ``plan_search``
  == the ``reference_search`` slab oracle, across shard counts and
  arbitrary ownership maps;
* serving-level parity: shard-mode servers (1/2/4 shards) produce the same
  retrieval results as a single-worker whole-index server for every
  workflow class in ``MIXES``, and the final merged top-k of every request
  matches the full-search oracle;
* gating: with ``index_sharding`` off, per-request event fingerprints are
  bit-identical to the default (pre-shard) configuration across
  hedra/async/sequential x 1/4 workers;
* shard-mode cost model (max over shards + merge term, not a sum);
* per-worker device-slab residency under sharding;
* crash recovery: a journaled shard-mode run cut mid-flight re-admits
  cleanly into a warm shard-mode server.

The hypothesis property test (random cluster->shard assignments, probe
lists, and k) lives at the bottom, gated on hypothesis availability like
the other property suites.
"""
import numpy as np
import pytest

from repro import workflows
from repro.core.backends import SimBackend
from repro.core.wavefront import SchedulerConfig
from repro.retrieval.distributed import ShardMap, scatter_gather_search
from repro.retrieval.hybrid import HybridRetrievalEngine
from repro.retrieval.ivf import ClusterCostModel
from repro.retrieval.plan import (
    BatchTopK,
    PlanBuilder,
    gather_scatter_rows,
    make_gather_plan,
    plan_search,
)
from repro.server import Server
from repro.serving import dispatch
from repro.serving.workload import MIXES, poisson_arrivals

RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)
ALL_WORKFLOWS = sorted({w for mix in MIXES.values() for w in mix.weights})
SHARD_COUNTS = [1, 2, 4]


def _server(index, emb, mode="hedra", nw=1, *, sharding=False,
            preserve=False, hot_cache=0, **cfg):
    hybrid = None
    if hot_cache:
        hybrid = HybridRetrievalEngine(index, cache_capacity=hot_cache,
                                       update_interval=10,
                                       transit_substages=1, kernel_impl="ref")
    be = SimBackend(index, emb, hybrid=hybrid, cost_model=RET_HEAVY, seed=0)
    if preserve:
        # result-preserving settings: final stage top-k == full probe-set
        # top-k regardless of sub-stage partitioning / event timing, which
        # is what makes results comparable across worker/shard counts
        cfg.setdefault("enable_cache_answer", False)
        cfg.setdefault("early_term_mode", "lossless")
    return Server(index, emb, mode=mode, backend=be, nprobe=12, topk=5,
                  num_ret_workers=nw, index_sharding=sharding, **cfg)


def _load(server, names, n=12, rate=8.0, seed=5):
    arr = poisson_arrivals(rate, n, seed=seed)
    for i, t in enumerate(arr):
        server.add_request(f"q{i}", workflows.build(names[i % len(names)]),
                           arrival_us=t)


def _ret_outputs(server):
    """request_id -> retrieval doc-id lists in the final state."""
    return {r.request_id: {k: v for k, v in r.state.items()
                           if isinstance(v, list)}
            for r in server.sched.done}


def _fingerprints(server):
    return {r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
            for r in server.sched.done}


# ------------------------------------------------ function-level bit parity


def test_scatter_gather_matches_plan_search_bitwise(small_index):
    rng = np.random.default_rng(0)
    q = rng.standard_normal((6, small_index.dim)).astype(np.float32)
    D0, I0 = plan_search(small_index, q, nprobe=16, k=5)
    for ns in SHARD_COUNTS + [7]:
        sm = ShardMap.build(small_index.cluster_sizes(), ns)
        D1, I1 = scatter_gather_search(small_index, q, 16, 5, sm)
        np.testing.assert_array_equal(D0, D1)
        np.testing.assert_array_equal(I0, I1)
    # arbitrary (non-contiguous) ownership, including an empty shard
    owner = rng.integers(0, 3, small_index.n_clusters)
    sm = ShardMap.from_owner(owner, n_shards=5)
    D1, I1 = scatter_gather_search(small_index, q, 16, 5, sm)
    np.testing.assert_array_equal(D0, D1)
    np.testing.assert_array_equal(I0, I1)


def test_scatter_gather_matches_reference_search_oracle(small_index):
    """The serving-path scatter-gather merge agrees with the distributed
    module's slab oracle (``reference_search``) on doc ids and distances."""
    from repro.retrieval.distributed import reference_search

    rng = np.random.default_rng(1)
    q = rng.standard_normal((4, small_index.dim)).astype(np.float32)
    k = 5
    # full-index slab: probe every cluster so both sides rank all vectors
    cids = list(range(small_index.n_clusters))
    slab, valid, slab_ids = small_index.cluster_tensor(cids)
    dref, rows = reference_search(q, slab, valid, k)
    oracle_ids = np.asarray(slab_ids).reshape(-1)[np.asarray(rows)]
    sm = ShardMap.build(small_index.cluster_sizes(), 4)
    D, I = scatter_gather_search(small_index, q, small_index.n_clusters, k, sm)
    np.testing.assert_array_equal(I, oracle_ids)
    np.testing.assert_allclose(D, np.asarray(dref), rtol=1e-4, atol=1e-3)


def test_shard_map_contiguous_and_balanced(small_index):
    sizes = small_index.cluster_sizes()
    for ns in (2, 4, 8):
        sm = ShardMap.build(sizes, ns)
        assert sm.n_shards == ns
        assert sm.bounds[0] == 0 and sm.bounds[-1] == small_index.n_clusters
        assert np.all(np.diff(sm.bounds) > 0)  # contiguous, non-empty
        # ownership follows the range table
        for s in range(ns):
            assert np.all(sm.owner[sm.bounds[s]: sm.bounds[s + 1]] == s)
        mass = sm.shard_sizes(sizes)
        assert mass.max() / mass.mean() < 2.0  # balanced by vector count

    def split_roundtrip(clusters):
        parts = sm.split(clusters)
        flat = [c for _, p in parts for c in p]
        assert sorted(flat) == sorted(clusters)
        for s, p in parts:
            assert all(int(sm.owner[c]) == s for c in p)

    split_roundtrip([0, 5, 17, 44, 29, 3])
    assert sm.split([]) == []


# ---------------------------------------------------- serving-level parity


@pytest.mark.parametrize("mix_name", sorted(MIXES))
def test_sharded_serving_matches_whole_index(small_index, embedder, mix_name):
    """For every workflow class in every mix: shard-mode retrieval results
    (1/2/4 shards) == single-worker whole-index serving results, and the
    final merged top-k of every request == the full-search oracle."""
    mix = MIXES[mix_name]
    names = sorted(mix.weights)
    base = _server(small_index, embedder, nw=1, sharding=False, preserve=True)
    _load(base, names)
    base.run()
    want = _ret_outputs(base)
    for nw in SHARD_COUNTS:
        s = _server(small_index, embedder, nw=nw, sharding=True,
                    preserve=True)
        _load(s, names)
        m = s.run()
        assert m.finished == len(want)
        assert _ret_outputs(s) == want
        if nw > 1:
            assert m.shard_scatters > 0 and m.shard_merges > 0
            assert m.shard_parts >= m.shard_scatters
        # merged top-k == full-search oracle for the final retrieval round
        # — only where "docs" comes straight from a pure dense retrieval
        # node (hybrid fusion and registry stages rescore/filter it, and
        # multiquery has no retrieval node at all; those classes are still
        # covered by the shard-vs-whole-index equality above)
        for r in s.sched.done:
            if r.round_idx == 0 or "docs" not in r.state:
                continue
            node = next((n for n in r.graph.nodes.values()
                         if n.kind == "retrieval" and n.output == "docs"
                         and n.lexical_weight == 0.0), None)
            if node is None:
                continue
            qv = embedder.embed_query(r.request_id, r.round_idx - 1)
            _, ids = small_index.search(qv[None], 12, node.topk or 5)
            assert r.state["docs"] == [int(i) for i in ids[0] if i >= 0]


@pytest.mark.parametrize("mode", ["hedra", "async", "sequential"])
def test_sharded_modes_complete_and_scatter(small_index, embedder, mode):
    """All three scheduling modes serve a shard-mode pool to completion,
    with whole coarse stages scattering across owners too."""
    s = _server(small_index, embedder, mode=mode, nw=4, sharding=True)
    _load(s, ALL_WORKFLOWS, n=16, rate=20.0)
    m = s.run()
    assert m.finished == 16
    assert m.shard_scatters > 0
    assert m.shard_parts >= m.shard_scatters
    if mode != "hedra":
        # coarse whole-stage scatters span several shards; hedra's Eq.(1)
        # budget can legitimately cut sub-stages down to single-shard parts
        assert m.shard_parts > m.shard_scatters
    rep = s.shard_report()
    assert rep["n_shards"] == 4
    assert rep["shard_merges"] == m.shard_merges


# -------------------------------------------------------- off-knob gating


@pytest.mark.parametrize("mode", ["hedra", "async", "sequential"])
@pytest.mark.parametrize("nw", [1, 4])
def test_sharding_off_fingerprints_unchanged(small_index, embedder, mode, nw):
    """index_sharding=False must leave the serving loop on the exact
    pre-shard path: per-request event fingerprints identical to the default
    configuration (PR 4 behaviour), across modes and worker counts."""
    assert SchedulerConfig().index_sharding is False
    s1 = _server(small_index, embedder, mode=mode, nw=nw)
    _load(s1, ALL_WORKFLOWS, n=14)
    s1.run()
    s2 = _server(small_index, embedder, mode=mode, nw=nw, sharding=False)
    _load(s2, ALL_WORKFLOWS, n=14)
    s2.run()
    assert s2.sched.shard_map is None
    assert s2.sched.metrics.shard_scatters == 0
    assert _fingerprints(s1) == _fingerprints(s2)


# ------------------------------------------------------ shard cost model


def test_sharded_scan_cost_is_max_plus_merge():
    sizes = np.array([100, 100, 100, 100], np.int64)
    cm = ClusterCostModel(fixed_us=10.0, per_vector_us=1.0, per_query_us=0.0)
    sm = ShardMap.from_owner([0, 0, 1, 1])
    clusters = np.array([0, 1, 2, 3], np.int64)
    flat = cm.batch_cost_us(sizes[clusters])  # single-worker sum
    sharded = dispatch.sharded_scan_cost_us(clusters, cm, sizes, sm,
                                            merge_us=25.0)
    # two equal shards: max is half the sum; two partial sets merge
    assert sharded == pytest.approx(flat / 2.0 + 2 * 25.0)
    # all probes on one shard: no parallelism, one merge
    one = dispatch.sharded_scan_cost_us(np.array([0, 1]), cm, sizes, sm,
                                        merge_us=25.0)
    assert one == pytest.approx(cm.batch_cost_us(sizes[:2]) + 25.0)
    assert dispatch.sharded_scan_cost_us(np.zeros(0, np.int64), cm, sizes,
                                         sm, merge_us=25.0) == 0.0


def test_admission_lower_bound_gains_merge_term(small_index):
    from repro.core.runtime import RequestContext
    from repro.core.substage import TimeBudget

    sizes = small_index.cluster_sizes()
    cfg = SchedulerConfig.preset("hedra", admission_control=True,
                                 num_ret_workers=4, index_sharding=True,
                                 shard_merge_us=40.0)
    sm = ShardMap.build(sizes, 4)
    ac_flat = dispatch.AdmissionController(cfg, TimeBudget(),
                                           ClusterCostModel(), sizes)
    ac_shard = dispatch.AdmissionController(cfg, TimeBudget(),
                                            ClusterCostModel(), sizes,
                                            shard_map=sm)
    req = RequestContext(0, workflows.build("multistep"), {})
    n_ret = sum(1 for n in req.graph.nodes.values() if n.kind == "retrieval")
    assert ac_shard.lower_bound_us(req) == pytest.approx(
        ac_flat.lower_bound_us(req) + n_ret * 40.0)


def test_pick_shard_worker_owner_and_replica_routing():
    from repro.crossreq.popularity import ReplicaMap

    d = dispatch.RetrievalDispatcher(4, 16, policy="affinity")
    # owner idle -> owner
    assert d.pick_shard_worker([3, 4], owner=1, candidates=[0, 1, 2]) == 1
    # owner busy, no replicas -> deferred
    assert d.pick_shard_worker([3, 4], owner=3, candidates=[0, 1, 2]) is None
    # replicated hot cluster: a holder covering *all* clusters may serve it
    rm = ReplicaMap(4, 2)
    rm._owners = {3: (1, 2), 4: (2, 3)}
    d2 = dispatch.RetrievalDispatcher(4, 16, policy="affinity",
                                      replica_map=rm)
    assert d2.pick_shard_worker([3, 4], owner=1, candidates=[0, 2]) == 2
    assert d2.replica_routes == 1
    # partial coverage (cluster 5 unreplicated) -> owner only
    assert d2.pick_shard_worker([3, 5], owner=1, candidates=[0, 2]) is None


# --------------------------------------------- per-worker slab residency


def test_per_worker_residency_drops_with_shards(small_index, embedder):
    """Shard mode partitions the device slab: primaries live on their
    owner's slots and per-worker residency shrinks ~N x."""
    residency = {}
    for nw in (1, 2, 4):
        s = _server(small_index, embedder, nw=nw, sharding=nw > 1,
                    hot_cache=16)
        _load(s, ALL_WORKFLOWS, n=24, rate=20.0)
        m = s.run()
        assert m.finished == 24
        cache = s.backend.hybrid.cache
        if nw == 1:
            residency[1] = len(cache.resident_ids)
            continue
        sm = s.sched.shard_map
        for cid in cache.resident_ids:
            assert cache._resident[cid] % nw == int(sm.owner[cid])
        per = cache.per_owner_resident()
        assert set(per) == set(range(nw))
        residency[nw] = max(per.values())
        # each worker's partition holds at most capacity/N slots
        assert residency[nw] <= -(-16 // nw)
    assert residency[2] <= residency[1] / 2 + 1
    assert residency[4] <= residency[1] / 4 + 1


# ------------------------------------------------------- crash recovery


def test_journal_replay_readmits_into_warm_sharded_server(tmp_path,
                                                          small_index,
                                                          embedder):
    """Journal a shard-mode run, cut it mid-flight, and re-admit the
    unfinished rows into a warm shard-mode server: everything completes,
    shard routing preserved."""
    p = str(tmp_path / "journal.jsonl")
    s1 = _server(small_index, embedder, nw=2, sharding=True, preserve=True)
    _load(s1, ALL_WORKFLOWS, n=10, rate=20.0)
    # advance far enough that some requests finished and some are in flight
    horizon = 0.0
    while not (s1.sched.done and (s1.sched.active or s1.sched.pending)):
        horizon += 50_000.0
        s1.step(horizon)
        assert horizon < 60e6, "never reached a mixed done/in-flight state"
    s1.write_journal(p)
    rows = Server.replay_unfinished(p)
    assert rows and len(rows) < 10

    # warm replacement server: some native traffic already admitted, clock
    # advanced, then the journal rows land on top
    s2 = _server(small_index, embedder, nw=2, sharding=True, preserve=True)
    s2.add_request("native", workflows.build("one-shot"), arrival_us=0.0)
    s2.step(1000.0)
    ids = s2.readmit(rows)
    assert all(i is not None for i in ids)
    m2 = s2.run()
    assert m2.finished == 1 + len(rows)
    assert m2.shard_scatters > 0  # recovered requests scatter like fresh ones
    rep = s2.shard_report()
    assert rep["n_shards"] == 2
    # re-admissions honored the warm clock: beyond the carried pre-crash
    # event prefix, no post-restart event precedes re-admission
    done_by_input = {r.state["input"]: r for r in s2.sched.done}
    for row in rows:
        r = done_by_input[row["input"]]
        assert all(t >= 1000.0 for t, _, _ in r.events[len(row["events"]):])
    # recovered requests produce the same retrieval results as the cut run
    # would have: spot-check against the full-search oracle (pure dense
    # retrieval classes only — hybrid/registry stages rescore "docs")
    for row in rows:
        r = done_by_input[row["input"]]
        if "docs" in r.state and r.round_idx > 0:
            node = next((n for n in r.graph.nodes.values()
                         if n.kind == "retrieval" and n.output == "docs"
                         and n.lexical_weight == 0.0), None)
            if node is None:
                continue
            qv = embedder.embed_query(r.request_id, r.round_idx - 1)
            _, ids_ref = small_index.search(qv[None], 12, node.topk or 5)
            assert r.state["docs"] == [int(i) for i in ids_ref[0] if i >= 0]
