"""Per-architecture smoke + decode/train consistency tests (reduced configs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm


def _batch(cfg, B=2, S=24, seed=0):
    k = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(k, (B, S), 1, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jnp.full((B, cfg.n_prefix_embeds, cfg.d_model), 0.01)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.full((B, cfg.encoder_seq, cfg.d_model), 0.01)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """Reduced config: one forward/train step on CPU, finite loss + grads."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, S=32)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: lm.train_loss(p, cfg, batch)))(params)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    gnorm = sum(jnp.sum(jnp.abs(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm), f"{arch}: grads not finite"
    assert float(loss) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode logits must match the full-sequence forward —
    the core serving-correctness invariant (KV caches, ring buffers, MLA
    absorbed decode, RWKV/RG-LRU recurrences all covered)."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, P, extra = 2, 16, 4
    S = P + extra
    batch = _batch(cfg, B=B, S=S, seed=2)
    tokens = batch["tokens"]

    # reference: full forward logits at each position
    h, _, n_prefix = lm._forward(
        cfg, params, tokens, mode="train",
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"),
    )
    if n_prefix:
        h = h[:, n_prefix:, :]
    ref_logits = (h @ lm._head_weights(cfg, params)).astype(jnp.float32)

    logits, state = lm.prefill(
        params, cfg, tokens[:, :P], max_len=S + cfg.n_prefix_embeds + 4,
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"),
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref_logits[:, P - 1]), rtol=2e-2, atol=2e-2,
    )
    for i in range(extra):
        logits, state = lm.decode_step(params, cfg, tokens[:, P + i], state)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits[:, P + i]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode step {i} diverges from forward",
        )


def test_chunked_xent_matches_dense():
    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, S=32)
    loss_chunked = lm.train_loss(params, cfg, batch)
    import dataclasses

    cfg2 = dataclasses.replace(cfg, loss_chunk=32)
    loss_dense = lm.train_loss(params, cfg2, batch)
    np.testing.assert_allclose(float(loss_chunked), float(loss_dense), rtol=1e-5)


def test_rwkv_chunk_vs_decode_recurrence():
    """Chunked parallel WKV must equal the step recurrence exactly."""
    from repro.models import rwkv6

    cfg = get_config("rwkv6-1.6b").reduced()
    seg = cfg.segments[0]
    p = rwkv6.init_timemix(cfg, seg, jax.random.PRNGKey(3))
    B, S, d = 2, 32, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, d)) * 0.3
    out_par, _ = rwkv6.apply_timemix(cfg, seg, p, x, mode="train")
    st = rwkv6.timemix_init_state(cfg, B)
    outs = []
    for t in range(S):
        o, st = rwkv6.apply_timemix(cfg, seg, p, x[:, t : t + 1], mode="decode", state=st)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_par), np.asarray(out_seq),
                               rtol=5e-3, atol=5e-3)


def test_int8_kv_cache_decode():
    """int8 KV cache (§Perf B2): decode logits must track the bf16 path and
    keep greedy decisions identical on the tested horizon."""
    import dataclasses

    cfg = get_config("qwen3-1.7b").reduced()
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, P, extra = 2, 16, 4
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, P + extra), 1,
                                cfg.vocab_size)
    lf, sf = lm.prefill(params, cfg, tokens[:, :P], max_len=P + extra + 2)
    lq, sq = lm.prefill(params, cfg8, tokens[:, :P], max_len=P + extra + 2)
    for i in range(extra):
        lf, sf = lm.decode_step(params, cfg, tokens[:, P + i], sf)
        lq, sq = lm.decode_step(params, cfg8, tokens[:, P + i], sq)
        cos = float(jnp.sum(lf * lq) / (jnp.linalg.norm(lf) * jnp.linalg.norm(lq)))
        assert cos > 0.999, f"step {i}: cosine {cos}"
        assert bool(jnp.all(jnp.argmax(lf, -1) == jnp.argmax(lq, -1)))


def test_param_count_close_to_nominal():
    """Analytic parameter counts should be in the right ballpark of the
    nominal model sizes (loose: embeddings/heads dominate small models)."""
    nominal = {
        "rwkv6-1.6b": 1.6e9, "qwen3-1.7b": 1.7e9, "phi3-mini-3.8b": 3.8e9,
        "stablelm-12b": 12e9, "qwen1.5-110b": 111e9,
        "recurrentgemma-2b": 2.7e9, "whisper-medium": 0.77e9,
        "deepseek-v2-lite-16b": 16e9, "llama4-scout-17b-a16e": 109e9,
        "paligemma-3b": 2.6e9,
    }
    for arch, n in nominal.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 1.9 * n, f"{arch}: {got/1e9:.2f}B vs nominal {n/1e9:.1f}B"
