"""Wall-clock ingress and its replay oracle.

The contract under test: a threaded wall-clock serve records an
arrival/heartbeat trace, and mechanically re-applying that trace on a
fresh server over the pure virtual clock reproduces bit-identical
per-request event fingerprints — including chaos runs with a FaultPlan
armed.  Plus the three bugfix regressions that ride along: tied-arrival
heap ordering, non-monotonic heartbeat/tick guards, and shed/readmit
counter conservation."""
import json

import pytest

from repro import workflows
from repro.server import Server
from repro.serving import ingress
from repro.serving.faults import FaultPlan
from repro.serving.ingress import (
    ArrivalTrace,
    IngressQueue,
    ReplayDivergence,
    Ticket,
    WallClock,
    replay_trace,
)
from repro.serving.lifecycle import HEALTHY, SUSPECT, WorkerRegistry
from repro.serving.workload import MIXES, ClosedLoopSpec


def _server(index, emb, **kw):
    return Server(index, emb, mode="hedra", nprobe=8, **kw)


def _fingerprints(server):
    return server.fingerprints()


# ---------------------------------------------------------------------------
# Satellite 1: tied wall-clock arrivals replay in submission order
# ---------------------------------------------------------------------------


def test_tied_arrivals_keep_submission_order(small_index, embedder):
    """Two requests stamped with the *same* arrival instant must come off
    the pending heap in submission order.  Request ids are allocated at
    build time — before admission — so a heap keyed (arrival, request_id)
    would replay the pair id-ordered even when the later-built request was
    submitted first.  The ingress sequence number pins submission order."""
    s = _server(small_index, embedder)
    g = workflows.build("one-shot")
    first = s.build_request("a", g, 0.0)   # rid 0, built first
    second = s.build_request("b", g, 0.0)  # rid 1, built second
    assert (first.request_id, second.request_id) == (0, 1)
    # submit in the *reverse* of id order, at an exactly tied arrival
    assert s.submit_built(second) == 1
    assert s.submit_built(first) == 0
    assert [r.request_id for r in s.sched.pending] == [1, 0]
    assert second.ingress_seq < first.ingress_seq
    m = s.run()
    assert m.finished == 2
    # the tie-broken order is observable: rid 1 entered service first
    done = {r.request_id: r for r in s.sched.done}
    assert done[1].events[0][0] <= done[0].events[0][0]


def test_tied_arrivals_replay_identically(small_index, embedder):
    """The same tied pair produces identical fingerprints when re-run."""

    def run():
        s = _server(small_index, embedder)
        g = workflows.build("one-shot")
        a = s.build_request("a", g, 0.0)
        b = s.build_request("b", g, 0.0)
        s.submit_built(b)
        s.submit_built(a)
        s.run()
        return _fingerprints(s)

    assert run() == run()


# ---------------------------------------------------------------------------
# Satellite 2: wall heartbeats are monotonic-safe
# ---------------------------------------------------------------------------


def test_heartbeat_never_regresses_on_backward_stamp():
    reg = WorkerRegistry(2, external_heartbeats=True)
    reg.heartbeat(0, 100_000.0)
    reg.heartbeat(0, 40_000.0)  # injected backward step: must clamp
    assert reg.workers[0].last_heartbeat_us == 100_000.0


def test_tick_clamps_non_monotonic_now():
    """A regressed tick timestamp must neither compute negative gaps nor
    demote freshly-heartbeaten workers."""
    reg = WorkerRegistry(2, external_heartbeats=True,
                         suspect_after_us=150_000.0)
    reg.heartbeat(0, 400_000.0)
    reg.heartbeat(1, 400_000.0)
    assert reg.tick(450_000.0) == []
    # clock steps backward: the tick is clamped to the high-water mark and
    # nothing transitions
    assert reg.tick(10_000.0) == []
    assert reg.state_of(0) == HEALTHY and reg.state_of(1) == HEALTHY
    # real gaps still drive SUSPECT once time genuinely advances
    out = reg.tick(700_000.0)
    assert {w for w, _old, new in out if new == SUSPECT} == {0, 1}


def test_wallclock_high_water_mark_survives_regressing_source():
    ticks = iter([0.0, 1.0, 0.5, 2.0])
    clk = WallClock(speedup=1.0, source=lambda: next(ticks))
    assert clk.now_us() == pytest.approx(1e6)
    assert clk.now_us() == pytest.approx(1e6)  # regressed source: clamped
    assert clk.now_us() == pytest.approx(2e6)


# ---------------------------------------------------------------------------
# Satellite 3: shed/readmit counter conservation, journal-at-most-once
# ---------------------------------------------------------------------------


def test_readmit_counter_conservation(small_index, embedder, tmp_path):
    journal = tmp_path / "journal.jsonl"
    s = _server(small_index, embedder, admission_control=True, max_pending=1,
                journal_path=str(journal))
    g = workflows.build("one-shot")
    offered = [s.build_request(f"q{i}", g, 0.0) for i in range(4)]
    admitted = [r for r in offered if s.submit_built(r) is not None]
    shed = [r for r in offered if "_shed" in r.state]
    assert len(shed) >= 1
    m = s.sched.metrics
    assert m.shed == len(shed)
    assert m.submitted == len(admitted)
    # failed re-attempt while still saturated: a resubmission, not a second
    # shed of the same logical request
    victim = shed[0]
    assert s.readmit_request(victim) is None
    assert m.shed == len(shed)
    assert m.resubmissions == 1
    # drain, then the re-admission lands: counted once as shed_readmitted
    s.run()
    assert s.readmit_request(victim) is not None
    assert m.shed_readmitted == 1
    assert m.resubmissions == 2
    assert "_shed" not in victim.state
    m2 = s.run()
    # conservation: offered = submitted + shed_final; all submitted finished
    assert m2.submitted == m2.finished
    assert m2.submitted + m2.shed_final == len(offered)
    summary = m2.summary()
    assert summary["shed_readmitted"] == 1
    assert summary["shed_final"] == m2.shed - 1
    # journal sees the readmitted request exactly once
    rows = [json.loads(ln) for ln in
            journal.read_text().strip().splitlines()]
    rids = [r["request_id"] for r in rows]
    assert rids.count(victim.request_id) == 1
    assert sorted(rids) == sorted({r.request_id for r in offered
                                   if "_shed" not in r.state})


def test_batch_shed_metrics_unchanged(small_index, embedder):
    """With no re-admission the new counters stay zero and the original
    shed accounting is untouched."""
    s = _server(small_index, embedder, admission_control=True, max_pending=1)
    g = workflows.build("one-shot")
    for i in range(4):
        s.add_request(f"q{i}", g, arrival_us=0.0)
    m = s.run()
    assert m.resubmissions == 0 and m.shed_readmitted == 0
    assert m.shed_final == m.shed > 0


# ---------------------------------------------------------------------------
# The tentpole: wall-clock serving replays bit-identically
# ---------------------------------------------------------------------------


def test_wallclock_heterogeneous_replays_bit_identically(small_index,
                                                         embedder):
    mix = MIXES["heterogeneous"]
    stream = mix.sample(16, rate_per_s=200.0, seed=3)

    def mk():
        return _server(small_index, embedder, workload=mix.profile(),
                       external_heartbeats=True, fault_tolerance=True,
                       num_ret_workers=2)

    s1 = mk()
    m1, trace = s1.serve_wallclock(stream, speedup=1000.0, max_wall_s=90.0)
    assert m1.finished == 16
    kinds = {r.kind for r in trace.rows}
    assert "arrival" in kinds and "heartbeat" in kinds
    # wall stamps were applied effectively: rows are time-ordered
    ts = [r.t_us for r in trace.rows]
    assert ts == sorted(ts)
    s2 = mk()
    m2 = replay_trace(s2, trace)
    assert m2.finished == 16
    assert _fingerprints(s2) == _fingerprints(s1)
    assert m2.summary() == m1.summary()


def test_wallclock_chaos_replays_bit_identically(small_index, embedder):
    """The fault-injected variant: heartbeat pump mirrors the plan, the
    recovery path runs under wall time, and the replay still matches."""
    mix = MIXES["heterogeneous"]
    stream = mix.sample(12, rate_per_s=150.0, seed=7)

    def mk():
        plan = FaultPlan.random(5, 3, 800_000.0, crash_frac=0.4,
                                stall_rate=2e-6, transient_prob=0.1)
        return _server(small_index, embedder, workload=mix.profile(),
                       fault_plan=plan, num_ret_workers=3)

    s1 = mk()
    m1, trace = s1.serve_wallclock(stream, speedup=1000.0, max_wall_s=90.0)
    assert m1.finished >= 1
    s2 = mk()
    replay_trace(s2, trace)
    assert _fingerprints(s2) == _fingerprints(s1)


def test_closed_loop_budget_and_replay(small_index, embedder):
    mix = MIXES["balanced"]
    spec = ClosedLoopSpec.from_mix(mix, num_clients=3, requests_per_client=6,
                                   think_time_s=0.01, token_budget=900,
                                   est_tokens_mean=160.0)
    # the budget binds well below the raw 18-request plan
    full = sum(d.est_tokens for c in range(spec.num_clients)
               for d in spec.plan(c))
    assert full > spec.token_budget

    def mk():
        return _server(small_index, embedder, workload=mix.profile())

    s1 = mk()
    m1, trace = s1.serve_wallclock(closed_loop=spec, speedup=800.0,
                                   max_wall_s=90.0)
    n_arrivals = sum(1 for r in trace.rows if r.kind == "arrival")
    assert 0 < n_arrivals < spec.num_clients * spec.requests_per_client
    assert m1.finished == n_arrivals
    s2 = mk()
    replay_trace(s2, trace)
    assert _fingerprints(s2) == _fingerprints(s1)


def test_closed_loop_plan_is_deterministic():
    spec = ClosedLoopSpec(weights={"one-shot": 1.0, "hyde": 2.0},
                          num_clients=2, requests_per_client=5, seed=3)
    assert spec.plan(0) == spec.plan(0)
    assert spec.plan(0) != spec.plan(1)


def test_trace_json_round_trip_replays(small_index, embedder):
    mix = MIXES["pure-oneshot"]
    stream = mix.sample(6, rate_per_s=300.0, seed=1)

    def mk():
        return _server(small_index, embedder, workload=mix.profile())

    s1 = mk()
    _, trace = s1.serve_wallclock(stream, speedup=1000.0, max_wall_s=60.0)
    rt = ArrivalTrace.from_dict(json.loads(trace.to_json()))
    s2 = mk()
    replay_trace(s2, rt)
    assert _fingerprints(s2) == _fingerprints(s1)


def test_tampered_trace_raises_divergence(small_index, embedder):
    mix = MIXES["pure-oneshot"]
    stream = mix.sample(4, rate_per_s=300.0, seed=2)

    def mk():
        return _server(small_index, embedder, workload=mix.profile())

    s1 = mk()
    _, trace = s1.serve_wallclock(stream, speedup=1000.0, max_wall_s=60.0)
    bad = ArrivalTrace.from_dict(trace.to_dict())
    row = next(r for r in bad.rows if r.kind == "arrival")
    row.admitted = False  # claim the scheduler shed it — it won't
    with pytest.raises(ReplayDivergence):
        replay_trace(mk(), bad)


def test_duration_tape_primitives():
    tape = ingress.DurationTape()
    tape.record("gen", 120.5)
    tape.record("search", 40.0)
    assert tape.next("gen") == 120.5
    with pytest.raises(ReplayDivergence):
        tape.next("stage")  # recorded "search" at this position
    rt = ingress.DurationTape.from_dict(tape.to_dict())
    assert rt.rows == tape.rows
    assert rt.next("gen") == 120.5 and rt.next("search") == 40.0
    with pytest.raises(ReplayDivergence):
        rt.next("gen")  # exhausted
    rt.rewind()
    assert rt.remaining() == 2


def test_duration_tape_makes_nondeterministic_backend_replayable(
        small_index, embedder):
    """A measured backend re-times itself on every pass, so the arrival
    trace alone cannot replay it.  Stand-in here: SimBackend instances
    with *different* noise seeds, whose gen charges genuinely differ
    run-to-run.  Taping the wall run's charges and replaying them into
    the mismatched replica must restore bit-identical fingerprints."""
    from repro.core.backends import SimBackend

    mix = MIXES["balanced"]
    stream = mix.sample(8, rate_per_s=200.0, seed=11)

    def mk(seed):
        return _server(small_index, embedder, workload=mix.profile(),
                       backend=SimBackend(small_index, embedder, seed=seed))

    tape = ingress.DurationTape()
    s1 = mk(seed=1)
    ingress.tape_backend(s1.backend, tape, mode="record")
    m1, trace = s1.serve_wallclock(stream, speedup=1000.0, max_wall_s=60.0)
    assert m1.finished == 8
    assert tape.rows, "no backend charges were recorded"

    # control: without the tape, the seed-99 replica's noise stream
    # diverges the virtual timeline (the test would otherwise be vacuous)
    bare = mk(seed=99)
    replay_trace(bare, trace)
    assert _fingerprints(bare) != _fingerprints(s1)

    s2 = mk(seed=99)
    ingress.tape_backend(s2.backend, tape, mode="replay")
    replay_trace(s2, trace)
    assert _fingerprints(s2) == _fingerprints(s1)
    assert tape.remaining() == 0, "replay consumed a different call count"


def test_wall_telemetry_track(small_index, embedder):
    mix = MIXES["pure-oneshot"]
    stream = mix.sample(6, rate_per_s=200.0, seed=4)
    s = _server(small_index, embedder, workload=mix.profile(),
                telemetry=True)
    s.serve_wallclock(stream, speedup=800.0, max_wall_s=60.0)
    tel = s.sched.telemetry
    snap = tel.snapshot()
    assert snap["wall_timeline"], "ingress loop never sampled the wall track"
    for row in snap["wall_timeline"]:
        assert row["drift_us"] == row["wall_us"] - row["virtual_us"]
    rows = snap["metrics"]["repro_ingress_rows_total"]["samples"]
    applied = {r["labels"]["kind"]: r["value"] for r in rows}
    assert applied["arrival"] == 6


# ---------------------------------------------------------------------------
# Ingress primitives
# ---------------------------------------------------------------------------


def test_ingress_queue_orders_and_bounds():
    q = IngressQueue(maxsize=2)
    assert q.put("arrival", 1.0, text="a") == 0
    assert q.put("arrival", 1.0, text="b") == 1
    # full: a bounded put times out instead of dropping silently
    assert q.put("arrival", 2.0, text="c", timeout_s=0.01) is None
    items = q.drain()
    assert [i.seq for i in items] == [0, 1]
    assert q.put("arrival", 3.0, text="d") == 2  # seq space keeps growing
    q.close()
    assert q.put("arrival", 4.0) is None  # closed queue admits nothing


def test_ticket_resolution():
    t = Ticket()
    assert not t.wait(timeout_s=0.01)
    t.resolve("finished", request_id=7, finish_us=10.0, latency_us=3.0)
    assert t.wait(timeout_s=1.0)
    assert (t.status, t.request_id) == ("finished", 7)
