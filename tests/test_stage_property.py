"""Property suite for the stage registry: random workflow graphs composed
from every registered stage kind round-trip through the scheduler's
split/merge machinery with their declared inputs/outputs respected, and the
generic Eq.(1) unit-sizing rule partitions any work queue losslessly.

Runs under hypothesis when it is installed (CI installs it explicitly);
otherwise falls back to a fixed seeded sweep of the same properties so the
suite never silently skips."""
import numpy as np
import pytest

from repro import workflows
from repro.core import stages
from repro.core.backends import SimBackend
from repro.core.ragraph import END, START, RAGraph
from repro.core.substage import TimeBudget
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # local envs without hypothesis: seeded sweep instead
    HAVE_HYPOTHESIS = False

RET_HEAVY = ClusterCostModel(fixed_us=150.0, per_vector_us=8.0,
                             per_query_us=2.0)
FALLBACK_SEEDS = list(range(24))


def _property(n_examples):
    """Decorator: hypothesis-driven seeds when available, a fixed
    parametrized sweep otherwise.  The wrapped test takes ``seed`` last."""
    if HAVE_HYPOTHESIS:
        return lambda fn: settings(
            max_examples=n_examples, deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )(given(seed=st.integers(0, 2**32 - 1))(fn))
    return lambda fn: pytest.mark.parametrize(
        "seed", FALLBACK_SEEDS[:n_examples])(fn)


# ---------------------------------------------------------------------------
# Eq.(1) unit sizing: lossless in-order partition of any stage work queue
# ---------------------------------------------------------------------------


@_property(60)
def test_units_for_budget_partitions_queue(seed):
    rng = np.random.default_rng(seed)
    budget = TimeBudget(beta_us=float(rng.uniform(10.0, 500.0)),
                        t_retrieval_us=float(rng.uniform(500.0, 60_000.0)))
    costs = [float(c) for c in rng.uniform(1.0, 4000.0,
                                           size=int(rng.integers(0, 40)))]
    queue = list(costs)
    chunks = []
    while queue:
        n = budget.units_for_budget(queue)
        assert n >= 1  # progress is always guaranteed
        chunks.append(queue[:n])
        queue = queue[n:]
    # split is a lossless in-order partition (merge == concatenation)
    assert [c for ch in chunks for c in ch] == costs
    mb = budget.mb_us
    for i, ch in enumerate(chunks):
        used = ch[0]
        for c in ch[1:]:  # units beyond the first fit the budget...
            assert used + c <= mb
            used += c
        if i + 1 < len(chunks):  # ...and each chunk is maximal
            assert used + chunks[i + 1][0] > mb


# ---------------------------------------------------------------------------
# Random stage graphs round-trip through the scheduler
# ---------------------------------------------------------------------------


def _random_graph(rng) -> RAGraph:
    """A random linear workflow over all registered kinds whose dataflow is
    valid by construction: doc-consuming stages (rerank/compress) only
    appear once some stage has produced a doc list."""
    g = RAGraph("random")
    text_keys = ["input"]  # embeddable query sources
    doc_keys = []  # doc-id list outputs
    n_mid = int(rng.integers(1, 5))
    nid = 0
    for _ in range(n_mid):
        choices = ["retrieval", "rewrite", "generation"]
        if doc_keys:
            choices += ["rerank", "compress"]
        kind = choices[int(rng.integers(len(choices)))]
        out = f"k{nid}"
        if kind == "retrieval":
            g.add_retrieval(nid, query=text_keys[int(rng.integers(
                len(text_keys)))], output=out,
                topk=int(rng.integers(3, 12)),
                lexical_weight=float(rng.choice([0.0, 0.5])))
            doc_keys.append(out)
        elif kind == "rewrite":
            g.add_rewrite(nid, query=text_keys[int(rng.integers(
                len(text_keys)))], output=out,
                n_queries=int(rng.integers(2, 4)),
                topk=int(rng.integers(3, 8)))
            doc_keys.append(out)
        elif kind == "rerank":
            g.add_rerank(nid, docs=doc_keys[int(rng.integers(
                len(doc_keys)))], output=out,
                keep=int(rng.integers(1, 6)),
                block=int(rng.integers(2, 6)))
            doc_keys.append(out)
        elif kind == "compress":
            g.add_compress(nid, docs=doc_keys[int(rng.integers(
                len(doc_keys)))], output=out,
                ratio=float(rng.uniform(0.2, 0.9)),
                block=int(rng.integers(2, 6)))
            doc_keys.append(out)
        else:
            src = (text_keys + doc_keys)[int(rng.integers(
                len(text_keys) + len(doc_keys)))]
            g.add_generation(nid, prompt=f"Expand {{{src}}}.", output=out,
                             max_tokens=32)
            text_keys.append(out)
        g.add_edge(START if nid == 0 else nid - 1, nid)
        nid += 1
    final_src = doc_keys[int(rng.integers(len(doc_keys)))] if doc_keys \
        else text_keys[-1]
    g.add_generation(nid, prompt=f"Answer {{input}} using {{{final_src}}}.",
                     output="answer", max_tokens=32)
    g.add_edge(nid - 1, nid)
    g.add_edge(nid, END)
    return g


@_property(20)
def test_random_stage_graphs_roundtrip(small_index, embedder, seed):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng)
    g.validate()  # valid-by-construction graphs must pass validation
    mode = ["hedra", "async", "sequential"][int(rng.integers(3))]
    be = SimBackend(small_index, embedder, cost_model=RET_HEAVY, seed=0)
    s = Server(small_index, embedder, mode=mode, backend=be, nprobe=8,
               topk=5)
    n = 3
    for i in range(n):
        s.add_request(f"q{i}", g, arrival_us=float(i) * 1e4)
    m = s.run()
    assert m.finished == n, f"{mode} finished {m.finished} of {n}"
    host_kinds = {"rerank", "rewrite", "compress"}
    graph_host = {nd.kind for nd in g.nodes.values()} & host_kinds
    for r in s.sched.done:
        # every node's declared output materialised in the final state
        for nd in g.nodes.values():
            assert nd.output in r.state, (nd.kind, nd.output)
            if nd.kind in ("retrieval", "rewrite", "rerank", "compress"):
                docs = r.state[nd.output]
                assert docs and all(isinstance(d, int) for d in docs)
        assert r.state["answer"]
        # host registry stages really entered the split/merge machinery
        entered = {e.split("_stage_start")[0] for _, e, _p in r.events
                   if e.endswith("_stage_start")}
        assert graph_host <= entered


@_property(20)
def test_random_graph_validation_catches_broken_dataflow(seed):
    """Breaking a valid random graph (dangling read) must be rejected."""
    rng = np.random.default_rng(seed)
    g = _random_graph(rng)
    bad = RAGraph("broken")
    bad.nodes = dict(g.nodes)
    bad.edges = {k: list(v) for k, v in g.edges.items()}
    bad.add_generation(999, prompt="Use {never_produced}.", output="x")
    last = max(n for n in g.nodes)
    bad.edges[last] = [999]
    bad.add_edge(999, END)
    with pytest.raises(ValueError, match="never_produced"):
        bad.validate()
