"""Quickstart: the paper's Listing-1 API end to end (sim-time backend).

Builds a synthetic corpus + IVF index, constructs two RAG workflows with the
graph primitives, serves a small batch of requests with the full HedraRAG
runtime, and prints the metrics that matter (latency, speculation, cache).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.backends import SimBackend
from repro.core.ragraph import END, START, RAGraph
from repro.retrieval import CorpusConfig, IVFIndex, SyntheticEmbedder, make_corpus
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.workload import poisson_arrivals


def main() -> None:
    # --- corpus + index (stands in for Wikipedia + e5 embeddings) ----------
    docs, _, topics = make_corpus(CorpusConfig(n_docs=20_000, dim=64,
                                               n_topics=128, zipf_alpha=1.25))
    index = IVFIndex.build(docs, n_clusters=64, iters=5)
    embedder = SyntheticEmbedder(topics)

    # --- Listing 1: HyDE-style workflow ------------------------------------
    g1 = RAGraph("hyde")
    g1.add_generation(0, prompt="Generate a hypothesis for {input}.",
                      output="hypopara")
    g1.add_retrieval(1, topk=5, query="hypopara", output="docs")
    g1.add_generation(2, prompt="Answer {input} using {docs}.")
    g1.add_edge(START, 0); g1.add_edge(0, 1)
    g1.add_edge(1, 2); g1.add_edge(2, END)

    # --- Listing 1: Multistep-style workflow with a conditional loop -------
    g2 = RAGraph("multistep")
    g2.add_generation(0, prompt="Decompose {input} into subquestions.",
                      output="subquestion")
    g2.add_retrieval(1, topk=2, query="subquestion", output="docs")
    g2.add_generation(2, prompt="Answer {subquestion} using {docs}.")
    g2.add_edge(START, 0); g2.add_edge(0, 1); g2.add_edge(1, 2)

    def loop(s):
        s["_round"] = s.get("_round", 0) + 1
        return 1 if s["_round"] < s.get("_target_rounds", 2) else END

    g2.add_edge(2, loop)

    # --- server -------------------------------------------------------------
    backend = SimBackend(index, embedder,
                         cost_model=ClusterCostModel(fixed_us=150, per_vector_us=8))
    server = Server(index, embedder, mode="hedra", backend=backend, nprobe=16)
    for i, t in enumerate(poisson_arrivals(5.0, 24, seed=1)):
        server.add_request(f"What is RAG? (v{i})", g1 if i % 2 == 0 else g2,
                           arrival_us=t)

    metrics = server.run()
    print("== HedraRAG quickstart ==")
    for k, v in metrics.summary().items():
        print(f"  {k:24s} {v}")
    done = server.sched.done[0]
    print("sample request state keys:", sorted(done.state.keys()))
    print("sample retrieved docs:", done.state.get("docs"))


if __name__ == "__main__":
    main()
