"""Training driver example: train a ~20M-param qwen3-family model for a few
hundred steps on the synthetic token stream, with checkpoint/restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import lm
from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.data import SyntheticTokenStream
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b").reduced(
        d_model=256, d_ff=1024, n_heads=8, d_head=32, vocab_size=2048,
        n_layers=4,
        segments=tuple(
            s for s in get_config("qwen3-1.7b").reduced().segments
        ) * 4,
    )
    shape = ShapeConfig("example", seq_len=128, global_batch=8, kind="train")
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        start, state, _ = restore_checkpoint(
            args.ckpt_dir, like={"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=20,
                                                     total_steps=args.steps)))
    ds = SyntheticTokenStream(cfg, shape)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: np.asarray(v) for k, v in ds.batch_at(step).items()}
        loss, params, opt, stats = step_fn(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"lr {float(stats['lr']):.2e} "
                  f"gnorm {float(stats['grad_norm']):.2f} "
                  f"({(time.time()-t0):.1f}s)")
        if step and step % 100 == 0:
            save_checkpoint(args.ckpt_dir, step, {"params": params, "opt": opt})
    save_checkpoint(args.ckpt_dir, args.steps, {"params": params, "opt": opt})
    print("done; checkpoint saved")


if __name__ == "__main__":
    main()
