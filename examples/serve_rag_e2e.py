"""End-to-end REAL-execution driver: a tiny JAX LM served with continuous
batching + real IVF retrieval through the HedraRAG scheduler (wall-clock).

Everything actually executes: prompts are tokenised (toy byte tokenizer),
the GenerationEngine decodes real tokens from a randomly-initialised reduced
qwen3 model, retrieval runs against the IVF index with the hot-cluster cache
(jnp kernel-ref path), and the wavefront scheduler coordinates both.

Run:  PYTHONPATH=src python examples/serve_rag_e2e.py
      PYTHONPATH=src python examples/serve_rag_e2e.py --crossreq   # + the
      cross-request layer: global semantic cache, in-flight query dedup
      (duplicate prompts fuse into one retrieval), replica routing knobs
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core.backends import RealBackend
from repro.models import lm
from repro.retrieval import (
    CorpusConfig,
    HybridRetrievalEngine,
    IVFIndex,
    SyntheticEmbedder,
    make_corpus,
)
from repro.server import Server
from repro.serving.engine import GenerationEngine
from repro import workflows


def tokenize(text: str, vocab: int) -> np.ndarray:
    return (np.frombuffer(text.encode(), dtype=np.uint8).astype(np.int32)
            % (vocab - 2)) + 1


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--crossreq", action="store_true",
                    help="enable the cross-request layer (global semantic "
                         "cache + in-flight query dedup/fusion + replica "
                         "routing knobs)")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the example smoke test")
    args = ap.parse_args(argv)

    n_docs, n_clusters, max_len = (2_000, 12, 96) if args.smoke else (8_000, 32, 192)
    docs, _, topics = make_corpus(CorpusConfig(n_docs=n_docs, dim=48,
                                               n_topics=64))
    index = IVFIndex.build(docs, n_clusters=n_clusters, iters=4)
    embedder = SyntheticEmbedder(topics)
    hybrid = HybridRetrievalEngine(index, cache_capacity=8, update_interval=10,
                                   kernel_impl="ref")

    cfg = get_config("qwen3-1.7b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = GenerationEngine(cfg, params, max_batch=8, max_len=max_len,
                              eos_id=0)

    backend = RealBackend(engine, index, embedder, hybrid=hybrid)

    # bind engine sequences to scheduler generation stages: the scheduler's
    # sub-stage calls engine.step_batch; sequences are admitted on stage start
    orig_gen_duration = backend.gen_duration

    def gen_duration(n_prefill_tokens, batch, n_steps):
        while engine.can_admit() and _pending_prompts:
            prompt = _pending_prompts.pop(0)
            engine.add_sequence(tokenize(prompt, cfg.vocab_size), max_new=24)
        return orig_gen_duration(n_prefill_tokens, batch, n_steps)

    backend.gen_duration = gen_duration
    _pending_prompts: list[str] = []

    crossreq_kw = {}
    if args.crossreq:
        # replication needs a worker pool (> 1) to have replica holders
        crossreq_kw = dict(global_cache_size=64, dedup_threshold=0.95,
                           replication_factor=2, num_ret_workers=2)
    server = Server(index, embedder, mode="hedra", backend=backend, nprobe=8,
                    **crossreq_kw)
    n = args.n_requests
    queries = [f"what is retrieval augmented generation {i}?" for i in range(n)]
    for i, q in enumerate(queries):
        _pending_prompts.append(q)
        server.add_request(q, workflows.build("one-shot" if i % 2 else "hyde"),
                           arrival_us=i * 30_000.0)

    t0 = time.perf_counter()
    metrics = server.run()
    wall = time.perf_counter() - t0
    print("== real-execution RAG serving ==")
    print(f"wall time: {wall:.2f}s; engine generated real tokens via JAX decode")
    for k, v in metrics.summary().items():
        print(f"  {k:24s} {v}")
    print("hot-cache stats:", hybrid.stats())
    if args.crossreq:
        print("crossreq report:", server.crossreq_report())
    assert metrics.finished == n, f"finished {metrics.finished}/{n}"


if __name__ == "__main__":
    main()
