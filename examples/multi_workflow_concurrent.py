"""Concurrent heterogeneous workflows (paper Fig. 14 scenario as an example):
all five workflow types interleaved at a high arrival rate, with the hot
cluster cache and speculation on, including a mid-run straggler injection.

Run:  PYTHONPATH=src python examples/multi_workflow_concurrent.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.backends import SimBackend
from repro.retrieval import (
    CorpusConfig,
    HybridRetrievalEngine,
    IVFIndex,
    SyntheticEmbedder,
    make_corpus,
)
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.workload import PROFILES, poisson_arrivals
from repro import workflows


def main() -> None:
    docs, _, topics = make_corpus(CorpusConfig(n_docs=30_000, dim=64,
                                               n_topics=192, zipf_alpha=1.3))
    index = IVFIndex.build(docs, n_clusters=96, iters=5)
    embedder = SyntheticEmbedder(topics, zipf_alpha=1.3)
    names = list(workflows.WORKFLOWS)

    for mode in ["async", "hedra"]:
        hybrid = None
        if mode == "hedra":
            hybrid = HybridRetrievalEngine(index, cache_capacity=16,
                                           update_interval=25, kernel_impl="ref")
        backend = SimBackend(
            index, embedder, hybrid=hybrid,
            cost_model=ClusterCostModel(fixed_us=150, per_vector_us=8),
            straggler_prob=0.05, straggler_factor=6.0,
        )
        server = Server(index, embedder, mode=mode, backend=backend,
                        nprobe=16, workload=PROFILES["hotpotqa"])
        for i, t in enumerate(poisson_arrivals(8.0, 60, seed=9)):
            server.add_request(f"q{i}", workflows.build(names[i % 5]),
                               arrival_us=t)
        m = server.run().summary()
        print(f"== {mode} ==")
        for k in ("avg_latency_ms", "p95_latency_ms", "throughput_rps",
                  "spec_gen_attempts", "spec_gen_validated", "early_terms",
                  "cache_answers", "straggler_redispatches"):
            print(f"  {k:24s} {m[k]}")
        if hybrid:
            print(f"  hot-cache hit rate       {hybrid.stats()['hit_rate']:.2f}")


if __name__ == "__main__":
    main()
