"""Concurrent heterogeneous workflows (paper Fig. 14 scenario as an example),
served through the *streaming* front-end: a sustained open-loop stream mixing
all five workflow types with per-class SLO tiers, submitted mid-run through
the admission layer (bounded in-system queue + deadline-infeasibility
shedding), with the hot cluster cache and speculation on and a mid-run
straggler injection.

Run:  PYTHONPATH=src python examples/multi_workflow_concurrent.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.backends import SimBackend
from repro.retrieval import (
    CorpusConfig,
    HybridRetrievalEngine,
    IVFIndex,
    SyntheticEmbedder,
    make_corpus,
)
from repro.retrieval.ivf import ClusterCostModel
from repro.server import Server
from repro.serving.workload import MIXES, PROFILES


def main() -> None:
    docs, _, topics = make_corpus(CorpusConfig(n_docs=30_000, dim=64,
                                               n_topics=192, zipf_alpha=1.3))
    index = IVFIndex.build(docs, n_clusters=96, iters=5)
    embedder = SyntheticEmbedder(topics, zipf_alpha=1.3)
    mix = MIXES["balanced"]
    workload = mix.profile(PROFILES["hotpotqa"])  # hop-heavy lengths + tiers
    stream = mix.sample(n=60, rate_per_s=8.0)

    for mode in ["async", "hedra"]:
        hybrid = None
        if mode == "hedra":
            hybrid = HybridRetrievalEngine(index, cache_capacity=16,
                                           update_interval=25, kernel_impl="ref")
        backend = SimBackend(
            index, embedder, hybrid=hybrid,
            cost_model=ClusterCostModel(fixed_us=150, per_vector_us=8),
            straggler_prob=0.05, straggler_factor=6.0,
        )
        server = Server(index, embedder, mode=mode, backend=backend,
                        nprobe=16, workload=workload,
                        max_pending=48, admission_control=True)
        # open-loop streaming: step the clock to each arrival, then submit
        for item in stream:
            server.step(item.arrival_us)
            server.submit(item.text, item.workflow, arrival_us=item.arrival_us)
        m = server.run().summary()
        print(f"== {mode} ==")
        for k in ("avg_latency_ms", "p95_latency_ms", "throughput_rps",
                  "steady_goodput_rps", "submitted", "shed",
                  "spec_gen_attempts", "spec_gen_validated", "early_terms",
                  "cache_answers", "straggler_redispatches"):
            print(f"  {k:24s} {m[k]}")
        if hybrid:
            print(f"  hot-cache hit rate       {hybrid.stats()['hit_rate']:.2f}")


if __name__ == "__main__":
    main()
