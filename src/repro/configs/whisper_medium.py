"""whisper-medium — enc-dec audio transformer (arXiv:2212.04356).

24L encoder + 24L decoder, d_model=1024, 16 heads MHA (d_head=64),
GELU MLP d_ff=4096, vocab 51865.  The conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (batch, frames, d_model); the encoder
runs bidirectional attention over them, decoder layers interleave causal
self-attention with cross-attention to the encoder output.
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51865,
    segments=(Segment(mixer="attn", ffn="gelu_mlp", repeat=24, cross_attn=True),),
    is_encoder_decoder=True,
    n_encoder_layers=24,
    encoder_segments=(Segment(mixer="encoder_attn", ffn="gelu_mlp", repeat=24),),
    encoder_seq=1500,  # 30 s of audio at 50 frames/s (stub embeddings)
    pos_emb="sinusoidal",
    norm_type="layernorm",
    act="gelu",
)
