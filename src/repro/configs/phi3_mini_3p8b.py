"""phi3-mini-3.8b — Phi-3-mini (arXiv:2404.14219): RoPE SwiGLU GQA.

32L, d_model=3072, 32 heads (kv=32 -> MHA, d_head=96), SwiGLU d_ff=8192,
vocab 32064.
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab_size=32064,
    segments=(Segment(mixer="attn", ffn="swiglu", repeat=32),),
    rope_theta=10000.0,
)
