"""qwen3-1.7b — Qwen3 family (hf:Qwen/Qwen3-*): qk_norm + GQA.

28L, d_model=2048, 16 heads (GQA kv=8, d_head=128), SwiGLU d_ff=6144,
vocab 151936, RoPE theta 1e6, per-head RMS qk-norm.
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=6144,
    vocab_size=151936,
    segments=(Segment(mixer="attn", ffn="swiglu", repeat=28),),
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
)
