"""deepseek-v2-lite-16b — MLA + fine-grained MoE (arXiv:2405.04434).

27L, d_model=2048, 16 heads, MLA kv_lora_rank=512 (rope 64 / nope 128 /
v 128), vocab 102400.  Layer 0 dense SwiGLU (d_ff=10944); layers 1..26 MoE
with 64 routed experts top-6 + 2 shared experts, expert d_ff=1408.

Assignment note: headline says "MoE 64e top-6", parenthetical "160 routed" is
the full V2 config — we follow the headline 64-routed Lite config (matches the
released model).  Recorded in DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,  # MLA: per-head latent expansion, kv head count == n_heads
    d_head=192,     # nope 128 + rope 64
    d_ff=10944,     # dense layer 0
    vocab_size=102400,
    segments=(
        Segment(mixer="mla", ffn="swiglu", repeat=1),
        Segment(mixer="mla", ffn="moe", repeat=26),
    ),
    kv_lora_rank=512,
    q_lora_rank=0,  # V2-Lite drops the q-lora projection
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
)
