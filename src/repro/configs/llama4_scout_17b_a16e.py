"""llama4-scout-17b-a16e — Llama-4 Scout MoE (hf:meta-llama/Llama-4-Scout-17B-16E).

48L, d_model=5120, 40 heads (GQA kv=8, d_head=128), vocab 202048.
Every layer: 16 routed experts top-1 + 1 shared expert, expert d_ff=8192.
Early-fusion multimodality is out of backbone scope (text tokens only here).
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=202048,
    segments=(Segment(mixer="attn", ffn="moe", repeat=48),),
    n_experts=16,
    n_shared_experts=1,
    moe_top_k=1,
    moe_d_ff=8192,
    rope_theta=500_000.0,
)
