"""Architecture registry: ``get_config(arch_id)`` / ``ARCH_IDS``."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    Segment,
    ShapeConfig,
    shape_applicable,
)

_MODULES = {
    "rwkv6-1.6b": "rwkv6_1p6b",
    "stablelm-12b": "stablelm_12b",
    "qwen3-1.7b": "qwen3_1p7b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "qwen1.5-110b": "qwen1p5_110b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-medium": "whisper_medium",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "paligemma-3b": "paligemma_3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


__all__ = [
    "ARCH_IDS",
    "get_config",
    "ModelConfig",
    "Segment",
    "ShapeConfig",
    "ALL_SHAPES",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "shape_applicable",
]
