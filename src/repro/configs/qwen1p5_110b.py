"""qwen1.5-110b — Qwen1.5 family (hf:Qwen/Qwen1.5-*): QKV bias.

80L, d_model=8192, 64 heads (GQA kv=8, d_head=128), SwiGLU d_ff=49152,
vocab 152064.
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49152,
    vocab_size=152064,
    segments=(Segment(mixer="attn", ffn="swiglu", repeat=80),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
