"""paligemma-3b — SigLIP + Gemma VLM (arXiv:2407.07726).

Gemma-2B text backbone: 18L, d_model=2048, 8 heads (MQA kv=1, d_head=256),
GeGLU d_ff=16384, vocab 257216.  The SigLIP vision tower is a STUB:
input_specs() provides 256 precomputed patch embeddings (batch, 256, d_model)
prepended as a prefix to the text tokens.
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab_size=257216,
    segments=(Segment(mixer="attn", ffn="geglu", repeat=18),),
    n_prefix_embeds=256,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
)
