"""Architecture + shape configuration system.

Every assigned architecture is expressed as a ``ModelConfig`` composed of
homogeneous ``Segment`` runs (mixer × ffn × repeat).  Segments are the unit of
``jax.lax.scan`` over layers: parameters inside a segment are stacked along a
leading layer axis, which keeps HLO size (and compile time) independent of
depth while still supporting heterogeneous stacks (hybrids, first-dense-then-
MoE, enc-dec).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------

MIXERS = ("attn", "local_attn", "mla", "rwkv6", "rglru", "encoder_attn", "cross_attn")
FFNS = ("swiglu", "gelu_mlp", "moe", "rwkv_cmix", "geglu")


@dataclasses.dataclass(frozen=True)
class Segment:
    """A run of ``repeat`` identical layers."""

    mixer: str
    ffn: str
    repeat: int
    cross_attn: bool = False  # decoder layers attending to encoder output

    def __post_init__(self):
        if self.mixer not in MIXERS:
            raise ValueError(f"unknown mixer {self.mixer!r}")
        if self.ffn not in FFNS:
            raise ValueError(f"unknown ffn {self.ffn!r}")


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    segments: tuple[Segment, ...]

    # --- attention options -------------------------------------------------
    pos_emb: str = "rope"  # rope | sinusoidal | none
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    local_window: int = 0  # sliding-window size for local_attn

    # --- MLA (DeepSeek-V2) --------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_noise: float = 0.0

    # --- RWKV6 --------------------------------------------------------------
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    rwkv_chunk: int = 64

    # --- RG-LRU (RecurrentGemma / Griffin) ----------------------------------
    lru_width: int = 0
    conv_width: int = 4

    # --- encoder-decoder ----------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # stub frontend sequence length (audio frames)
    encoder_segments: tuple[Segment, ...] = ()

    # --- multimodal prefix (VLM) --------------------------------------------
    n_prefix_embeds: int = 0  # precomputed patch embeddings prepended to text

    # --- serving ------------------------------------------------------------
    # 'bf16' (default) or 'int8': int8 stores KV with a per-(token, kv-head)
    # f32 scale — halves the decode memory stream (KIVI-style, beyond-paper
    # §Perf optimization)
    kv_cache_dtype: str = "bf16"

    # --- misc ---------------------------------------------------------------
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scaling
    dtype: str = "bfloat16"
    # attention implementation: q-chunk size for the online-softmax jnp path
    attn_q_chunk: int = 1024
    # cross-entropy loss seq-chunk size (bounds (B,S,V) logits materialisation)
    loss_chunk: int = 512
    remat: bool = True
    scan_layers: bool = True
    # unroll inner lax.scans (rwkv chunk loop) — used by the cost-analysis
    # depth variants because XLA cost_analysis counts while-loop bodies once
    unroll_scans: bool = False

    # ------------------------------------------------------------------ utils
    def __post_init__(self):
        n = sum(s.repeat for s in self.segments)
        if n != self.n_layers:
            raise ValueError(
                f"{self.name}: segments sum to {n} layers, expected {self.n_layers}"
            )
        if self.is_encoder_decoder:
            ne = sum(s.repeat for s in self.encoder_segments)
            if ne != self.n_encoder_layers:
                raise ValueError(
                    f"{self.name}: encoder segments sum to {ne}, expected "
                    f"{self.n_encoder_layers}"
                )

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def attention_free(self) -> bool:
        """True when no quadratic-in-sequence mixer exists (long-context OK)."""
        quad = {"attn", "mla", "encoder_attn"}
        return all(s.mixer not in quad for s in self.segments)

    @property
    def uses_moe(self) -> bool:
        return any(s.ffn == "moe" for s in self.segments)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs roofline)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        return _param_count(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=len(self.segments),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads)),
            d_head=16,
            d_ff=128,
            vocab_size=256,
            segments=tuple(dataclasses.replace(s, repeat=1) for s in self.segments),
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            q_lora_rank=0,
            rope_head_dim=8 if self.kv_lora_rank else 64,
            nope_head_dim=16 if self.kv_lora_rank else 128,
            v_head_dim=16 if self.kv_lora_rank else 128,
            n_experts=4 if self.n_experts else 0,
            moe_top_k=min(2, self.moe_top_k) if self.moe_top_k else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            # ample capacity: token drops are batch-shape-dependent, which
            # would break train-vs-decode consistency checks on tiny batches
            capacity_factor=8.0,
            n_shared_experts=min(1, self.n_shared_experts),
            rwkv_head_size=16,
            rwkv_decay_lora=8,
            rwkv_chunk=16,
            lru_width=64 if self.lru_width else 0,
            local_window=16 if self.local_window else 0,
            n_encoder_layers=len(self.encoder_segments),
            encoder_seq=8 if self.is_encoder_decoder else 0,
            encoder_segments=tuple(
                dataclasses.replace(s, repeat=1) for s in self.encoder_segments
            ),
            n_prefix_embeds=4 if self.n_prefix_embeds else 0,
            attn_q_chunk=32,
            loss_chunk=32,
            remat=False,
            dtype="float32",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # lm head
    seg_lists = [cfg.segments]
    if cfg.is_encoder_decoder:
        seg_lists.append(cfg.encoder_segments)
    for segs in seg_lists:
        for seg in segs:
            per_layer = 2 * d  # two norms
            # mixer
            if seg.mixer in ("attn", "local_attn", "encoder_attn"):
                per_layer += d * cfg.n_heads * cfg.d_head  # q
                per_layer += 2 * d * cfg.n_kv_heads * cfg.d_head  # k, v
                per_layer += cfg.n_heads * cfg.d_head * d  # o
            elif seg.mixer == "mla":
                qdim = cfg.nope_head_dim + cfg.rope_head_dim
                if cfg.q_lora_rank:
                    per_layer += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qdim
                else:
                    per_layer += d * cfg.n_heads * qdim
                per_layer += d * (cfg.kv_lora_rank + cfg.rope_head_dim)  # down
                per_layer += cfg.kv_lora_rank * cfg.n_heads * (
                    cfg.nope_head_dim + cfg.v_head_dim
                )  # up
                per_layer += cfg.n_heads * cfg.v_head_dim * d  # o
            elif seg.mixer == "rwkv6":
                per_layer += 4 * d * d + d * cfg.rwkv_decay_lora * 2  # r,k,v,g + decay lora
                per_layer += d * d  # output
            elif seg.mixer == "rglru":
                w = cfg.lru_width or d
                per_layer += 2 * d * w + w * d  # in x2 (branch+gate), out
                per_layer += cfg.conv_width * w + 2 * w  # conv + lru gates (approx)
            if seg.cross_attn:
                per_layer += d * cfg.n_heads * cfg.d_head * 2  # q, o
                per_layer += 2 * d * cfg.n_kv_heads * cfg.d_head  # k, v
                per_layer += d  # norm
            # ffn
            if seg.ffn == "swiglu" or seg.ffn == "geglu":
                per_layer += 3 * d * cfg.d_ff
            elif seg.ffn == "gelu_mlp":
                per_layer += 2 * d * cfg.d_ff
            elif seg.ffn == "rwkv_cmix":
                per_layer += 2 * d * cfg.d_ff + d * d
            elif seg.ffn == "moe":
                n_routed = cfg.moe_top_k if active_only else cfg.n_experts
                per_layer += 3 * d * cfg.moe_d_ff * n_routed
                per_layer += 3 * d * cfg.moe_d_ff * cfg.n_shared_experts
                per_layer += d * cfg.n_experts  # router
            total += per_layer * seg.repeat
    return total


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason).  long_500k only for sub-quadratic mixers."""
    if shape.name == "long_500k" and not cfg.attention_free:
        return False, "skipped: quadratic full attention at 500k context"
    return True, "ok"
