"""recurrentgemma-2b — Griffin RG-LRU + local attention, 1:2 (arXiv:2402.19427).

26L, d_model=2560, 10 heads (MQA kv=1, d_head=256), GeGLU d_ff=7680,
vocab 256000.  Layer pattern: (rglru, rglru, local_attn) repeating; 26 layers
= 8 x (R,R,A) + (R,R).  Local attention window 2048 -> long_500k runs.
"""
from repro.configs.base import ModelConfig, Segment

_PATTERN = []
for _ in range(8):
    _PATTERN.append(Segment(mixer="rglru", ffn="geglu", repeat=2))
    _PATTERN.append(Segment(mixer="local_attn", ffn="geglu", repeat=1))
_PATTERN.append(Segment(mixer="rglru", ffn="geglu", repeat=2))

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab_size=256000,
    segments=tuple(_PATTERN),
    local_window=2048,
    lru_width=2560,
    conv_width=4,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
)
