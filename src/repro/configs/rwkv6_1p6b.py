"""rwkv6-1.6b — RWKV-6 "Finch" (arXiv:2404.05892).

24L, d_model=2048, attention-free data-dependent-decay linear recurrence,
channel-mix FFN d_ff=7168, vocab 65536.  head_size 64 -> 32 wkv heads.
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,        # wkv heads = d_model / head_size
    n_kv_heads=32,
    d_head=64,
    d_ff=7168,
    vocab_size=65536,
    segments=(Segment(mixer="rwkv6", ffn="rwkv_cmix", repeat=24),),
    pos_emb="none",
    rwkv_head_size=64,
    rwkv_decay_lora=64,
    norm_type="layernorm",
)
