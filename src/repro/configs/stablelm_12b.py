"""stablelm-12b — Stability AI StableLM-2-12B family (hf:stabilityai).

40L, d_model=5120, 32 heads (GQA kv=8, d_head=160), SwiGLU d_ff=13824,
vocab 100352, RoPE.
"""
from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=160,
    d_ff=13824,
    vocab_size=100352,
    segments=(Segment(mixer="attn", ffn="swiglu", repeat=40),),
    rope_theta=10000.0,
)
