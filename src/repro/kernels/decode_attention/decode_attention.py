"""Flash-decoding GQA attention Pallas TPU kernel.

Decode attention is the memory-roofline op of serving: each step streams the
whole KV cache once at arithmetic intensity ~G (query heads per KV head).
The kernel keeps the online-softmax state (m, l, acc) for one (batch, kv
head) pair in VMEM scratch while iterating KV tiles, so HBM traffic is
exactly one read of K and V — no score matrix, no second pass.

Layout notes (TPU):
* q for one kv-head group is a (G, dh) tile — G is padded to the 8-sublane
  floor in ops.py, dh is expected to be 64/128/256 (lane-aligned);
* KV tiles are (SB, dh) with SB a multiple of 128;
* per-sequence valid length masks the tail tile via broadcasted_iota.

Grid: (B, KV, S // SB) with the KV-tile index innermost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG = -1.0e30


def _decode_attn_kernel(
    lengths_ref,  # (B,) int32 in SMEM
    q_ref,        # (G, dh)
    k_ref,        # (SB, dh)
    v_ref,        # (SB, dh)
    o_ref,        # (G, dh)
    m_ref,        # (G, 1) scratch
    l_ref,        # (G, 1) scratch
    acc_ref,      # (G, dh) scratch
    *,
    sb: int,
    n_s_tiles: int,
    scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(f32) * scale            # (G, dh)
    k = k_ref[...].astype(f32)                    # (SB, dh)
    v = v_ref[...].astype(f32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=f32
    )                                             # (G, SB)
    length = lengths_ref[b]
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + j * sb
    s = jnp.where(col < length, s, NEG)

    m_prev = m_ref[...]                           # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                        # (G, SB)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=f32
    )
    m_ref[...] = m_new

    @pl.when(j == n_s_tiles - 1)
    def _fin():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sb", "interpret"))
def decode_attention_pallas(
    q: jax.Array,        # (B, KV, G, dh)  — reshaped/padded by ops.py
    k_cache: jax.Array,  # (B, S, KV, dh)
    v_cache: jax.Array,  # (B, S, KV, dh)
    lengths: jax.Array,  # (B,) int32
    *,
    sb: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, KV, G, dh = q.shape
    S = k_cache.shape[1]
    sb = min(sb, S)
    assert S % sb == 0, f"cache len {S} not divisible by KV tile {sb}"
    n_s = S // sb
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(_decode_attn_kernel, sb=sb, n_s_tiles=n_s, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec((None, None, G, dh), lambda b, h, j, ln: (b, h, 0, 0)),
            pl.BlockSpec((None, sb, None, dh), lambda b, h, j, ln: (b, j, h, 0)),
            pl.BlockSpec((None, sb, None, dh), lambda b, h, j, ln: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, dh), lambda b, h, j, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, dh), f32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_cache, v_cache)
    return out
