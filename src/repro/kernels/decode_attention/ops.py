"""Public op: single-token GQA decode attention.

Handles layout adaptation (H -> (KV, G) grouping, sublane padding of G) and
backend dispatch: Pallas kernel on TPU, jnp oracle elsewhere, interpret mode
for validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "sb"))
def decode_attention(q, k_cache, v_cache, lengths, *, impl: str = "auto", sb: int = 512):
    """q (B, H, dh); k/v (B, S, KV, dh); lengths (B,) -> (B, H, dh)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, lengths)

    B, H, dh = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dh)
    pad = (-G) % 8  # sublane alignment
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = decode_attention_pallas(
        qg, k_cache, v_cache, lengths, sb=sb, interpret=(impl == "interpret")
    )
    if pad:
        out = out[:, :, :G, :]
    return out.reshape(B, H, dh)
