"""Pure-jnp oracle for the GQA decode-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

f32 = jnp.float32


def decode_attention_ref(
    q: jax.Array,        # (B, H, dh) one new token per sequence
    k_cache: jax.Array,  # (B, S, KV, dh)
    v_cache: jax.Array,  # (B, S, KV, dh)
    lengths: jax.Array,  # (B,) valid cache length per sequence
) -> jax.Array:
    """Softmax(q k^T / sqrt(dh)) v over the valid prefix.  -> (B, H, dh)."""
    B, H, dh = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, dh).astype(f32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(f32))
    scores = scores / math.sqrt(dh)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(f32))
    return out.reshape(B, H, dh)
