from repro.kernels.ivf_scan.ops import ivf_scan
from repro.kernels.ivf_scan.ref import ivf_scan_ref

__all__ = ["ivf_scan", "ivf_scan_ref"]
