"""Fused IVF distance + top-k Pallas TPU kernel.

The paper's retrieval hot loop computes, per (query, cluster) work item, the
L2 distances of the query to every vector in the cluster and keeps the top-k.
A GPU library does this as a distance GEMM followed by a separate selection
pass through global memory.  The TPU-native formulation fuses both:

* the distance matrix tile (QB x LB) is produced on the MXU from a
  ``q @ tile^T`` matmul plus norm terms and *never leaves VMEM*;
* a k-pass min/mask selection reduces the tile into a running (QB, k)
  scoreboard held in VMEM scratch across the cluster's row tiles;
* the cluster id -> slab row indirection is a *scalar-prefetch* BlockSpec
  index_map (the same mechanism paged-attention kernels use), so gathering
  the right cluster tile costs no extra HBM copy.

Grid: (n_groups, L // LB), j (row-tile) innermost so scratch carries the
scoreboard across row tiles of one group.

Output per group: (QB, k) distances + row indices — k values per query
instead of an (Q, N) distance dump, which is what makes the hot-cache path
bandwidth-cheap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
BIG = 3.0e38  # plain python float: jnp constants may not be closure-captured


def _kpass_select(d2: jax.Array, base_idx: jax.Array, k: int):
    """Top-k smallest of d2 (QB, M) -> (vals (QB, k), idx (QB, k))."""
    QB, M = d2.shape
    vals, idxs = [], []
    work = d2
    for _ in range(k):
        m = jnp.min(work, axis=1, keepdims=True)  # (QB, 1)
        is_min = work <= m
        cand = jnp.where(is_min, base_idx, jnp.int32(2**30))
        sel = jnp.min(cand, axis=1, keepdims=True)  # first argmin
        vals.append(m)
        idxs.append(sel)
        work = jnp.where(base_idx == sel, BIG, work)
    return jnp.concatenate(vals, axis=1), jnp.concatenate(idxs, axis=1)


def _ivf_scan_kernel(
    # scalar prefetch
    group_cluster,  # (G,) int32
    # inputs
    q_ref,          # (QB, d)
    slab_ref,       # (LB, d)
    valid_ref,      # (C,) int32 (full, in SMEM)
    # outputs
    dist_ref,       # (QB, k)
    idx_ref,        # (QB, k)
    # scratch
    best_d,         # (QB, k) f32
    best_i,         # (QB, k) i32
    *,
    k: int,
    lb: int,
    n_l_tiles: int,
):
    g = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_d[...] = jnp.full_like(best_d, BIG)
        best_i[...] = jnp.full_like(best_i, -1)

    q = q_ref[...].astype(f32)          # (QB, d)
    tile = slab_ref[...].astype(f32)    # (LB, d)
    # squared L2 via MXU matmul + norms
    qn = jnp.sum(q * q, axis=1, keepdims=True)          # (QB, 1)
    tn = jnp.sum(tile * tile, axis=1)[None, :]          # (1, LB)
    d2 = qn - 2.0 * jax.lax.dot_general(
        q, tile, (((1,), (1,)), ((), ())), preferred_element_type=f32
    ) + tn                                              # (QB, LB)

    nvalid = valid_ref[group_cluster[g]]
    col = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1) + j * lb
    d2 = jnp.where(col < nvalid, d2, BIG)

    bv, bi = _kpass_select(d2, col, k)                  # block top-k
    # merge with running scoreboard: k-pass over the 2k candidates
    cat_d = jnp.concatenate([best_d[...], bv], axis=1)  # (QB, 2k)
    cat_i = jnp.concatenate([best_i[...], bi], axis=1)
    QB = cat_d.shape[0]
    pos = jax.lax.broadcasted_iota(jnp.int32, cat_d.shape, 1)
    md, mp = _kpass_select(cat_d, pos, k)
    mi = jnp.take_along_axis(cat_i, mp, axis=1)
    best_d[...] = md
    best_i[...] = mi

    @pl.when(j == n_l_tiles - 1)
    def _fin():
        out_d = best_d[...]
        dist_ref[...] = jnp.where(out_d >= BIG, jnp.inf, out_d)
        idx_ref[...] = best_i[...]


@functools.partial(jax.jit, static_argnames=("k", "lb", "interpret"))
def ivf_scan_pallas(
    q_groups: jax.Array,       # (G, QB, d)
    group_cluster: jax.Array,  # (G,) int32
    slab: jax.Array,           # (C, L, d)
    valid: jax.Array,          # (C,) int32
    k: int,
    *,
    lb: int = 512,
    interpret: bool = False,
):
    G, QB, d = q_groups.shape
    C, L, _ = slab.shape
    lb = min(lb, L)
    assert L % lb == 0, f"slab tile {L} not divisible by block {lb}"
    n_l = L // lb

    kernel = functools.partial(_ivf_scan_kernel, k=k, lb=lb, n_l_tiles=n_l)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, n_l),
        in_specs=[
            pl.BlockSpec((None, QB, d), lambda g, j, gc: (g, 0, 0)),
            pl.BlockSpec((None, lb, d), lambda g, j, gc: (gc[g], j, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((None, QB, k), lambda g, j, gc: (g, 0, 0)),
            pl.BlockSpec((None, QB, k), lambda g, j, gc: (g, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((QB, k), f32),
            pltpu.VMEM((QB, k), jnp.int32),
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((G, QB, k), f32),
        jax.ShapeDtypeStruct((G, QB, k), jnp.int32),
    ]
    dists, idx = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(group_cluster, q_groups, slab, valid.astype(jnp.int32))
    return dists, idx
