"""Pure-jnp oracle for the fused IVF distance + top-k kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def ivf_scan_ref(
    q_groups: jax.Array,      # (G, QB, d) query blocks (host pre-gathered)
    group_cluster: jax.Array,  # (G,) int32 cluster id per group
    slab: jax.Array,          # (C, L, d) padded cluster tiles
    valid: jax.Array,         # (C,) int32 valid rows per cluster
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (dists (G, QB, k) f32 ascending, idx (G, QB, k) i32 row-in-cluster).

    Squared L2 distances; padded rows get +inf and idx -1 when selected.
    """
    blocks = slab[group_cluster]            # (G, L, d)
    nvalid = valid[group_cluster]           # (G,)
    qf = q_groups.astype(f32)
    bf = blocks.astype(f32)
    d2 = (
        (qf**2).sum(-1)[..., None]
        - 2.0 * jnp.einsum("gqd,gld->gql", qf, bf)
        + (bf**2).sum(-1)[:, None, :]
    )                                        # (G, QB, L)
    L = slab.shape[1]
    mask = jnp.arange(L)[None, None, :] < nvalid[:, None, None]
    d2 = jnp.where(mask, d2, jnp.inf)
    neg_top, idx = jax.lax.top_k(-d2, k)     # ascending distances
    dists = -neg_top
    idx = jnp.where(jnp.isfinite(dists), idx, -1).astype(jnp.int32)
    return dists.astype(f32), idx
