"""Public op: fused IVF cluster scan (kernel on TPU, jnp oracle elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ivf_scan.ivf_scan import ivf_scan_pallas
from repro.kernels.ivf_scan.ref import ivf_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def ivf_scan(q_groups, group_cluster, slab, valid, k: int, *, impl: str = "auto"):
    """impl: auto | pallas | interpret | ref.  See ivf_scan.py for semantics."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        return ivf_scan_pallas(q_groups, group_cluster, slab, valid, k)
    if impl == "interpret":
        return ivf_scan_pallas(q_groups, group_cluster, slab, valid, k, interpret=True)
    return ivf_scan_ref(q_groups, group_cluster, slab, valid, k)
