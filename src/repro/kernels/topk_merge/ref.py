"""Pure-jnp oracle for the running top-k merge kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def topk_merge_ref(run_d, run_i, cand_d, cand_i):
    """Merge running top-k with new candidates (both ascending-by-distance).

    run_d/run_i: (Q, k); cand_d/cand_i: (Q, m).  Returns (Q, k) merged,
    ascending, ties broken toward the running entries (stable).
    """
    k = run_d.shape[1]
    d = jnp.concatenate([run_d.astype(f32), cand_d.astype(f32)], axis=1)
    i = jnp.concatenate([run_i, cand_i], axis=1)
    neg, sel = jax.lax.top_k(-d, k)
    return -neg, jnp.take_along_axis(i, sel, axis=1)
