from repro.kernels.topk_merge.ops import topk_merge
from repro.kernels.topk_merge.ref import topk_merge_ref

__all__ = ["topk_merge", "topk_merge_ref"]
