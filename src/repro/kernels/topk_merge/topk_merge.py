"""Fused running top-k merge Pallas TPU kernel.

The retrieval scheduler merges every sub-stage's per-cluster candidates into
each request's running top-k (scoreboard).  At pod scale this runs for
thousands of in-flight (request, sub-stage) pairs per cycle; doing it as a
concat + full sort wastes k·log and an HBM round-trip.  The kernel keeps
both lists in VMEM and runs the same k-pass min/mask selection as ivf_scan
(k is small — 5..32), one grid step per query block.

Grid: (Q // QB,).  Everything fits one VMEM tile per step; the op is
bandwidth-bound at ~(k+m) reads + k writes per query.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
BIG = 3.0e38


def _merge_kernel(run_d_ref, run_i_ref, cand_d_ref, cand_i_ref,
                  out_d_ref, out_i_ref, *, k: int):
    rd = run_d_ref[...].astype(f32)
    cd = cand_d_ref[...].astype(f32)
    d = jnp.concatenate([rd, cd], axis=1)           # (QB, k+m)
    idx = jnp.concatenate([run_i_ref[...], cand_i_ref[...]], axis=1)
    d = jnp.where(jnp.isfinite(d), d, BIG)
    pos = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    out_d, out_i = [], []
    work = d
    for _ in range(k):
        m = jnp.min(work, axis=1, keepdims=True)
        is_min = work <= m
        cand_pos = jnp.where(is_min, pos, jnp.int32(2**30))
        sel = jnp.min(cand_pos, axis=1, keepdims=True)  # first (stable)
        out_d.append(m)
        out_i.append(jnp.take_along_axis(idx, sel, axis=1))
        work = jnp.where(pos == sel, BIG, work)
    dmerged = jnp.concatenate(out_d, axis=1)
    out_d_ref[...] = jnp.where(dmerged >= BIG, jnp.inf, dmerged)
    out_i_ref[...] = jnp.concatenate(out_i, axis=1)


@functools.partial(jax.jit, static_argnames=("qb", "interpret"))
def topk_merge_pallas(run_d, run_i, cand_d, cand_i, *, qb: int = 8,
                      interpret: bool = False):
    Q, k = run_d.shape
    m = cand_d.shape[1]
    qb = min(qb, Q)
    assert Q % qb == 0, f"Q {Q} not divisible by query block {qb}"
    kernel = functools.partial(_merge_kernel, k=k)
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=(Q // qb,),
        in_specs=[
            pl.BlockSpec((qb, k), lambda q: (q, 0)),
            pl.BlockSpec((qb, k), lambda q: (q, 0)),
            pl.BlockSpec((qb, m), lambda q: (q, 0)),
            pl.BlockSpec((qb, m), lambda q: (q, 0)),
        ],
        out_specs=[
            pl.BlockSpec((qb, k), lambda q: (q, 0)),
            pl.BlockSpec((qb, k), lambda q: (q, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), f32),
            jax.ShapeDtypeStruct((Q, k), run_i.dtype),
        ],
        interpret=interpret,
    )(run_d, run_i, cand_d, cand_i)
    return out_d, out_i
