"""Public op: running top-k merge (kernel on TPU, jnp oracle elsewhere)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.topk_merge.ref import topk_merge_ref
from repro.kernels.topk_merge.topk_merge import topk_merge_pallas


@functools.partial(jax.jit, static_argnames=("impl",))
def topk_merge(run_d, run_i, cand_d, cand_i, *, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return topk_merge_pallas(run_d, run_i, cand_d, cand_i)
    if impl == "interpret":
        return topk_merge_pallas(run_d, run_i, cand_d, cand_i, interpret=True)
    return topk_merge_ref(run_d, run_i, cand_d, cand_i)
