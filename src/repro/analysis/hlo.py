"""HLO-level analysis: collective bytes, cost extraction, roofline terms.

Facts this module is built around (verified on this jax/XLA build):

* ``compiled.cost_analysis()`` reports **per-device** FLOPs/bytes of the
  SPMD-partitioned module, and counts while-loop bodies **once** (no trip
  multiplication) — hence the depth-extrapolation scheme in dryrun.py.
* collective instructions in ``compiled.as_text()`` reference operands by
  name only, so operand byte-sizes are resolved through a full instruction
  shape table built from the module text.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# %name = dtype[d0,d1]{layout} — also matches scalar dtype[]
_DEF_RE = re.compile(r"%([\w\.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\])")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict
    f32_bytes: float = 0.0  # portion of total carried by f32 buffers

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def bf16_corrected_bytes(self) -> float:
        """XLA:CPU float-normalizes bf16 buffers to f32 (no bf16 arithmetic
        on CPU); on TPU the same collectives run in bf16.  Corrected total
        halves the f32 portion — documented in EXPERIMENTS.md §Roofline."""
        other = self.total_bytes - self.f32_bytes
        return other + 0.5 * self.f32_bytes

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def merged(self, other: "CollectiveStats", scale: float = 1.0) -> "CollectiveStats":
        b = dict(self.bytes_by_op)
        c = dict(self.count_by_op)
        for k, v in other.bytes_by_op.items():
            b[k] = b.get(k, 0) + v * scale
        for k, v in other.count_by_op.items():
            c[k] = c.get(k, 0) + v * scale
        return CollectiveStats(b, c, self.f32_bytes + scale * other.f32_bytes)


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in the module (per device)."""
    # build instruction shape table
    shapes: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        shapes[m.group(1)] = _shape_bytes(m.group(2))

    bytes_by_op: dict[str, int] = {}
    count_by_op: dict[str, int] = {}
    f32_bytes = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        for op in COLLECTIVE_OPS:
            # match "= ... op(" or "= op-start(" variants
            m = re.search(rf"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{{[^}}]*\}})?))\s*{op}(?:-start)?\(([^)]*)\)", s)
            if m is None:
                continue
            operands = _OPERAND_RE.findall(m.group(2))
            b = sum(shapes.get(o, 0) for o in operands)
            if b == 0:
                # fall back to result size (all-reduce: result == operand)
                b = _shape_bytes(m.group(1))
            bytes_by_op[op] = bytes_by_op.get(op, 0) + b
            count_by_op[op] = count_by_op.get(op, 0) + 1
            if "f32[" in m.group(1) or "f32[" in m.group(2):
                f32_bytes += b
            break
    return CollectiveStats(bytes_by_op, count_by_op, f32_bytes)


@dataclasses.dataclass
class CompiledCosts:
    flops_per_device: float
    bytes_per_device: float
    collectives: CollectiveStats

    def scaled_sub(self, other: "CompiledCosts") -> "CompiledCosts":
        """self - other (slope computation)."""
        coll = CollectiveStats(
            {k: self.collectives.bytes_by_op.get(k, 0) - other.collectives.bytes_by_op.get(k, 0)
             for k in set(self.collectives.bytes_by_op) | set(other.collectives.bytes_by_op)},
            {k: self.collectives.count_by_op.get(k, 0) - other.collectives.count_by_op.get(k, 0)
             for k in set(self.collectives.count_by_op) | set(other.collectives.count_by_op)},
            self.collectives.f32_bytes - other.collectives.f32_bytes,
        )
        return CompiledCosts(
            self.flops_per_device - other.flops_per_device,
            self.bytes_per_device - other.bytes_per_device,
            coll,
        )

    def plus_scaled(self, other: "CompiledCosts", n: float) -> "CompiledCosts":
        coll = self.collectives.merged(other.collectives, n)
        return CompiledCosts(
            self.flops_per_device + n * other.flops_per_device,
            self.bytes_per_device + n * other.bytes_per_device,
            coll,
        )


def extract_costs(compiled) -> CompiledCosts:
    ca = compiled.cost_analysis()
    return CompiledCosts(
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        collectives=collective_stats(compiled.as_text()),
    )


# ---------------------------------------------------------------------------
# Roofline (TPU v5e constants from the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(costs: CompiledCosts, chips: int) -> dict:
    """Three terms in seconds (per step).  cost_analysis is per-device, so
    `flops/(chips*peak)` from the spec == `flops_per_device/peak`."""
    t_compute = costs.flops_per_device / PEAK_FLOPS_BF16
    t_memory = costs.bytes_per_device / HBM_BW
    t_collective = costs.collectives.bf16_corrected_bytes / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "flops_per_device": costs.flops_per_device,
        "bytes_per_device": costs.bytes_per_device,
        "collective_bytes_per_device": costs.collectives.bf16_corrected_bytes,
        "collective_bytes_raw_f32normalized": costs.collectives.total_bytes,
        "collective_counts": costs.collectives.count_by_op,
        "collective_bytes_by_op": costs.collectives.bytes_by_op,
    }


def model_flops(cfg, shape, chips: int) -> dict:
    """Analytic MODEL_FLOPS: 6·N·D for train, 2·N·D for inference steps
    (N = active params, D = tokens processed by the step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mf = 2.0 * n_active * tokens
    return {"model_flops_global": mf, "model_flops_per_device": mf / chips,
            "active_params": n_active, "total_params": cfg.param_count()}
