"""Analytic per-device HBM traffic model (the roofline memory term).

Why analytic: XLA:CPU applies far less fusion than XLA:TPU, so the
``bytes accessed`` cost-analysis metric on this container over-counts HBM
traffic by 2-3 orders of magnitude (every unfused elementwise op's operands
are charged).  The memory term is therefore computed from an explicit
traffic model, with the XLA number reported alongside as the no-fusion upper
bound.  All formulas below count bytes *per device per step*; weights are
assumed fully sharded (FSDP x TP, so local shard = W/chips) but *gathered
per layer* during compute, hence each device streams the **full** weight
bytes through HBM once per traversal — matching how XLA materialises
all-gathered operands.

train   : 2 weight reads (fwd+bwd, bf16) + grad f32 write+read
          + AdamW (mu, nu read+write f32; param read+write)
          + activations: ~14 bf16 (B,S,d)-equivalents per layer forward,
            x (1 fwd + 1 remat + 1 bwd read) + grad acts written once
          + attention scores: 3 x causal-half B H S^2 f32 (fwd/remat/bwd)
          + logits: 3 x (B,S,V) bf16 (chunked: fwd + remat + grad)
prefill : 1 weight read + 1x activations + KV-cache write
decode  : 1 weight read (the gathered stream — decode is weight-bound)
          + KV-cache read+write + small activations
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


def _attention_score_bytes(cfg: ModelConfig, B: int, S: int, passes: float) -> float:
    """Causal-half score tensors, f32, summed over quadratic layers."""
    total = 0.0
    for seg in tuple(cfg.segments) + tuple(cfg.encoder_segments):
        if seg.mixer in ("attn", "mla", "encoder_attn"):
            eff = S * S if seg.mixer == "encoder_attn" else S * S / 2
            total += seg.repeat * B * cfg.n_heads * eff * F32
        elif seg.mixer == "local_attn":
            w = min(cfg.local_window, S)
            total += seg.repeat * B * cfg.n_heads * S * w * F32
        if seg.cross_attn:
            total += seg.repeat * B * cfg.n_heads * S * cfg.encoder_seq * F32
    return passes * total * 2  # write + read


def _kv_cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    total = 0.0
    kv_b = 1 + 4.0 / max(cfg.d_head, 1) if cfg.kv_cache_dtype == "int8" else BF16
    for seg in cfg.segments:
        if seg.mixer in ("attn", "encoder_attn"):
            total += seg.repeat * 2 * B * S * cfg.n_kv_heads * cfg.d_head * kv_b
        elif seg.mixer == "local_attn":
            w = min(cfg.local_window, S)
            total += seg.repeat * 2 * B * w * cfg.n_kv_heads * cfg.d_head * kv_b
        elif seg.mixer == "mla":
            total += seg.repeat * B * S * (cfg.kv_lora_rank + cfg.rope_head_dim) * BF16
        elif seg.mixer == "rwkv6":
            total += seg.repeat * B * cfg.rwkv_n_heads * cfg.rwkv_head_size**2 * F32
        elif seg.mixer == "rglru":
            total += seg.repeat * B * (cfg.lru_width or cfg.d_model) * F32
        if seg.cross_attn:
            total += seg.repeat * 2 * B * cfg.encoder_seq * cfg.n_kv_heads * cfg.d_head * BF16
    return total


ACT_TENSORS_PER_LAYER = 14  # qkv/gates/ffn-hidden(≈8x d wide counted via d_ff)


def _activation_bytes(cfg: ModelConfig, B: int, S: int, tp: int) -> float:
    """Forward activation traffic of one pass, bf16, for one dp shard
    (caller divides by dp).  d-wide tensors are dp-sharded only; ff/head-wide
    tensors are additionally tp-sharded."""
    per_tok = 0.0
    for seg in tuple(cfg.segments) + tuple(cfg.encoder_segments):
        d_ff = cfg.moe_d_ff * cfg.moe_top_k if seg.ffn == "moe" else cfg.d_ff
        shared = cfg.moe_d_ff * cfg.n_shared_experts if seg.ffn == "moe" else 0
        # ~6 d-wide tensors + 3 ff-wide tensors per layer, write+read
        per_tok += seg.repeat * (6 * cfg.d_model + 3 * (d_ff + shared) / tp) * BF16 * 2
    return B * S * per_tok


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                       tp: int = 16) -> dict:
    """Per-device HBM bytes/step.  tp = model-axis size; weights are TP-kept
    and DP-gathered, so one weight traversal streams W/tp bytes per device.
    Activations: d-wide tensors shard over dp only; ff/head-wide over dp*tp."""
    B, S = shape.global_batch, shape.seq_len
    dp = max(chips // tp, 1)
    W = cfg.param_count()
    W_local = W / chips

    if shape.kind == "train":
        weights = 2 * W * BF16 / tp  # fwd + bwd reads of the gathered stream
        opt = W_local * (2 * F32 + 4 * F32 + 2 * F32)  # grad w+r, mu/nu rw, param rw
        acts = _activation_bytes(cfg, B, S, tp) * 3 / dp  # fwd + remat + bwd
        scores = _attention_score_bytes(cfg, B, S, passes=3.0) / chips
        logits = 3 * B * S * cfg.vocab_size * BF16 / chips
        total = weights + opt + acts + scores + logits
        parts = {"weights": weights, "optimizer": opt, "activations": acts,
                 "attn_scores": scores, "logits": logits}
    elif shape.kind == "prefill":
        weights = W * BF16 / tp
        acts = _activation_bytes(cfg, B, S, tp) / dp
        scores = _attention_score_bytes(cfg, B, S, passes=1.0) / chips
        kv = _kv_cache_bytes(cfg, B, S) / chips
        logits = B * cfg.vocab_size * BF16 / chips  # last-position head only
        total = weights + acts + scores + kv + logits
        parts = {"weights": weights, "activations": acts, "attn_scores": scores,
                 "kv_cache_write": kv, "logits": logits}
    else:  # decode
        weights = W * BF16 / tp
        kv = _kv_cache_bytes(cfg, B, S) / chips  # full cache read
        acts = B * (cfg.n_layers + cfg.n_encoder_layers) * cfg.d_model * 20 * BF16 / dp
        logits = B * cfg.vocab_size * F32 / chips
        total = weights + kv + acts + logits
        parts = {"weights": weights, "kv_cache_read": kv, "activations": acts,
                 "logits": logits}
    parts["total"] = total
    return parts
