"""Shared visitor framework for repro-lint checkers.

One parse per file; every checker gets a :class:`FileContext` (AST, source
lines, import-alias table, inline suppressions) and returns
:class:`Finding` rows.  The engine (:func:`run_lint`) applies suppressions,
canonicalises ordering (findings sort by path/line/col/rule, never by scan
order) and renders text or a JSON report — the report is a pure function
of the file *contents*, so shuffling the input file list cannot change a
byte of it.

Suppression syntax, modelled on pylint::

    time.time()  # repro-lint: disable=wall-clock -- justification here

A suppression comment on its own line applies to the next code line; rule
names may be the short form (``wall-clock``) or fully qualified
(``determinism/wall-clock``), comma-separated.  Suppressions require a
rule name — there is deliberately no ``disable=all``.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Iterable, Optional

REPORT_SCHEMA_VERSION = 1

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_/,\-]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation, addressed by file-relative position."""

    path: str  # posix path relative to the scan root, e.g. repro/core/x.py
    line: int
    col: int
    rule: str  # qualified, e.g. "determinism/wall-clock"
    message: str

    @property
    def short_rule(self) -> str:
        return self.rule.rsplit("/", 1)[-1]

    def to_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "rule": self.rule, "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


class Suppressions:
    """Per-file map of line -> suppressed short rule names."""

    def __init__(self, source: str):
        self.by_line: dict[int, set[str]] = {}
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(source).readline))
        except (tokenize.TokenError, IndentationError, SyntaxError):
            return
        code_lines = {
            t.start[0]
            for t in tokens
            if t.type not in (tokenize.COMMENT, tokenize.NL,
                              tokenize.NEWLINE, tokenize.INDENT,
                              tokenize.DEDENT, tokenize.ENDMARKER)
        }
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            rules = {r.strip().rsplit("/", 1)[-1]
                     for r in m.group(1).split(",") if r.strip()}
            row = tok.start[0]
            # a comment-only line suppresses the next code line
            target = row if row in code_lines else row + 1
            self.by_line.setdefault(target, set()).update(rules)

    def covers(self, finding: Finding) -> bool:
        return finding.short_rule in self.by_line.get(finding.line, ())


class ImportTable:
    """Maps local names to canonical dotted module paths.

    ``import numpy as np`` -> ``np: numpy``;
    ``from time import perf_counter`` -> ``perf_counter: time.perf_counter``.
    """

    def __init__(self, tree: ast.AST):
        self.names: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    self.names[alias.asname or alias.name.split(".")[0]] = (
                        alias.name if alias.asname else alias.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    self.names[alias.asname or alias.name] = (
                        f"{node.module}.{alias.name}")

    def resolve_call(self, func: ast.expr) -> Optional[str]:
        """Canonical dotted path of a call target, or None.

        ``np.random.rand`` -> ``numpy.random.rand`` given ``import numpy
        as np``; a bare ``perf_counter`` resolves through a from-import.
        """
        parts: list[str] = []
        node = func
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.names.get(node.id)
        if base is None:
            return None
        parts.append(base)
        return ".".join(reversed(parts))


@dataclasses.dataclass
class FileContext:
    path: str  # absolute filesystem path
    relpath: str  # posix path relative to scan root
    source: str
    tree: ast.Module
    imports: ImportTable
    suppressions: Suppressions

    @classmethod
    def load(cls, path: str, relpath: str) -> "FileContext":
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
        return cls(path=path, relpath=relpath, source=source, tree=tree,
                   imports=ImportTable(tree),
                   suppressions=Suppressions(source))


def attr_chain(node: ast.expr) -> Optional[list[str]]:
    """``self.sched.metrics`` -> ["self", "sched", "metrics"]; None when the
    expression is not a plain name/attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return list(reversed(parts))


class ScopedVisitor(ast.NodeVisitor):
    """NodeVisitor that tracks the enclosing class/function stack — the one
    shared chassis every checker builds on."""

    def __init__(self, ctx: FileContext):
        self.ctx = ctx
        self.findings: list[Finding] = []
        self.class_stack: list[ast.ClassDef] = []
        self.func_stack: list[ast.AST] = []

    # ----------------------------------------------------------- plumbing
    def emit(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(Finding(
            path=self.ctx.relpath, line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0), rule=rule, message=message))

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.class_stack.append(node)
        self.generic_visit(node)
        self.class_stack.pop()

    def _visit_func(self, node) -> None:
        self.func_stack.append(node)
        self.generic_visit(node)
        self.func_stack.pop()

    def visit_FunctionDef(self, node) -> None:
        self._visit_func(node)

    def visit_AsyncFunctionDef(self, node) -> None:
        self._visit_func(node)

    @property
    def current_class(self) -> Optional[ast.ClassDef]:
        return self.class_stack[-1] if self.class_stack else None

    @property
    def current_func(self):
        return self.func_stack[-1] if self.func_stack else None


@dataclasses.dataclass
class LintReport:
    root: str
    files: list[str]
    findings: list[Finding]
    suppressed: list[Finding]
    rules: tuple

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        by_rule: dict[str, int] = {r: 0 for r in self.rules}
        for f in self.findings:
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        return {
            "schema_version": REPORT_SCHEMA_VERSION,
            "tool": "repro-lint",
            "rules": list(self.rules),
            "files_scanned": len(self.files),
            "files": list(self.files),
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "summary": {
                "total": len(self.findings),
                "suppressed": len(self.suppressed),
                "by_rule": {k: by_rule[k] for k in sorted(by_rule)},
            },
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True) + "\n"

    def render_text(self) -> str:
        lines = [f.render() for f in self.findings]
        lines.append(
            f"repro-lint: {len(self.findings)} finding(s), "
            f"{len(self.suppressed)} suppressed, "
            f"{len(self.files)} file(s) scanned")
        return "\n".join(lines)


def iter_py_files(paths: Iterable[str]) -> list[str]:
    out: set[str] = set()
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                out.add(os.path.abspath(p))
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                for fn in filenames:
                    if fn.endswith(".py"):
                        out.add(os.path.abspath(os.path.join(dirpath, fn)))
    return sorted(out)


def run_lint(paths: Iterable[str], root: str, policy=None,
             baseline: Optional[Iterable[dict]] = None) -> LintReport:
    """Run every checker over ``paths`` (files or directories).

    ``root`` anchors the policy's package-relative path matching: a file at
    ``<root>/repro/core/wavefront.py`` is matched against policy prefixes
    like ``repro/core/``.  ``baseline`` (optional) is a list of finding
    dicts to subtract — a migration crutch the repo itself does not use
    (its baseline is empty).
    """
    # resolved late so checkers can import the framework without a cycle
    from repro.analysis.lint import ALL_RULES
    from repro.analysis.lint.determinism import DeterminismChecker
    from repro.analysis.lint.hooks import HooksChecker
    from repro.analysis.lint.ownership import OwnershipChecker
    from repro.analysis.lint.registry import RegistryChecker
    from repro.analysis.lint.policy import DEFAULT_POLICY

    policy = policy or DEFAULT_POLICY
    root = os.path.abspath(root)
    files = iter_py_files(paths)
    contexts: list[FileContext] = []
    findings: list[Finding] = []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        try:
            contexts.append(FileContext.load(path, rel))
        except SyntaxError as e:
            findings.append(Finding(
                path=rel, line=e.lineno or 0, col=e.offset or 0,
                rule="parse/error", message=f"cannot parse: {e.msg}"))
    # ownership needs a cross-file declaration pass; order the contexts by
    # relpath so every phase is independent of filesystem enumeration order
    contexts.sort(key=lambda c: c.relpath)
    checkers = [DeterminismChecker(policy), RegistryChecker(policy),
                HooksChecker(policy), OwnershipChecker(policy)]
    for checker in checkers:
        collect = getattr(checker, "collect", None)
        if collect is not None:
            for ctx in contexts:
                collect(ctx)
    for ctx in contexts:
        for checker in checkers:
            findings.extend(checker.check(ctx))
    kept: list[Finding] = []
    suppressed: list[Finding] = []
    by_rel = {c.relpath: c for c in contexts}
    base_keys = {(b["path"], b["rule"], b.get("line"))
                 for b in (baseline or ())}
    for f in findings:
        ctx = by_rel.get(f.path)
        if ctx is not None and ctx.suppressions.covers(f):
            suppressed.append(f)
        elif ((f.path, f.rule, f.line) in base_keys
              or (f.path, f.rule, None) in base_keys):
            suppressed.append(f)
        else:
            kept.append(f)
    return LintReport(
        root=root,
        files=sorted(c.relpath for c in contexts),
        findings=sorted(kept),
        suppressed=sorted(suppressed),
        rules=ALL_RULES,
    )
