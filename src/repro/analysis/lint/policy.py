"""Per-package policy for repro-lint.

Paths are posix-style and relative to the scan root (normally ``src/``), so
prefixes look like ``repro/core/``.  Benchmarks, scripts, examples and
tests sit outside the scan root and are therefore exempt from every rule —
bench code in particular is *allowed* to read the wall clock (it measures
real time by design; see benchmarks/README.md).
"""
from __future__ import annotations

import dataclasses


def _match(relpath: str, prefixes: tuple) -> bool:
    return any(relpath == p or relpath.startswith(p) for p in prefixes)


@dataclasses.dataclass(frozen=True)
class Policy:
    # ---- registry discipline ------------------------------------------
    # stage kinds owned by the registry; keep in sync with
    # repro.core.stages (tests/test_lint.py asserts the sync)
    stage_kinds: frozenset = frozenset(
        {"generation", "retrieval", "rerank", "rewrite", "compress"})
    # files allowed to branch on kind strings: the registry itself and the
    # node dataclass definitions
    kind_exempt: tuple = ("repro/core/stages.py", "repro/core/ragraph.py")

    # ---- determinism ---------------------------------------------------
    # packages where only the virtual clock may be read
    virtual_clock_paths: tuple = (
        "repro/core/", "repro/serving/", "repro/crossreq/", "repro/obs/")
    # the one carve-out inside those packages: the wall-clock ingress
    # boundary (serving/ingress.py) exists to *read* real time — producer
    # threads stamp arrivals/heartbeats there and everything downstream
    # consumes the recorded stamps.  Nothing else in the serving packages
    # may join this list; obs taps receive wall values as arguments.
    wallclock_ingress_paths: tuple = ("repro/serving/ingress.py",)
    wallclock_calls: frozenset = frozenset({
        "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
        "time.perf_counter", "time.perf_counter_ns", "time.process_time",
        "time.process_time_ns",
        "datetime.datetime.now", "datetime.datetime.utcnow",
        "datetime.datetime.today", "datetime.date.today",
    })
    # module-level stdlib/numpy RNG entry points that draw from global or
    # entropy-seeded state (checked everywhere under the scan root)
    global_rng_calls: frozenset = frozenset({
        "random.random", "random.randint", "random.randrange",
        "random.shuffle", "random.choice", "random.choices",
        "random.sample", "random.uniform", "random.gauss",
        "random.normalvariate", "random.betavariate", "random.seed",
        "random.getrandbits",
        "numpy.random.rand", "numpy.random.randn", "numpy.random.randint",
        "numpy.random.random", "numpy.random.random_sample",
        "numpy.random.ranf", "numpy.random.sample",
        "numpy.random.shuffle", "numpy.random.permutation",
        "numpy.random.choice", "numpy.random.uniform",
        "numpy.random.normal", "numpy.random.standard_normal",
        "numpy.random.seed",
    })
    # constructors that *must* be seeded: flagged only with zero args
    seed_required_calls: frozenset = frozenset({
        "numpy.random.default_rng", "random.Random", "random.SystemRandom",
        "jax.random.PRNGKey",
    })
    # packages where hash-ordered iteration is checked
    set_iter_paths: tuple = (
        "repro/core/", "repro/serving/", "repro/crossreq/", "repro/obs/")
    # calls that make iteration order observable in scheduling decisions:
    # heap pushes, top-k folds, dispatch selection, admission
    ordering_sinks: frozenset = frozenset({
        "heappush", "heapify", "heappushpop", "heapreplace",
        "nlargest", "nsmallest",
        "pick_worker", "pick_shard_worker", "least_loaded",
        "add_request", "submit",
    })
    # known set-returning APIs in this codebase (syntactic, by method name)
    set_returning_calls: frozenset = frozenset({
        "covering_holders", "owners_for",
    })
    # loop-body statement calls that are order-insensitive folds: a loop
    # over a set whose body only accumulates into sets is deterministic
    order_insensitive_calls: frozenset = frozenset(
        {"add", "update", "discard"})

    # ---- hook passivity ------------------------------------------------
    obs_paths: tuple = ("repro/obs/",)
    # the scheduler file whose hook callsites must be knob-guarded, and the
    # attributes holding the hook objects (None when the knob is off)
    hook_file: str = "repro/core/wavefront.py"
    hook_attrs: tuple = ("obs", "telemetry")
    # method names that mutate their receiver — calling one of these on an
    # object passed *into* an obs hook is a passivity violation
    mutator_calls: frozenset = frozenset({
        "add", "append", "extend", "insert", "remove", "discard", "pop",
        "popleft", "popitem", "clear", "update", "setdefault", "sort",
        "reverse", "write", "inc", "dec", "set", "observe", "record",
        "reset", "push", "heappush", "submit", "step", "run", "drain",
        "cancel", "tick", "register", "readmit", "rebind",
        "add_request", "note_busy", "note_complete", "note_dispatch",
        "register_worker", "drain_worker", "rebind_worker",
    })

    def in_virtual_clock_zone(self, relpath: str) -> bool:
        if _match(relpath, self.wallclock_ingress_paths):
            return False
        return _match(relpath, self.virtual_clock_paths)

    def in_set_iter_zone(self, relpath: str) -> bool:
        return _match(relpath, self.set_iter_paths)

    def in_obs_zone(self, relpath: str) -> bool:
        return _match(relpath, self.obs_paths)

    def kind_exempted(self, relpath: str) -> bool:
        return _match(relpath, self.kind_exempt)


DEFAULT_POLICY = Policy()
