"""CLI for repro-lint::

    PYTHONPATH=src python -m repro.analysis.lint                 # scan repro/
    PYTHONPATH=src python -m repro.analysis.lint --format json   # JSON to stdout
    PYTHONPATH=src python -m repro.analysis.lint --report out.json path/...

Exit codes: 0 clean, 1 findings, 2 bad invocation.  With no paths the
scan target is the installed ``repro`` package itself and the scan root is
its parent directory (``src/`` in a checkout), so policy prefixes like
``repro/core/`` resolve identically however the tool is launched.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import repro
from repro.analysis.lint import ALL_RULES, run_lint


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST invariant analyzer: determinism, registry "
                    "discipline, hook passivity, thread ownership.")
    parser.add_argument("paths", nargs="*",
                        help="files or directories to scan "
                             "(default: the repro package)")
    parser.add_argument("--root", default=None,
                        help="scan root for package-relative policy paths "
                             "(default: parent of the repro package)")
    parser.add_argument("--format", choices=("text", "json"), default="text",
                        help="stdout format (default text)")
    parser.add_argument("--report", default=None, metavar="FILE",
                        help="also write the JSON report to FILE")
    parser.add_argument("--baseline", default=None, metavar="FILE",
                        help="JSON file of known findings to subtract "
                             "(the repo's own baseline is empty)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule ids and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(rule)
        return 0

    # repro is a namespace package (no __init__.py): locate it via __path__
    pkg_dir = os.path.abspath(list(repro.__path__)[0])
    paths = args.paths or [pkg_dir]
    root = args.root or os.path.dirname(pkg_dir)
    baseline = None
    if args.baseline:
        try:
            with open(args.baseline, "r", encoding="utf-8") as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            print(f"repro-lint: cannot read baseline: {e}", file=sys.stderr)
            return 2
        baseline = data.get("findings", data) if isinstance(data, dict) else data

    report = run_lint(paths, root=root, baseline=baseline)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            f.write(report.to_json())
    if args.format == "json":
        sys.stdout.write(report.to_json())
    else:
        print(report.render_text())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
