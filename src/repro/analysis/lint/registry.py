"""Registry-discipline checker: stage-kind strings stay in the registry.

The stage registry (``repro/core/stages.py``) owns all per-kind behaviour;
scheduler/serving/crossreq layers must dispatch through ``stages.spec(...)``
and never branch on node-kind strings.  The old CI grep only caught the
literal pattern ``kind == "..."``; this AST checker also catches

* membership tests — ``if n.kind in ("retrieval", "rerank")``,
* aliased locals — ``k = node.kind`` ... ``if k == "generation"``,
* yoda comparisons — ``"retrieval" == st.kind``,
* ``match`` statements whose subject is a kind and whose cases pattern-
  match kind string literals.

``core/stages.py`` (the registry) and ``core/ragraph.py`` (the node
dataclass definitions with their class-level kind tags) are exempt.
"""
from __future__ import annotations

import ast

from repro.analysis.lint.framework import (
    FileContext,
    Finding,
    ScopedVisitor,
    attr_chain,
)

RULE = "registry/kind-branch"


def _collect_kind_aliases(tree: ast.AST) -> set:
    """Names assigned from a ``.kind`` attribute anywhere in the scope."""
    aliases: set = set()

    def kindish(expr: ast.expr) -> bool:
        return ((isinstance(expr, ast.Attribute) and expr.attr == "kind")
                or (isinstance(expr, ast.Name) and expr.id in aliases))

    for node in ast.walk(tree):
        targets, value = [], None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        elif isinstance(node, ast.NamedExpr):
            targets, value = [node.target], node.value
        if value is not None and kindish(value):
            for t in targets:
                if isinstance(t, ast.Name):
                    aliases.add(t.id)
    return aliases


class _RegistryVisitor(ScopedVisitor):
    def __init__(self, ctx: FileContext, policy):
        super().__init__(ctx)
        self.policy = policy
        self.aliases = _collect_kind_aliases(ctx.tree)

    def _kindish(self, expr: ast.expr) -> bool:
        if isinstance(expr, ast.Attribute) and expr.attr == "kind":
            return True
        return isinstance(expr, ast.Name) and expr.id in self.aliases

    def _kind_literals(self, expr: ast.expr) -> list:
        """Stage-kind string constants inside a literal or container."""
        if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
            return ([expr.value]
                    if expr.value in self.policy.stage_kinds else [])
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            out = []
            for e in expr.elts:
                out.extend(self._kind_literals(e))
            return out
        return []

    def visit_Compare(self, node: ast.Compare) -> None:
        sides = [node.left] + list(node.comparators)
        for op, lhs, rhs in zip(node.ops, sides, sides[1:]):
            if not isinstance(op, (ast.Eq, ast.NotEq, ast.In, ast.NotIn)):
                continue
            for kind_side, lit_side in ((lhs, rhs), (rhs, lhs)):
                lits = self._kind_literals(lit_side)
                if lits and self._kindish(kind_side):
                    self.emit(
                        node, RULE,
                        f"stage-kind comparison against {lits!r} outside "
                        "the registry; dispatch through "
                        "repro.core.stages.spec(kind) instead")
                    break
        self.generic_visit(node)

    def visit_Match(self, node: ast.Match) -> None:
        if self._kindish(node.subject):
            for case in node.cases:
                lits = [
                    p.value.value
                    for p in ast.walk(case.pattern)
                    if isinstance(p, ast.MatchValue)
                    and isinstance(p.value, ast.Constant)
                    and isinstance(p.value.value, str)
                    and p.value.value in self.policy.stage_kinds
                ]
                if lits:
                    self.emit(
                        case.pattern, RULE,
                        f"match on stage kind {lits!r} outside the "
                        "registry; dispatch through "
                        "repro.core.stages.spec(kind) instead")
        self.generic_visit(node)


class RegistryChecker:
    name = "registry"

    def __init__(self, policy):
        self.policy = policy

    def check(self, ctx: FileContext) -> list[Finding]:
        if self.policy.kind_exempted(ctx.relpath):
            return []
        v = _RegistryVisitor(ctx, self.policy)
        v.visit(ctx.tree)
        return v.findings
