"""Determinism checker: the golden-fingerprint invariant, statically.

Three rules:

* ``determinism/unseeded-rng`` — module-level ``random``/``np.random``
  calls draw from global or entropy-seeded state; every RNG in the repo
  must be an explicitly seeded generator.  Checked everywhere.
* ``determinism/wall-clock`` — ``time.time``/``perf_counter``/
  ``datetime.now`` and friends inside the virtual-clock zone
  (core/serving/crossreq/obs), where the event clock is the only legal
  time source.  RealBackend's measured-execution path is the sanctioned
  exception, carried as inline suppressions with justification.
* ``determinism/set-iteration`` — iterating a ``set``/``frozenset`` leaks
  hash order into whatever the loop does; inside the scheduling packages
  that is an ordering bug waiting for a string key.  Iterations wrapped in
  ``sorted()`` are fine, as are loops whose body only folds into other
  sets (order-insensitive).  ``dict`` views are insertion-ordered and only
  flagged when the loop body feeds an ordering-sensitive sink (heap push,
  dispatch selection, admission) — there the incidental insertion order
  becomes load-bearing schedule input.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.lint.framework import (
    FileContext,
    Finding,
    ScopedVisitor,
    attr_chain,
)

_DICT_VIEWS = ("values", "keys", "items")
# calls whose argument's iteration order is irrelevant (deterministic
# aggregate or explicit re-ordering)
_SANITIZERS = frozenset({
    "sorted", "len", "sum", "min", "max", "any", "all", "set", "frozenset",
})


def _is_set_expr(node: ast.expr, setvars: set, policy) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Name):
        return node.id in setvars
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("set", "frozenset"):
            return True
        if isinstance(f, ast.Attribute) and f.attr in policy.set_returning_calls:
            return True
        return False
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.BitXor, ast.Sub)):
        return (_is_set_expr(node.left, setvars, policy)
                or _is_set_expr(node.right, setvars, policy))
    if isinstance(node, ast.IfExp):
        return (_is_set_expr(node.body, setvars, policy)
                or _is_set_expr(node.orelse, setvars, policy))
    if isinstance(node, ast.BoolOp):
        return any(_is_set_expr(v, setvars, policy) for v in node.values)
    return False


def _collect_set_vars(func: ast.AST, policy) -> set:
    """Flow-insensitive, source-order inference of set-typed local names."""
    setvars: set = set()
    for node in ast.walk(func):
        targets = []
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        elif isinstance(node, ast.NamedExpr):
            targets, value = [node.target], node.value
        else:
            continue
        if _is_set_expr(value, setvars, policy):
            for t in targets:
                if isinstance(t, ast.Name):
                    setvars.add(t.id)
    return setvars


def _is_dict_view(node: ast.expr) -> Optional[str]:
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and node.func.attr in _DICT_VIEWS and not node.args
            and not node.keywords):
        return node.func.attr
    return None


def _find_sink(body: list, policy) -> Optional[ast.Call]:
    for stmt in body:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            name = None
            if isinstance(node.func, ast.Attribute):
                name = node.func.attr
            elif isinstance(node.func, ast.Name):
                name = node.func.id
            if name in policy.ordering_sinks:
                return node
    return None


def _order_insensitive_body(body: list, policy) -> bool:
    """True when every statement in the loop body only folds into sets
    (``x.add(...)``/``update``/``discard``), possibly behind guards —
    the one loop shape whose result cannot depend on iteration order."""
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue, ast.Break)):
            continue
        if isinstance(stmt, ast.If):
            if not _order_insensitive_body(stmt.body, policy):
                return False
            if not _order_insensitive_body(stmt.orelse, policy):
                return False
            continue
        if (isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call)
                and isinstance(stmt.value.func, ast.Attribute)
                and stmt.value.func.attr in policy.order_insensitive_calls):
            continue
        return False
    return True


class _DetVisitor(ScopedVisitor):
    def __init__(self, ctx: FileContext, policy):
        super().__init__(ctx)
        self.policy = policy
        self.in_clock_zone = policy.in_virtual_clock_zone(ctx.relpath)
        self.in_set_zone = policy.in_set_iter_zone(ctx.relpath)
        self._setvar_stack: list[set] = [_collect_set_vars(ctx.tree, policy)]
        # comprehension/loop iterables already passed through a sanitizer
        self._sanitized: set = set()

    # -------------------------------------------------------------- calls
    def visit_Call(self, node: ast.Call) -> None:
        policy = self.policy
        target = self.ctx.imports.resolve_call(node.func)
        if target is not None:
            if target in policy.global_rng_calls:
                self.emit(node, "determinism/unseeded-rng",
                          f"call to {target}() draws from global RNG state; "
                          "use an explicitly seeded np.random.default_rng / "
                          "SeedSequence")
            elif (target in policy.seed_required_calls
                  and not node.args and not node.keywords):
                self.emit(node, "determinism/unseeded-rng",
                          f"{target}() without a seed is entropy-seeded; "
                          "pass an explicit seed")
            elif self.in_clock_zone and target in policy.wallclock_calls:
                self.emit(node, "determinism/wall-clock",
                          f"wall-clock call {target}() in the virtual-clock "
                          "zone; scheduling code must use the event clock "
                          "(scheduler.now)")
        if (isinstance(node.func, ast.Name)
                and node.func.id in _SANITIZERS):
            for arg in node.args:
                self._sanitized.add(id(arg))
        self.generic_visit(node)

    # -------------------------------------------------------------- loops
    def _check_iteration(self, iter_node: ast.expr, body: list,
                         where: ast.AST, kind: str) -> None:
        if not self.in_set_zone or id(iter_node) in self._sanitized:
            return
        policy = self.policy
        setvars = self._setvar_stack[-1]
        if _is_set_expr(iter_node, setvars, policy):
            sink = _find_sink(body, policy) if body else None
            if sink is not None:
                name = (sink.func.attr if isinstance(sink.func, ast.Attribute)
                        else sink.func.id)  # type: ignore[union-attr]
                self.emit(where, "determinism/set-iteration",
                          f"{kind} over a set feeds ordering-sensitive "
                          f"sink {name}(); iterate sorted(...) instead")
            elif not (body and _order_insensitive_body(body, policy)):
                self.emit(where, "determinism/set-iteration",
                          f"{kind} over a set exposes hash order; wrap in "
                          "sorted(...) or fold order-insensitively")
        else:
            view = _is_dict_view(iter_node)
            if view is not None and body:
                sink = _find_sink(body, policy)
                if sink is not None:
                    name = (sink.func.attr
                            if isinstance(sink.func, ast.Attribute)
                            else sink.func.id)  # type: ignore[union-attr]
                    self.emit(
                        where, "determinism/set-iteration",
                        f"{kind} over dict.{view}() feeds ordering-"
                        f"sensitive sink {name}(); make the order explicit "
                        "(sorted or an ordered key list)")

    def visit_For(self, node: ast.For) -> None:
        self._check_iteration(node.iter, node.body, node, "iteration")
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            # a set comprehension re-folding a set stays order-insensitive
            if not isinstance(node, ast.SetComp):
                self._check_iteration(gen.iter, [], node, "comprehension")
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_GeneratorExp = _visit_comp
    visit_DictComp = _visit_comp
    visit_SetComp = _visit_comp

    # ------------------------------------------------------ function scope
    def _visit_func(self, node) -> None:
        self._setvar_stack.append(
            _collect_set_vars(node, self.policy)
            | self._setvar_stack[0])
        super()._visit_func(node)
        self._setvar_stack.pop()


class DeterminismChecker:
    name = "determinism"

    def __init__(self, policy):
        self.policy = policy

    def check(self, ctx: FileContext) -> list[Finding]:
        v = _DetVisitor(ctx, self.policy)
        v.visit(ctx.tree)
        return v.findings
