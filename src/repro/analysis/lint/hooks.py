"""Hook-passivity checker: observability must observe, never steer.

Two rules:

* ``hooks/obs-mutation`` — inside ``repro/obs/``, a function must never
  write to or call a mutating method on an object that was *passed in*
  (the scheduler, dispatcher, jobs, requests...).  Recorder-owned state
  (anything rooted at ``self`` or built locally) is fair game.  Local
  aliases of parameters (``s = sched``; ``s.x = 1``) are tracked.
* ``hooks/unguarded-hook`` — in the scheduler file, every call through a
  hook attribute (``self.obs.…`` / ``self.telemetry.…``) must sit under a
  guard that mentions that attribute (``if self.obs is not None: …``), so
  the knobs-off path provably never touches the obs layer.

Both rules are syntactic over-approximations on purpose: obs code that
wants to do something clever can carry an inline suppression with a
justification, which is exactly the review surface we want.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.lint.framework import (
    FileContext,
    Finding,
    ScopedVisitor,
    attr_chain,
)

MUTATION_RULE = "hooks/obs-mutation"
GUARD_RULE = "hooks/unguarded-hook"


def _chain_root(node: ast.expr) -> Optional[str]:
    """Base Name of an attribute/subscript chain, or None for anything
    passing through a call or other opaque expression."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _param_names(func) -> set:
    a = func.args
    names = [p.arg for p in
             (a.posonlyargs + a.args + a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n not in ("self", "cls")}


def _param_rooted_names(func, params: set) -> set:
    """params plus local names assigned from param-rooted chains."""
    rooted = set(params)
    for node in ast.walk(func):
        if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.NamedExpr)):
            continue
        value = node.value
        if value is None:
            continue
        root = _chain_root(value) if isinstance(
            value, (ast.Name, ast.Attribute, ast.Subscript)) else None
        if root in rooted:
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Name):
                    rooted.add(t.id)
    return rooted


class _ObsVisitor(ScopedVisitor):
    """Passivity pass over one obs/ file."""

    def __init__(self, ctx: FileContext, policy):
        super().__init__(ctx)
        self.policy = policy
        self._rooted_stack: list[set] = [set()]

    def _visit_func(self, node) -> None:
        self._rooted_stack.append(
            _param_rooted_names(node, _param_names(node)))
        super()._visit_func(node)
        self._rooted_stack.pop()

    def _foreign(self, node: ast.expr) -> Optional[str]:
        root = _chain_root(node)
        if root is not None and root in self._rooted_stack[-1]:
            return root
        return None

    def _check_store(self, target: ast.expr, node: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._check_store(el, node)
            return
        if isinstance(target, ast.Starred):
            self._check_store(target.value, node)
            return
        if isinstance(target, (ast.Attribute, ast.Subscript)):
            root = self._foreign(target)
            if root is not None:
                self.emit(node, MUTATION_RULE,
                          f"obs hook writes to passed-in object {root!r}; "
                          "recording paths must be record-only")

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._check_store(t, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_store(node.target, node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._check_store(node.target, node)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._check_store(t, node)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in self.policy.mutator_calls:
                root = self._foreign(node.func.value)
                if root is not None:
                    self.emit(
                        node, MUTATION_RULE,
                        f"obs hook calls mutator .{node.func.attr}() on "
                        f"passed-in object {root!r}; recording paths must "
                        "be record-only")
        self.generic_visit(node)


def _mentions_hook_attr(test: ast.expr, attr: str) -> bool:
    for node in ast.walk(test):
        if (isinstance(node, ast.Attribute) and node.attr == attr
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return True
    return False


class _GuardWalker:
    """Recursive walker carrying the active guard tests, including
    short-circuit BoolOp prefixes (``self.obs and self.obs.f()``)."""

    def __init__(self, ctx: FileContext, policy):
        self.ctx = ctx
        self.policy = policy
        self.findings: list[Finding] = []

    def walk(self, node: ast.AST, guards: tuple) -> None:
        if isinstance(node, ast.If) or isinstance(node, ast.IfExp):
            self.walk(node.test, guards)
            body = node.body if isinstance(node.body, list) else [node.body]
            orelse = (node.orelse if isinstance(node.orelse, list)
                      else [node.orelse])
            for child in body:
                self.walk(child, guards + (node.test,))
            for child in orelse:
                self.walk(child, guards)
            return
        if isinstance(node, ast.BoolOp) and isinstance(node.op, ast.And):
            seen: tuple = guards
            for value in node.values:
                self.walk(value, seen)
                seen = seen + (value,)
            return
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if (chain is not None and len(chain) >= 3
                    and chain[0] == "self"
                    and chain[1] in self.policy.hook_attrs):
                attr = chain[1]
                if not any(_mentions_hook_attr(g, attr) for g in guards):
                    self.findings.append(Finding(
                        path=self.ctx.relpath, line=node.lineno,
                        col=node.col_offset, rule=GUARD_RULE,
                        message=(
                            f"hook call self.{attr}."
                            f"{'.'.join(chain[2:])}() is not guarded by "
                            f"'if self.{attr} is not None'; the knobs-off "
                            "path must never touch the obs layer")))
        for child in ast.iter_child_nodes(node):
            self.walk(child, guards)


class HooksChecker:
    name = "hooks"

    def __init__(self, policy):
        self.policy = policy

    def check(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        if self.policy.in_obs_zone(ctx.relpath):
            v = _ObsVisitor(ctx, self.policy)
            v.visit(ctx.tree)
            findings.extend(v.findings)
        if ctx.relpath == self.policy.hook_file:
            w = _GuardWalker(ctx, self.policy)
            w.walk(ctx.tree, ())
            findings.extend(w.findings)
        return findings
