"""repro-lint: AST-based invariant analyzer for the repro codebase.

Four checkers share one visitor framework (``framework.py``), a per-package
policy (``policy.py``), inline ``# repro-lint: disable=<rule>`` suppressions
and a JSON report artifact:

* ``determinism`` — unseeded RNG, wall-clock calls where only the virtual
  clock is allowed, and order-sensitive iteration over hash-ordered
  containers (``determinism/unseeded-rng``, ``determinism/wall-clock``,
  ``determinism/set-iteration``).
* ``registry`` — stage-kind string branching outside the stage registry
  (``registry/kind-branch``); the AST replacement for the old CI grep.
* ``hooks`` — obs/ recording paths stay record-only and every hook callsite
  in the scheduler is knob-guarded (``hooks/obs-mutation``,
  ``hooks/unguarded-hook``).
* ``ownership`` — ``@owned_by``/``@handoff`` thread-domain discipline
  (``ownership/cross-domain-write``, ``ownership/cross-domain-call``).

Run with ``python -m repro.analysis.lint`` (see ``__main__.py``).
"""
from repro.analysis.lint.framework import (  # noqa: F401
    Finding,
    LintReport,
    run_lint,
)
from repro.analysis.lint.policy import DEFAULT_POLICY, Policy  # noqa: F401

ALL_RULES = (
    "determinism/unseeded-rng",
    "determinism/wall-clock",
    "determinism/set-iteration",
    "registry/kind-branch",
    "hooks/obs-mutation",
    "hooks/unguarded-hook",
    "ownership/cross-domain-write",
    "ownership/cross-domain-call",
)
