"""Thread-ownership checker: static groundwork for the ingress thread.

Classes annotated ``@owned_by(domain, expose=(...))`` (see
``repro/core/ownership.py``) declare which logical thread domain owns
their mutable state; methods annotated ``@handoff(*callers)`` are the
sanctioned cross-domain entry points.  The checker runs in two phases:

1. **collect** (all files): domain declarations, handoff methods, exposed
   fields, and handle inference — ``self.sched = WavefrontScheduler(...)``
   inside an owned class records that its ``sched`` field holds a
   scheduler-domain object.
2. **check** (per file): inside a method of a class owned by domain A, an
   access through a cross-domain handle (a field inferred to hold a
   domain-B object, B != A) is flagged when it is

   * a *write* past the handle (``self.sched.now = 5``,
     ``self.sched.active.append(r)`` via a mutator name), rule
     ``ownership/cross-domain-write``; rebinding the handle itself
     (``self.sched = ...``) is ownership of the *reference* and stays
     legal, or
   * a *method call* that is neither a declared ``@handoff`` for domain A
     nor routed through an ``expose``-listed read surface, rule
     ``ownership/cross-domain-call``.

   Plain attribute reads are allowed (single-writer snapshots); local
   aliases of cross-domain handles (``tel = self.sched.telemetry``) are
   followed.

This is deliberately lightweight: it reasons only about ``self``-rooted
chains inside annotated classes, so unannotated glue code (launch
scripts, tests) incurs no obligations.  The point is that when the
wall-clock ingress thread lands, every scheduler-state touch from the
server side is already enumerated — each ``@handoff`` is a place to put a
lock or queue crossing.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Optional

from repro.analysis.lint.framework import (
    FileContext,
    Finding,
    ScopedVisitor,
    attr_chain,
)

WRITE_RULE = "ownership/cross-domain-write"
CALL_RULE = "ownership/cross-domain-call"


def _decorator_call(dec: ast.expr, name: str) -> Optional[ast.Call]:
    if isinstance(dec, ast.Call):
        f = dec.func
        if (isinstance(f, ast.Name) and f.id == name) or (
                isinstance(f, ast.Attribute) and f.attr == name):
            return dec
    return None


def _str_args(call: ast.Call) -> list:
    return [a.value for a in call.args
            if isinstance(a, ast.Constant) and isinstance(a.value, str)]


@dataclasses.dataclass
class OwnedClass:
    name: str
    domain: str
    expose: tuple = ()
    handoffs: dict = dataclasses.field(default_factory=dict)  # method -> callers


class OwnershipChecker:
    name = "ownership"

    def __init__(self, policy):
        self.policy = policy
        self.classes: dict[str, OwnedClass] = {}
        # (owner class name, attr) -> handle's target class name
        self.handles: dict[tuple, str] = {}

    # ------------------------------------------------------------- phase 1
    def collect(self, ctx: FileContext) -> None:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            owned = None
            for dec in node.decorator_list:
                call = _decorator_call(dec, "owned_by")
                if call is None:
                    continue
                domains = _str_args(call)
                expose: tuple = ()
                for kw in call.keywords:
                    if kw.arg == "expose" and isinstance(
                            kw.value, (ast.Tuple, ast.List)):
                        expose = tuple(
                            e.value for e in kw.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str))
                if domains:
                    owned = OwnedClass(node.name, domains[0], expose)
            if owned is None:
                continue
            self.classes[node.name] = owned
            for item in node.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                for dec in item.decorator_list:
                    call = _decorator_call(dec, "handoff")
                    if call is not None:
                        callers = tuple(_str_args(call)) or ("*",)
                        owned.handoffs[item.name] = callers
            # handle inference: self.<attr> = SomeOwnedClass(...)
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Assign):
                    continue
                value = sub.value
                if not isinstance(value, ast.Call):
                    continue
                cname = None
                if isinstance(value.func, ast.Name):
                    cname = value.func.id
                elif isinstance(value.func, ast.Attribute):
                    cname = value.func.attr
                if cname is None:
                    continue
                for t in sub.targets:
                    chain = attr_chain(t) if isinstance(
                        t, ast.Attribute) else None
                    if chain and len(chain) == 2 and chain[0] == "self":
                        self.handles[(node.name, chain[1])] = cname

    # ------------------------------------------------------------- phase 2
    def check(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef) and node.name in self.classes:
                v = _OwnershipVisitor(ctx, self.policy, self, node)
                v.visit(node)
                findings.extend(v.findings)
        return findings

    def handle_target(self, owner_cls: str, attr: str) -> Optional[OwnedClass]:
        cname = self.handles.get((owner_cls, attr))
        if cname is None:
            return None
        return self.classes.get(cname)


class _OwnershipVisitor(ScopedVisitor):
    def __init__(self, ctx: FileContext, policy, checker: OwnershipChecker,
                 cls: ast.ClassDef):
        super().__init__(ctx)
        self.policy = policy
        self.checker = checker
        self.cls = cls
        self.owned = checker.classes[cls.name]
        # local alias name -> (handle attr, subchain after the handle)
        self._alias_stack: list[dict] = [{}]

    # ----------------------------------------------------------- resolution
    def _cross_handle(self, attr: str) -> Optional[OwnedClass]:
        target = self.checker.handle_target(self.cls.name, attr)
        if target is not None and target.domain != self.owned.domain:
            return target
        return None

    def _resolve(self, node: ast.expr) -> Optional[tuple]:
        """Resolve an expression to (target OwnedClass, subchain) when it is
        rooted at a cross-domain handle, following local aliases."""
        chain = attr_chain(node)
        if chain is None:
            return None
        if chain[0] == "self" and len(chain) >= 2:
            target = self._cross_handle(chain[1])
            if target is not None:
                return target, chain[2:]
            return None
        alias = self._alias_stack[-1].get(chain[0])
        if alias is not None:
            attr, sub = alias
            target = self._cross_handle(attr)
            if target is not None:
                return target, list(sub) + chain[1:]
        return None

    def _visit_func(self, node) -> None:
        aliases: dict = dict(self._alias_stack[-1])
        for sub in ast.walk(node):
            if not isinstance(sub, (ast.Assign, ast.NamedExpr)):
                continue
            value = sub.value
            chain = attr_chain(value) if isinstance(
                value, ast.Attribute) else None
            if chain and chain[0] == "self" and len(chain) >= 2:
                if self._cross_handle(chain[1]) is not None:
                    targets = (sub.targets if isinstance(sub, ast.Assign)
                               else [sub.target])
                    for t in targets:
                        if isinstance(t, ast.Name):
                            aliases[t.id] = (chain[1], chain[2:])
        self._alias_stack.append(aliases)
        super()._visit_func(node)
        self._alias_stack.pop()

    # --------------------------------------------------------------- writes
    def _check_store(self, target: ast.expr, node: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._check_store(el, node)
            return
        if isinstance(target, ast.Starred):
            target = target.value
        if isinstance(target, ast.Name):
            # rebinding a local (even one aliasing a cross-domain handle)
            # only changes the local namespace, never foreign state
            return
        base = target
        depth_past_handle = isinstance(base, ast.Subscript)
        while isinstance(base, ast.Subscript):
            base = base.value
            if isinstance(base, ast.Subscript):
                continue
        if not isinstance(base, (ast.Attribute, ast.Name)):
            return
        resolved = self._resolve(base)
        if resolved is None:
            return
        target_cls, sub = resolved
        # rebinding the handle itself (subchain empty, no subscript) is the
        # owner managing its own reference, not a foreign-state write
        if not sub and not depth_past_handle:
            return
        self.emit(node, WRITE_RULE,
                  f"{self.owned.domain!r}-domain code writes "
                  f"{target_cls.domain!r}-owned state "
                  f"({target_cls.name}.{'.'.join(sub) or '[...]'}); route "
                  "the mutation through a declared @handoff method")

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._check_store(t, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_store(node.target, node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._check_store(node.target, node)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._check_store(t, node)
        self.generic_visit(node)

    # ---------------------------------------------------------------- calls
    def visit_Call(self, node: ast.Call) -> None:
        resolved = (self._resolve(node.func)
                    if isinstance(node.func, ast.Attribute) else None)
        if resolved is not None:
            target_cls, sub = resolved
            if sub:
                ok = False
                if len(sub) == 1:
                    callers = target_cls.handoffs.get(sub[0])
                    ok = callers is not None and (
                        "*" in callers or self.owned.domain in callers)
                else:
                    ok = sub[0] in target_cls.expose
                if not ok:
                    self.emit(
                        node, CALL_RULE,
                        f"{self.owned.domain!r}-domain call to "
                        f"{target_cls.name}.{'.'.join(sub)}() is not a "
                        "declared @handoff and not routed through an "
                        "exposed read surface")
        self.generic_visit(node)
