"""Logical-axis sharding rules for every architecture in the zoo.

Scheme (baseline; §Perf hillclimbs depart from it per-cell):

* 2-D weight sharding: tensor-parallel over ``model``, FSDP over ``data``
  (and ``pod`` stays pure DP).  Stacked layer axes are never sharded.
* vocab-parallel embedding/head over ``model``.
* MoE expert axis over ``model`` (+ FSDP over ``data``) — expert parallelism;
  the capacity-dispatch scatter becomes XLA all-to-alls.
* KV caches: batch over data axes; heads over ``model`` when divisible,
  else head_dim (partial-sum attention), else replicated.
* ``long_500k`` (batch 1): the cache *sequence* axis shards over ``data`` —
  sequence parallelism is the only way a 500k-token cache spreads.

Everything is derived from pytree paths + shapes, so new layer types get
rules by name here, not by editing model code.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


# ---------------------------------------------------------------------------
# Layouts: how the fixed physical mesh axes map to logical roles.
#   'tp'       — data axes = (pod, data); model axis = tensor parallel;
#                weights FSDP-sharded over data (gathered per traversal)
#   'serve_tp' — like 'tp' but weights are TP-resident ONLY (replicated over
#                the data axes): no per-step weight all-gathers — the right
#                inference layout whenever W/tp fits HBM (§Perf decode cells)
#   'dp_only'  — model axis joins the data axes (pure FSDP/DP; right choice
#                for small archs where TP all-reduces dominate — see §Perf)
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh, layout: str = "tp"):
    names = ("pod", "data", "model") if layout == "dp_only" else ("pod", "data")
    axes = tuple(a for a in names if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def tp_axis(mesh: Mesh, layout: str = "tp"):
    if layout in ("tp", "serve_tp") and "model" in mesh.axis_names:
        return "model"
    return None


def dp_size(mesh: Mesh, layout: str = "tp") -> int:
    n = _axis_size(mesh, "pod") * _axis_size(mesh, "data")
    if layout == "dp_only":
        n *= _axis_size(mesh, "model")
    return n


def _div(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= _axis_size(mesh, a)
        return n % size == 0
    return n % _axis_size(mesh, axis) == 0


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

_LAST2_RULES: dict[str, tuple[Optional[str], Optional[str]]] = {
    # name -> (spec for dim -2, spec for dim -1); leading dims unsharded
    # (stacked layer axes) unless MoE handles them explicitly.
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "wq_a": ("data", None),
    "wq_b": (None, "model"),
    "wkv_a": ("data", None),
    "wk_b": (None, "model"),
    "wv_b": (None, "model"),
    "w1": ("data", "model"),
    "w3": ("data", "model"),
    "w2": ("model", "data"),
    "sw1": ("data", "model"),
    "sw3": ("data", "model"),
    "sw2": ("model", "data"),
    "wg": ("data", "model"),
    "wr": ("data", "model"),
    "wd_w1": (None, None),
    "wd_w2": (None, None),
    "tm_w1": (None, None),
    "tm_w2": (None, None),
    "w_in1": ("data", "model"),
    "w_in2": ("data", "model"),
    "w_out": ("model", "data"),
    "w_a": ("data", "model"),
    "w_x": ("data", "model"),
    "router": (None, None),
}

_VEC_MODEL = {"bq", "bk", "bv", "lam", "b_a", "b_x", "conv_b"}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def param_spec(cfg: ModelConfig, mesh: Mesh, path, leaf, layout: str = "tp") -> P:
    names = _path_names(path)
    name = names[-1]
    shape = leaf.shape
    nd = len(shape)
    # rule tokens -> physical axes under this layout
    if layout == "dp_only":
        fsdp = ("data", "model")
    elif layout == "serve_tp":
        fsdp = None  # weights TP-resident, replicated over data axes
    else:
        fsdp = "data"
    tp = tp_axis(mesh, layout)

    def ax(token, dim):
        a = {"data": fsdp, "model": tp}.get(token, token)
        return a if (a and _div(dim, mesh, a)) else None

    if name == "embed":  # (V, d): vocab-parallel + FSDP on d
        v_ax = ax("model", shape[0]) or ax("data", shape[0])
        d_ax = ax("data", shape[1]) if v_ax != fsdp else None
        return P(v_ax, d_ax)
    if name == "lm_head":  # (d, V)
        v_ax = ax("model", shape[1]) or ax("data", shape[1])
        d_ax = ax("data", shape[0]) if v_ax != fsdp else None
        return P(d_ax, v_ax)
    if name == "u":  # rwkv bonus (L, H, N)
        return P(*([None] * (nd - 2)), ax("model", shape[-2]), None)

    is_moe = "ffn" in names and name in ("w1", "w2", "w3") and nd >= 3 and (
        cfg.n_experts and shape[-3] == cfg.n_experts
    )
    if is_moe:
        # (..., E, d, ff) or (..., E, ff, d): expert-parallel over model,
        # FSDP over data on the d dim
        a, b = _LAST2_RULES[name]
        lead = [None] * (nd - 3)
        spec2 = [
            ax(a, shape[-2]) if a == "data" else None,
            ax(b, shape[-1]) if b == "data" else None,
        ]
        e_ax = ax("model", cfg.n_experts) or (
            ax("data", cfg.n_experts) if layout != "tp" else None
        )
        if e_ax == fsdp:  # expert dim took the fsdp axes; drop from dims
            spec2 = [None, None]
        return P(*lead, e_ax, *spec2)

    if name in _LAST2_RULES and nd >= 2:
        a, b = _LAST2_RULES[name]
        lead = [None] * (nd - 2)
        return P(*lead, ax(a, shape[-2]), ax(b, shape[-1]))
    if name in _VEC_MODEL and nd >= 1:
        lead = [None] * (nd - 1)
        return P(*lead, ax("model", shape[-1]))
    # norms, small loras, scalars: replicated
    return P(*([None] * nd))


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape,
                    layout: str = "tp") -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(cfg, mesh, path, leaf, layout)),
        params_shape,
    )


# ---------------------------------------------------------------------------
# Batch / decode-state rules
# ---------------------------------------------------------------------------


def batch_spec(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
               layout: str = "tp") -> dict:
    dp = dp_axes(mesh, layout)
    sharded_b = shape.global_batch % dp_size(mesh, layout) == 0
    bax = dp if sharded_b else None
    out = {
        "tokens": P(bax, None),
        "labels": P(bax, None),
    }
    if cfg.n_prefix_embeds:
        out["prefix_embeds"] = P(bax, None, None)
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = P(bax, None, None)
    return out


def decode_state_spec(cfg: ModelConfig, mesh: Mesh, batch: int, path, leaf,
                      layout: str = "tp") -> P:
    """Sharding for one leaf of the DecodeState pytree (leading dim = stacked
    layers within a segment for everything except cache_len).

    Caches shard: batch -> dp axes; *sequence* -> model axis (distributed
    softmax: XLA turns the masked softmax over a sharded S into local work +
    tiny reduction all-reduces — the sequence-sharded flash-decoding layout).
    Batch-1 long-context additionally shards S over the data axes.
    """
    names = _path_names(path)
    name = names[-1]
    dp = dp_axes(mesh, layout)
    tp = tp_axis(mesh, layout)
    sharded_b = batch % dp_size(mesh, layout) == 0
    bax = dp if sharded_b else None
    nd = len(leaf.shape)

    if name == "cache_len":
        return P(bax)

    def seq_axes(S: int):
        axes = []
        if tp and S % _axis_size(mesh, tp) == 0 and S > 1:
            axes.append(tp)
        if not sharded_b and nd >= 3 and S > 1:
            size = dp_size(mesh, layout)
            if (S // (int(np.prod([_axis_size(mesh, a) for a in axes])) or 1)) % size == 0:
                axes = (list(dp) if isinstance(dp, tuple) else [dp]) + axes
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    def mod_ax(dim: int):
        return tp if (tp and dim % _axis_size(mesh, tp) == 0) else None

    if name in ("k", "v"):  # (L, B, S, KV, dh)
        sax = seq_axes(leaf.shape[2])
        if sax is None and tp and leaf.shape[3] % _axis_size(mesh, tp) == 0:
            # sequence not shardable (e.g. enc-dec cross KV, 1500 frames):
            # shard heads instead so per-step reshards disappear
            return P(None, bax, None, tp, None)
        return P(None, bax, sax, None, None)
    if name in ("k_scale", "v_scale"):  # (L, B, S, KV) int8-cache scales
        return P(None, bax, seq_axes(leaf.shape[2]), None)
    if name == "ckv":  # (L, B, S, r)
        return P(None, bax, seq_axes(leaf.shape[2]), None)
    if name == "kpe":  # (L, B, S, rope_dim)
        return P(None, bax, seq_axes(leaf.shape[2]), None)
    if name == "S":  # rwkv state (L, B, H, N, N)
        return P(None, bax, mod_ax(leaf.shape[2]), None, None)
    if name == "x_prev":  # (L, B, 1, d)
        return P(None, bax, None, mod_ax(leaf.shape[-1]))
    if name == "h":  # rglru (L, B, W)
        return P(None, bax, mod_ax(leaf.shape[-1]))
    if name == "conv":  # (L, B, cw-1, W)
        return P(None, bax, None, mod_ax(leaf.shape[-1]))
    if name == "ffn":  # rwkv cmix token shift (L, B, 1, d)
        return P(None, bax, None, mod_ax(leaf.shape[-1]))
    # enc_kv k/v handled by ("k","v") above; default: batch only
    spec = [None] * nd
    if nd >= 2:
        spec[1] = bax
    return P(*spec)


def decode_state_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, state_shape,
                           layout: str = "tp"):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, decode_state_spec(cfg, mesh, batch, path, leaf, layout)
        ),
        state_shape,
    )


def to_named(mesh: Mesh, tree_of_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
