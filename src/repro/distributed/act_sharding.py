"""Activation sharding constraints (logical axes 'dp'/'tp').

Model code calls ``constrain(x, 'dp', None, 'tp', None)``-style hints at the
canonical points (post-QKV, FFN hidden, MoE dispatch buffers...).  Outside a
``use_mesh`` scope these are no-ops, so single-device smoke tests and the
Pallas interpret paths never see a mesh.  Axes that do not divide the
corresponding dimension are dropped per-dimension — the same divisibility
policy as the parameter rules, which is what keeps one rule set valid for
all 10 architectures x 4 shapes x 2 meshes.

Without these constraints XLA's SPMD partitioner resolves the GQA
(kv_heads < tp) contraction by sharding head_dim and all-reducing full
attention-score tensors — ~GBs per layer.  With them, k/v stay
head-replicated and the schedule collapses to the expected
all-gather(weights)/reduce-scatter(grads) pattern.  (Found in the first
dry-run iteration; see EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, layout: str = "tp"):
    prev, prev_layout = _mesh(), getattr(_STATE, "layout", "tp")
    _STATE.mesh = mesh
    _STATE.layout = layout
    try:
        yield
    finally:
        _STATE.mesh = prev
        _STATE.layout = prev_layout


def active() -> bool:
    return _mesh() is not None


def _resolve(mesh: Mesh, dim: int, ax):
    """logical 'dp'/'tp' -> mesh axes, dropped unless they divide dim."""
    layout = getattr(_STATE, "layout", "tp")
    if ax is None:
        return None
    if ax == "tp":
        names = (("model",) if (layout in ("tp", "serve_tp")
                                and "model" in mesh.axis_names) else ())
    elif ax == "dp":
        pool = (("pod", "data", "model") if layout == "dp_only"
                else ("pod", "data"))
        names = tuple(a for a in pool if a in mesh.axis_names)
    else:
        names = (ax,) if ax in mesh.axis_names else ()
    size = 1
    for n in names:
        size *= mesh.shape[n]
    if not names or size == 0 or dim % size != 0:
        return None
    return names if len(names) > 1 else names[0]


def constrain(x: jax.Array, *spec):
    mesh = _mesh()
    if mesh is None:
        return x
    assert len(spec) == x.ndim, f"spec rank {len(spec)} vs array rank {x.ndim}"
    resolved = [_resolve(mesh, d, a) for d, a in zip(x.shape, spec)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )


def dp_total() -> int:
    """Size of the current data-parallel axis pool (1 outside a mesh scope).
    Model code uses this to pick per-shard dispatch granularity (MoE)."""
    mesh = _mesh()
    if mesh is None:
        return 1
    layout = getattr(_STATE, "layout", "tp")
    pool = (("pod", "data", "model") if layout == "dp_only" else ("pod", "data"))
    size = 1
    for a in pool:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size
