"""Elastic scaling + failure handling for long-running jobs.

Policy (documented for the 1000+-node posture; exercised in tests on the
host mesh):

* **Checkpoint/restart** — training saves every N steps (atomic, pruned);
  on restart the launcher restores the latest step and the data pipeline
  resumes deterministically from it (data.py is stateless-per-step).
* **Re-mesh** — when the healthy device count changes, a new mesh is built,
  train_step re-jitted with the same PartitionSpec rules (they only consult
  divisibility, so smaller/larger data axes work), and the checkpoint is
  restored with the new shardings; global batch is preserved by scaling the
  microbatch count.
* **Straggler mitigation** — serving side: sub-stages are the re-dispatch
  quantum (wavefront scheduler); training side: the pod axis is pure DP, so
  a slow pod bounds step time — the launcher monitors step-time EMA and
  triggers re-mesh when a pod exceeds ``straggler_factor`` x median for
  ``patience`` consecutive steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.training import checkpoint as ckpt


@dataclasses.dataclass
class ElasticConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    save_every: int = 50
    keep: int = 3
    straggler_factor: float = 2.0
    patience: int = 5


class ElasticRunner:
    """Wraps a (re)jittable train loop with checkpoint/restart + re-mesh."""

    def __init__(self, cfg: ElasticConfig, build_mesh: Callable[[], jax.sharding.Mesh],
                 build_step: Callable[[jax.sharding.Mesh], Callable]):
        self.cfg = cfg
        self.build_mesh = build_mesh
        self.build_step = build_step
        self._slow_streak = 0

    def resume_or_init(self, init_fn, shardings_fn):
        mesh = self.build_mesh()
        step_fn = self.build_step(mesh)
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is None:
            state = init_fn(mesh)
            start = 0
        else:
            like = jax.eval_shape(lambda: init_fn(mesh))
            start, state, _ = ckpt.restore_checkpoint(
                self.cfg.ckpt_dir, last, like=like, shardings=shardings_fn(mesh, like)
            )
        return mesh, step_fn, state, start

    def maybe_save(self, step: int, state) -> Optional[str]:
        if step % self.cfg.save_every == 0 and step > 0:
            return ckpt.save_checkpoint(self.cfg.ckpt_dir, step, state,
                                        keep=self.cfg.keep)
        return None

    def observe_step_time(self, dt: float, median_dt: float) -> bool:
        """Returns True when a re-mesh should be triggered (straggler)."""
        if median_dt > 0 and dt > self.cfg.straggler_factor * median_dt:
            self._slow_streak += 1
        else:
            self._slow_streak = 0
        return self._slow_streak >= self.cfg.patience
