"""RWKV-6 "Finch" time-mix (arXiv:2404.05892) — data-dependent per-channel
decay linear recurrence.

TPU adaptation: instead of a token-sequential CUDA recurrence we use a
*chunked* parallel form (GLA-style).  Within a chunk of L tokens all work is
dense einsums (MXU-friendly); chunks are processed with a ``lax.scan``
carrying the (B, H, N, N) state.  Numerics: the pairwise decay exponent
``p_excl[t] - P[s]`` is computed explicitly per (t, s, n) and is always <= 0
for s < t, so the chunked form is exp-overflow-safe at any decay rate (this
is why we keep L modest, default 32..128: the (L, L, N) exponent tensor stays
in VMEM range).

State layout (decode):  {"S": (B, H, N, N) f32, "x_prev": (B, 1, d)}
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, Segment
from repro.distributed.act_sharding import constrain
from repro.models.layers import _dense, dtype_of

f32 = jnp.float32

TIME_MIX_EXTRA_DIM = 32


def init_timemix(cfg: ModelConfig, seg: Segment, key) -> dict:
    dt = dtype_of(cfg)
    d = cfg.d_model
    H, N = cfg.rwkv_n_heads, cfg.rwkv_head_size
    A, D = TIME_MIX_EXTRA_DIM, cfg.rwkv_decay_lora
    ks = jax.random.split(key, 12)
    return {
        "mu_x": jnp.full((d,), 0.5, dt),
        "mu_5": jnp.full((5, d), 0.5, dt),  # base mix for (w, k, v, r, g)
        "tm_w1": _dense(ks[0], (d, 5 * A), dt),
        "tm_w2": _dense(ks[1], (5, A, d), dt, scale=0.1 / math.sqrt(A)),
        "wr": _dense(ks[2], (d, d), dt),
        "wk": _dense(ks[3], (d, d), dt),
        "wv": _dense(ks[4], (d, d), dt),
        "wg": _dense(ks[5], (d, d), dt),
        "w0": jnp.full((d,), -6.0, f32),  # decay base: w = -exp(w0 + lora)
        "wd_w1": _dense(ks[6], (d, D), dt),
        "wd_w2": _dense(ks[7], (D, d), dt, scale=0.1 / math.sqrt(D)),
        "u": (jax.random.normal(ks[8], (H, N), f32) * 0.1),  # bonus
        "ln_scale": jnp.ones((d,), dt),
        "ln_bias": jnp.zeros((d,), dt),
        "wo": _dense(ks[9], (d, d), dt),
    }


def _ddlerp(p: dict, x: jax.Array, xs: jax.Array):
    """Data-dependent token-shift mixing -> the 5 projected inputs."""
    dx = xs - x
    xxx = x + dx * p["mu_x"]
    a = jnp.tanh(xxx @ p["tm_w1"])  # (B, S, 5A)
    B, S, _ = a.shape
    a = a.reshape(B, S, 5, TIME_MIX_EXTRA_DIM)
    mix = jnp.einsum("bsfa,fad->bsfd", a, p["tm_w2"].astype(a.dtype))
    mix = mix + p["mu_5"]  # (B, S, 5, d)
    return [x + dx * mix[:, :, i] for i in range(5)]


def _project(cfg: ModelConfig, p: dict, x: jax.Array, xs: jax.Array):
    H, N = cfg.rwkv_n_heads, cfg.rwkv_head_size
    B, S, d = x.shape
    m_w, m_k, m_v, m_r, m_g = _ddlerp(p, x, xs)
    r = constrain((m_r @ p["wr"]).reshape(B, S, H, N), "dp", None, "tp", None)
    k = constrain((m_k @ p["wk"]).reshape(B, S, H, N), "dp", None, "tp", None)
    v = constrain((m_v @ p["wv"]).reshape(B, S, H, N), "dp", None, "tp", None)
    g = constrain(jax.nn.silu(m_g @ p["wg"]), "dp", None, "tp")
    lw = -jnp.exp(
        p["w0"] + (jnp.tanh(m_w @ p["wd_w1"]) @ p["wd_w2"]).astype(f32)
    )  # log decay, strictly negative; (B, S, d)
    lw = lw.reshape(B, S, H, N)
    return r, k, v, g, lw


def _group_norm(cfg: ModelConfig, p: dict, y: jax.Array) -> jax.Array:
    """Per-head group norm over (H, N) -> flattened d."""
    B, S, H, N = y.shape
    yf = y.astype(f32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yn = (yf - mu) * lax.rsqrt(var + 64e-5)
    yn = yn.reshape(B, S, H * N)
    return yn * p["ln_scale"].astype(f32) + p["ln_bias"].astype(f32)


def _chunk_scan(r, k, v, lw, u, S0, chunk: int = 32, unroll: bool = False):
    """Chunked WKV6: r,k,v,lw (B, S, H, N) fp32; S0 (B, H, N, N) fp32.

    Returns (y (B,S,H,N), S_final).  S is the k->v linear map:
        y_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(exp(lw_t)) S_{t-1} + k_t v_t^T
    """
    B, S, H, N = r.shape
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # zero k/v/r and zero log-decay (decay=1) leave the state untouched
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, lw = zpad(r), zpad(k), zpad(v), zpad(lw)
        S_orig = S
        S = S + pad
    nc = S // L

    def seq4(x):
        return x.reshape(B, nc, L, H, N).transpose(1, 0, 2, 3, 4)  # (nc,B,L,H,N)

    rc, kc, vc, lwc = map(seq4, (r, k, v, lw))

    def body(Sprev, inp):
        rr, kk, vv, ww = inp  # (B, L, H, N)
        P = jnp.cumsum(ww, axis=1)  # inclusive log-decay prefix
        p_excl = P - ww
        # inter-chunk: state contribution decayed to each t
        y = jnp.einsum("blhn,bhnm->blhm", rr * jnp.exp(p_excl), Sprev)
        # intra-chunk pairwise decays (always <= 0 where used)
        D = p_excl[:, :, None, :, :] - P[:, None, :, :, :]  # (B, t, s, H, N)
        t_idx = jnp.arange(L)
        causal = (t_idx[:, None] > t_idx[None, :])[None, :, :, None, None]
        E = jnp.where(causal, D, -jnp.inf)
        A = jnp.einsum("bthn,bshn,btshn->bths", rr, kk, jnp.exp(E))
        diag = jnp.einsum("bthn,hn,bthn->bth", rr, u, kk)  # bonus on s == t
        A = A + diag[:, :, :, None] * jnp.eye(L)[None, :, None, :]
        y = y + jnp.einsum("bths,bshm->bthm", A, vv)
        # state update: S_new = diag(exp(P_L)) S + sum_s (k_s e^{P_L - P_s}) v_s^T
        decay_all = jnp.exp(P[:, -1])  # (B, H, N)
        kd = kk * jnp.exp(P[:, -1:, :, :] - P)
        S_new = decay_all[..., None] * Sprev + jnp.einsum("blhn,blhm->bhnm", kd, vv)
        return S_new, y

    if unroll:
        Scur, ys = S0, []
        for i in range(nc):
            Scur, yi = body(Scur, (rc[i], kc[i], vc[i], lwc[i]))
            ys.append(yi)
        S_final, ys = Scur, jnp.stack(ys)
    else:
        S_final, ys = lax.scan(body, S0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
    if pad:
        y = y[:, :S_orig]
    return y, S_final


def timemix_init_state(cfg: ModelConfig, batch: int):
    H, N = cfg.rwkv_n_heads, cfg.rwkv_head_size
    return {
        "S": jnp.zeros((batch, H, N, N), f32),
        "x_prev": jnp.zeros((batch, 1, cfg.d_model), dtype_of(cfg)),
    }


def apply_timemix(cfg: ModelConfig, seg: Segment, p: dict, x: jax.Array, *, mode: str,
                  state=None, **_unused):
    B, S, d = x.shape
    H, N = cfg.rwkv_n_heads, cfg.rwkv_head_size
    u = p["u"]

    if mode in ("train", "prefill"):
        xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        r, k, v, g, lw = _project(cfg, p, x, xs)
        S0 = jnp.zeros((B, H, N, N), f32)
        y, S_fin = _chunk_scan(r.astype(f32), k.astype(f32), v.astype(f32), lw, u, S0,
                               chunk=cfg.rwkv_chunk, unroll=cfg.unroll_scans)
        out = _group_norm(cfg, p, y).astype(x.dtype) * g
        out = out @ p["wo"]
        st = None
        if mode == "prefill":
            st = {"S": S_fin, "x_prev": x[:, -1:, :]}
        return out, st

    # decode
    assert state is not None
    xs = state["x_prev"]
    r, k, v, g, lw = _project(cfg, p, x, xs)
    r1, k1, v1 = r[:, 0].astype(f32), k[:, 0].astype(f32), v[:, 0].astype(f32)
    Sm = state["S"]  # (B, H, N, N)
    kv = jnp.einsum("bhn,bhm->bhnm", k1, v1)
    y = jnp.einsum("bhn,bhnm->bhm", r1, Sm + u[None, :, :, None] * kv)
    S_new = jnp.exp(lw[:, 0])[..., None] * Sm + kv
    y = y[:, None]  # (B, 1, H, N) time axis
    y = y.reshape(B, 1, H, N)
    out = _group_norm(cfg, p, y).astype(x.dtype) * g
    out = out @ p["wo"]
    return out, {"S": S_new, "x_prev": x}
