from repro.models.lm import (
    decode_step,
    init_decode_state,
    init_params,
    prefill,
    train_loss,
)

__all__ = ["init_params", "train_loss", "prefill", "decode_step", "init_decode_state"]
