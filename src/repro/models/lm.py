"""Unified language model over heterogeneous layer stacks.

A model is a sequence of ``Segment`` runs (see configs.base).  Within each
segment parameters are stacked on a leading layer axis and executed with
``lax.scan`` — HLO size stays O(#segments), not O(#layers), which keeps the
80-layer / 32k-seq dry-runs compilable in seconds.

Entry points (all pure functions of (params, cfg, ...)):

  init_params(cfg, key)                          -> pytree
  train_loss(params, cfg, batch)                 -> scalar loss
  prefill(params, cfg, tokens, ...)              -> (last_logits, DecodeState)
  decode_step(params, cfg, tokens, state)        -> (logits, DecodeState)
  init_decode_state(cfg, batch, max_len)         -> DecodeState (zeros)

DecodeState = {"cache_len": (B,) i32, "segments": tuple[per-seg stacked state]}
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, Segment
from repro.distributed.act_sharding import constrain
from repro.models import rglru, rwkv6
from repro.models.layers import (
    apply_attention,
    apply_cross_attention,
    apply_ffn,
    apply_mla,
    apply_norm,
    attention_init_state,
    dtype_of,
    encode_cross_kv,
    ffn_init_state,
    init_attention,
    init_cross_attention,
    init_ffn,
    init_mla,
    init_norm,
    mla_init_state,
    sinusoidal_embedding,
    _dense,
)

f32 = jnp.float32

_MIXER_INIT = {
    "attn": init_attention,
    "local_attn": init_attention,
    "encoder_attn": init_attention,
    "mla": init_mla,
    "rwkv6": rwkv6.init_timemix,
    "rglru": rglru.init_rglru,
}

_MIXER_APPLY = {
    "attn": apply_attention,
    "local_attn": apply_attention,
    "encoder_attn": apply_attention,
    "mla": apply_mla,
    "rwkv6": rwkv6.apply_timemix,
    "rglru": rglru.apply_rglru,
}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, seg: Segment, key) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "norm1": init_norm(cfg, ks[0]),
        "mixer": _MIXER_INIT[seg.mixer](cfg, seg, ks[1]),
        "norm2": init_norm(cfg, ks[2]),
        "ffn": init_ffn(cfg, seg, ks[3]),
    }
    if seg.cross_attn:
        p["norm_x"] = init_norm(cfg, ks[4])
        p["cross"] = init_cross_attention(cfg, ks[5])
    return p


def _init_segment(cfg: ModelConfig, seg: Segment, key) -> dict:
    keys = jax.random.split(key, seg.repeat)
    return jax.vmap(lambda k: _init_layer(cfg, seg, k))(keys)


def init_params(cfg: ModelConfig, key) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4 + len(cfg.segments) + len(cfg.encoder_segments))
    params: dict = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), f32) * 0.02).astype(dt),
        "final_norm": init_norm(cfg, ks[1]),
        "segments": tuple(
            _init_segment(cfg, seg, ks[4 + i]) for i, seg in enumerate(cfg.segments)
        ),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(ks[2], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.is_encoder_decoder:
        off = 4 + len(cfg.segments)
        params["encoder"] = {
            "segments": tuple(
                _init_segment(cfg, seg, ks[off + i])
                for i, seg in enumerate(cfg.encoder_segments)
            ),
            "final_norm": init_norm(cfg, ks[3]),
        }
    return params


# ---------------------------------------------------------------------------
# Single transformer block
# ---------------------------------------------------------------------------


def _apply_block(
    cfg: ModelConfig,
    seg: Segment,
    p: dict,
    x: jax.Array,
    *,
    mode: str,
    positions,
    state: Optional[dict],
    cache_len,
    enc_out,
    max_len: int,
):
    st_in = state or {}
    h = apply_norm(cfg, p["norm1"], x)
    mix_out, mix_st = _MIXER_APPLY[seg.mixer](
        cfg, seg, p["mixer"], h,
        mode=mode, positions=positions, state=st_in.get("mixer"),
        cache_len=cache_len, max_len=max_len,
    )
    x = x + mix_out

    new_state: dict = {}
    if mix_st is not None:
        new_state["mixer"] = mix_st

    if seg.cross_attn:
        h = apply_norm(cfg, p["norm_x"], x)
        if mode == "decode":
            enc_kv = st_in["enc_kv"]
        else:
            enc_kv = encode_cross_kv(cfg, p["cross"], enc_out)
        x = x + apply_cross_attention(cfg, p["cross"], h, enc_kv)
        if mode == "prefill":
            new_state["enc_kv"] = enc_kv
        elif mode == "decode":
            new_state["enc_kv"] = enc_kv  # carried through unchanged

    h = apply_norm(cfg, p["norm2"], x)
    ffn_out, ffn_st = apply_ffn(
        cfg, seg, p["ffn"], h, state=st_in.get("ffn"), mode=mode
    )
    x = x + ffn_out
    if ffn_st is not None:
        new_state["ffn"] = ffn_st
    return x, (new_state or None)


# mixers whose apply signature accepts positions/cache_len transparently via
# **_unused kwargs (rwkv6 / rglru) vs attention family that requires them —
# _MIXER_APPLY entries all take the same kwargs, so dispatch is uniform.


def _run_segment(
    cfg: ModelConfig,
    seg: Segment,
    stacked_p: dict,
    x: jax.Array,
    *,
    mode: str,
    positions,
    stacked_state=None,
    cache_len=None,
    enc_out=None,
    max_len: int = 0,
):
    """Scan a segment's layers.  Returns (x, stacked_new_state|None)."""

    if mode == "train":

        def body(carry, lp):
            out, _ = _apply_block(
                cfg, seg, lp, carry, mode=mode, positions=positions,
                state=None, cache_len=None, enc_out=enc_out, max_len=max_len,
            )
            return out, None

        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, _ = lax.scan(body, x, stacked_p)
        else:
            for i in range(seg.repeat):
                lp = jax.tree.map(lambda a: a[i], stacked_p)
                x, _ = body(x, lp)
        return x, None

    if mode == "prefill":

        def body(carry, lp):
            out, st = _apply_block(
                cfg, seg, lp, carry, mode=mode, positions=positions,
                state=None, cache_len=None, enc_out=enc_out, max_len=max_len,
            )
            return out, st

        if cfg.scan_layers:
            x, states = lax.scan(body, x, stacked_p)
        else:
            sts = []
            for i in range(seg.repeat):
                lp = jax.tree.map(lambda a: a[i], stacked_p)
                x, st = body(x, lp)
                sts.append(st)
            states = jax.tree.map(lambda *a: jnp.stack(a), *sts)
        return x, states

    # decode
    def body(carry, inp):
        lp, st = inp
        out, st2 = _apply_block(
            cfg, seg, lp, carry, mode=mode, positions=positions,
            state=st, cache_len=cache_len, enc_out=enc_out, max_len=max_len,
        )
        return out, st2

    if cfg.scan_layers:
        x, new_states = lax.scan(body, x, (stacked_p, stacked_state))
    else:
        sts = []
        for i in range(seg.repeat):
            lp = jax.tree.map(lambda a: a[i], stacked_p)
            st = jax.tree.map(lambda a: a[i], stacked_state)
            x, st2 = body(x, (lp, st))
            sts.append(st2)
        new_states = jax.tree.map(lambda *a: jnp.stack(a), *sts)
    return x, new_states


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens].astype(dtype_of(cfg))
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, "dp", None, None)


def _head_weights(cfg: ModelConfig, params: dict) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T.astype(dtype_of(cfg))
    return params["lm_head"]


def _encoder_forward(cfg: ModelConfig, params: dict, enc_embeds: jax.Array) -> jax.Array:
    """Stub-frontend encoder: enc_embeds (B, Se, d) precomputed frames."""
    x = enc_embeds.astype(dtype_of(cfg))
    Se = x.shape[1]
    pos = jnp.arange(Se)[None, :]
    x = x + sinusoidal_embedding(pos, cfg.d_model).astype(x.dtype)
    for seg, sp in zip(cfg.encoder_segments, params["encoder"]["segments"]):
        x, _ = _run_segment(cfg, seg, sp, x, mode="train", positions=pos)
    return apply_norm(cfg, params["encoder"]["final_norm"], x)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def _forward(cfg, params, tokens, *, mode, prefix_embeds=None, enc_embeds=None,
             max_len=0):
    """Shared train/prefill trunk.  Returns (h, states, n_prefix)."""
    B, S = tokens.shape
    x = _embed(cfg, params, tokens)
    n_prefix = 0
    if cfg.n_prefix_embeds and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        n_prefix = prefix_embeds.shape[1]
    St = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(St)[None, :], (B, St))
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(cfg, params, enc_embeds)

    states = []
    for seg, sp in zip(cfg.segments, params["segments"]):
        x, st = _run_segment(
            cfg, seg, sp, x, mode=mode, positions=positions,
            enc_out=enc_out, max_len=max_len,
        )
        states.append(st)
    h = apply_norm(cfg, params["final_norm"], x)
    return h, states, n_prefix


def _chunked_xent(cfg: ModelConfig, h: jax.Array, w_head: jax.Array,
                  labels: jax.Array) -> jax.Array:
    """Cross-entropy without materialising (B, S, V) logits: scan over
    sequence chunks, rematerialised in backward."""
    B, S, d = h.shape
    ck = min(cfg.loss_chunk, S)
    n = -(-S // ck)
    pad = n * ck - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hs = h.reshape(B, n, ck, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, ck).transpose(1, 0, 2)

    def body(acc, inp):
        hc, lc = inp
        logits = (hc @ w_head).astype(f32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(f32)
        nll = (lse - tgt) * mask
        return (acc[0] + nll.sum(), acc[1] + mask.sum()), None

    body = jax.checkpoint(body)
    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), f32), jnp.zeros((), f32)), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def train_loss(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """batch: tokens (B,S) i32, labels (B,S) i32 (-1 = masked),
    optional prefix_embeds (B,P,d) [vlm], enc_embeds (B,Se,d) [audio]."""
    h, _, n_prefix = _forward(
        cfg, params, batch["tokens"], mode="train",
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"),
    )
    labels = batch["labels"]
    if n_prefix:
        h = h[:, n_prefix:, :]
    return _chunked_xent(cfg, h, _head_weights(cfg, params), labels)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, *, max_len: int,
            prefix_embeds=None, enc_embeds=None):
    """Returns (last_token_logits (B, V), DecodeState)."""
    B, S = tokens.shape
    h, states, n_prefix = _forward(
        cfg, params, tokens, mode="prefill",
        prefix_embeds=prefix_embeds, enc_embeds=enc_embeds, max_len=max_len,
    )
    logits = (h[:, -1, :] @ _head_weights(cfg, params)).astype(f32)
    state = {
        "cache_len": jnp.full((B,), S + n_prefix, jnp.int32),
        "segments": tuple(states),
    }
    return logits, state


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, state: dict):
    """tokens: (B,) i32 new token per sequence.  Returns (logits (B,V), state)."""
    B = tokens.shape[0]
    cache_len = state["cache_len"]
    x = _embed(cfg, params, tokens[:, None])
    positions = cache_len[:, None]
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)

    new_states = []
    for seg, sp, st in zip(cfg.segments, params["segments"], state["segments"]):
        x, st2 = _run_segment(
            cfg, seg, sp, x, mode="decode", positions=positions,
            stacked_state=st, cache_len=cache_len,
        )
        new_states.append(st2)
    h = apply_norm(cfg, params["final_norm"], x)
    logits = (h[:, 0, :] @ _head_weights(cfg, params)).astype(f32)
    return logits, {"cache_len": cache_len + 1, "segments": tuple(new_states)}


# ---------------------------------------------------------------------------
# Decode-state construction without running prefill (dry-run / serving slabs)
# ---------------------------------------------------------------------------


def _layer_state_skeleton(cfg: ModelConfig, seg: Segment, batch: int, max_len: int):
    st: dict = {}
    if seg.mixer in ("attn", "local_attn"):
        st["mixer"] = attention_init_state(cfg, seg, batch, max_len)
    elif seg.mixer == "mla":
        st["mixer"] = mla_init_state(cfg, batch, max_len)
    elif seg.mixer == "rwkv6":
        st["mixer"] = rwkv6.timemix_init_state(cfg, batch)
    elif seg.mixer == "rglru":
        st["mixer"] = rglru.rglru_init_state(cfg, batch)
    if seg.cross_attn:
        st["enc_kv"] = {
            "k": jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.d_head), dtype_of(cfg)),
            "v": jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.d_head), dtype_of(cfg)),
        }
    fst = ffn_init_state(cfg, seg, batch)
    if fst is not None:
        st["ffn"] = fst
    return st


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      filled: int = 0) -> dict:
    """Zero decode state with capacity ``max_len`` and ``filled`` tokens."""
    segs = []
    for seg in cfg.segments:
        one = _layer_state_skeleton(cfg, seg, batch, max_len)
        stacked = jax.tree.map(
            lambda a: jnp.zeros((seg.repeat,) + a.shape, a.dtype), one
        )
        segs.append(stacked)
    return {
        "cache_len": jnp.full((batch,), filled, jnp.int32),
        "segments": tuple(segs),
    }
