"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  x -> [W_in1 -> causal conv1d -> RG-LRU]  *  gelu(W_in2 x)  -> W_out
RG-LRU: r_t = sigma(W_a c_t + b_a),  i_t = sigma(W_x c_t + b_x)
        a_t = exp(-c * softplus(lambda) * r_t)           (c = 8)
        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * c_t)

TPU adaptation: train/prefill uses ``lax.associative_scan`` over the linear
recurrence (log-depth, parallel across the sequence); decode is the O(1)
elementwise update.  The conv is width-4 causal depthwise, realised as a sum
of shifted slices (no im2col).

State layout (decode): {"h": (B, W) f32, "conv": (B, cw-1, W)}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, Segment
from repro.distributed.act_sharding import constrain
from repro.models.layers import _dense, dtype_of

f32 = jnp.float32
_C = 8.0


def init_rglru(cfg: ModelConfig, seg: Segment, key) -> dict:
    dt = dtype_of(cfg)
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "w_in1": _dense(ks[0], (d, w), dt),
        "w_in2": _dense(ks[1], (d, w), dt),
        "w_out": _dense(ks[2], (w, d), dt),
        "conv_w": _dense(ks[3], (cfg.conv_width, w), dt, scale=0.3),
        "conv_b": jnp.zeros((w,), dt),
        "w_a": _dense(ks[4], (w, w), dt),
        "b_a": jnp.zeros((w,), f32),
        "w_x": _dense(ks[5], (w, w), dt),
        "b_x": jnp.zeros((w,), f32),
        # softplus(lam) ~ U(...) so that a^c in [0.9, 0.999] at r=1 (paper init)
        "lam": jax.random.uniform(ks[6], (w,), f32, 0.9, 1.1),
    }


def _causal_conv(p: dict, x: jax.Array, tail: jax.Array | None = None):
    """x: (B, S, W).  tail: (B, cw-1, W) previous inputs for decode/prefill."""
    cw = p["conv_w"].shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    out = p["conv_b"]
    pieces = [xp[:, j : j + S] * p["conv_w"][j] for j in range(cw)]
    return sum(pieces) + out


def rglru_init_state(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), f32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype_of(cfg)),
    }


def _gates(p: dict, c: jax.Array):
    cf = c.astype(f32)
    r = jax.nn.sigmoid(cf @ p["w_a"].astype(f32) + p["b_a"])
    i = jax.nn.sigmoid(cf @ p["w_x"].astype(f32) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * cf)
    return a, b


def apply_rglru(cfg: ModelConfig, seg: Segment, p: dict, x: jax.Array, *, mode: str,
                state=None, **_unused):
    B, S, d = x.shape
    branch = constrain(x @ p["w_in1"], "dp", None, "tp")
    gate = constrain(jax.nn.gelu(x @ p["w_in2"]), "dp", None, "tp")

    if mode in ("train", "prefill"):
        c = _causal_conv(p, branch)
        a, b = _gates(p, c)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, h = lax.associative_scan(combine, (a, b), axis=1)
        out = (h.astype(x.dtype) * gate) @ p["w_out"]
        st = None
        if mode == "prefill":
            cw = cfg.conv_width
            tail = branch[:, -(cw - 1) :, :]
            pad = (cw - 1) - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            st = {"h": h[:, -1].astype(f32), "conv": tail}
        return out, st

    # decode (S == 1)
    assert state is not None
    tail = state["conv"]
    c = _causal_conv(p, branch, tail=tail)
    a, b = _gates(p, c)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    new_tail = jnp.concatenate([tail[:, 1:], branch.astype(tail.dtype)], axis=1)
    return out, {"h": h, "conv": new_tail}
