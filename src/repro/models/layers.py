"""Layer library: norms, RoPE, attention family (GQA/MLA/local), FFN family
(SwiGLU/GeGLU/GELU/RWKV channel-mix/MoE).

Conventions
-----------
* Parameters are plain nested dicts of ``jnp`` arrays (no flax).
* Every mixer/ffn exposes ``init_*(cfg, seg, key) -> params`` and an apply
  function.  Apply functions are mode-polymorphic:

    mode='train'    full sequence, no state
    mode='prefill'  full sequence, returns a decode state
    mode='decode'   one new token per sequence, consumes + returns state

* Attention is computed with a FLOPs-exact blocked online-softmax jnp path
  (static python loop over query chunks with statically-sliced KV ranges) so
  that causal attention costs ~S^2/2 instead of S^2 and peak memory stays
  O(B*H*qc*S).  The Pallas decode kernel (kernels/decode_attention) plugs in
  behind the same signature on TPU.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, Segment
from repro.distributed.act_sharding import constrain

Params = dict
f32 = jnp.float32


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, key, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype_of(cfg)), "bias": jnp.zeros((d,), dtype_of(cfg))}
    return {"scale": jnp.ones((d,), dtype_of(cfg))}


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(f32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + cfg.norm_eps)
        return (y * p["scale"].astype(f32) + p["bias"].astype(f32)).astype(x.dtype)
    var = (xf**2).mean(-1, keepdims=True)
    y = xf * lax.rsqrt(var + cfg.norm_eps)
    return (y * p["scale"].astype(f32)).astype(x.dtype)


def rms_norm_headwise(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (Qwen3)."""
    xf = x.astype(f32)
    y = xf * lax.rsqrt((xf**2).mean(-1, keepdims=True) + eps)
    return (y * scale.astype(f32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Positional embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(d: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=f32) / d))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, d); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    angles = positions[..., :, None].astype(f32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(f32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: jax.Array, d: int) -> jax.Array:
    """(..., S) -> (..., S, d) classic transformer sinusoids."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=f32) / max(half - 1, 1))
    ang = positions[..., None].astype(f32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Blocked attention core (jnp online-softmax; FLOPs-exact causal blocking)
# ---------------------------------------------------------------------------


def _sdpa_block(q, k, v, mask, scale):
    """q:(B,Sq,H,dh) k,v:(B,Sk,KV,dh) mask:(B?,Sq,Sk) or None -> (B,Sq,H,dh)."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.reshape(B, Sq, KV, G, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf.astype(f32), k.astype(f32)) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(f32))
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    q_chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Chunked attention with static causal/window KV slicing (prefill/train).

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh).  q_offset: absolute position of
    q[0] within the kv sequence (0 for self-attention from scratch).
    Causal chunking slices KV to [lo, hi) with *python-int* bounds, so HLO
    FLOPs match the true causal cost (~1/2 of full) instead of mask-and-waste.
    """
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    if not causal:
        return _sdpa_block(q, k, v, None, scale)

    qc = min(q_chunk, Sq)
    n_chunks = (Sq + qc - 1) // qc
    outs = []
    for i in range(n_chunks):
        q0, q1 = i * qc, min((i + 1) * qc, Sq)
        qi = q[:, q0:q1]
        hi = min(q_offset + q1, Sk)  # static upper causal bound
        lo = 0
        if window:
            lo = max(0, q_offset + q0 - window + 1)
        ki, vi = k[:, lo:hi], v[:, lo:hi]
        # in-block causal/window mask
        qpos = q_offset + jnp.arange(q0, q1)
        kpos = jnp.arange(lo, hi)
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        outs.append(_sdpa_block(qi, ki, vi, mask[None], scale))
    return jnp.concatenate(outs, axis=1)


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token attention against a (B, Smax, KV, dh) cache.

    cache_len: (B,) number of valid positions per sequence.  This is the pure
    jnp oracle that the Pallas decode kernel must match.
    """
    B, Smax, KV, dh = k_cache.shape
    H = q.shape[2]
    scale = 1.0 / math.sqrt(dh)
    G = H // KV
    qf = q.reshape(B, KV, G, dh).astype(f32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(f32)) * scale
    pos = jnp.arange(Smax)[None, :]
    valid = pos < cache_len[:, None]
    if window:
        valid &= pos >= (cache_len[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(f32))
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Dense / local attention mixer (GQA, optional qk-norm, optional bias)
# ---------------------------------------------------------------------------


def _dense(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, f32) * scale).astype(dtype)


def init_attention(cfg: ModelConfig, seg: Segment, key) -> Params:
    dt = dtype_of(cfg)
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 8)
    p = {
        "wq": _dense(ks[0], (d, H * dh), dt),
        "wk": _dense(ks[1], (d, KV * dh), dt),
        "wv": _dense(ks[2], (d, KV * dh), dt),
        "wo": _dense(ks[3], (H * dh, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dt)
        p["bk"] = jnp.zeros((KV * dh,), dt)
        p["bv"] = jnp.zeros((KV * dh,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _qkv(cfg: ModelConfig, p: Params, x: jax.Array, positions):
    B, S, _ = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(B, S, H, dh), "dp", None, "tp", None)
    k = constrain(k.reshape(B, S, KV, dh), "dp", None, "tp", None)
    v = constrain(v.reshape(B, S, KV, dh), "dp", None, "tp", None)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_norm"])
        k = rms_norm_headwise(k, p["k_norm"])
    if cfg.pos_emb == "rope" and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_init_state(cfg: ModelConfig, seg: Segment, batch: int, max_len: int):
    """Decode-state skeleton (zeros) for one attention layer."""
    dt = dtype_of(cfg)
    KV, dh = cfg.n_kv_heads, cfg.d_head
    if seg.mixer == "local_attn":
        max_len = min(max_len, cfg.local_window)
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, max_len, KV, dh), jnp.int8),
            "v": jnp.zeros((batch, max_len, KV, dh), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, KV), f32),
            "v_scale": jnp.zeros((batch, max_len, KV), f32),
        }
    return {
        "k": jnp.zeros((batch, max_len, KV, dh), dt),
        "v": jnp.zeros((batch, max_len, KV, dh), dt),
    }


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, KV, dh) -> (int8 values, per-(token, head) f32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(f32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(f32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dt) -> jax.Array:
    return (q.astype(f32) * scale[..., None]).astype(dt)


def apply_attention(
    cfg: ModelConfig,
    seg: Segment,
    p: Params,
    x: jax.Array,
    *,
    mode: str,
    positions: jax.Array,
    state: Optional[Params] = None,
    cache_len: Optional[jax.Array] = None,
    max_len: int = 0,
):
    """Returns (out, new_state)."""
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.d_head
    window = cfg.local_window if seg.mixer == "local_attn" else 0
    causal = seg.mixer != "encoder_attn"
    q, k, v = _qkv(cfg, p, x, positions)

    if mode == "train":
        out = blocked_attention(q, k, v, causal=causal, window=window, q_chunk=cfg.attn_q_chunk)
        out = constrain(out, "dp", None, "tp", None)
        return constrain(out.reshape(B, S, H * dh) @ p["wo"], "dp", None, None), None

    int8_kv = cfg.kv_cache_dtype == "int8"

    if mode == "prefill":
        out = blocked_attention(q, k, v, causal=causal, window=window, q_chunk=cfg.attn_q_chunk)
        out = constrain(out, "dp", None, "tp", None)
        if window:
            # keep only the trailing window in the ring cache
            pad = max(0, window - S)
            kw = jnp.pad(k[:, -window:], ((0, 0), (pad, 0), (0, 0), (0, 0)))
            vw = jnp.pad(v[:, -window:], ((0, 0), (pad, 0), (0, 0), (0, 0)))
            st = {"k": kw.astype(k.dtype), "v": vw.astype(v.dtype)}
        else:
            pad = max_len - S
            st = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            }
        if int8_kv:
            kq, ks = _quantize_kv(st["k"])
            vq, vs = _quantize_kv(st["v"])
            st = {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}
        return out.reshape(B, S, H * dh) @ p["wo"], st

    # decode: S == 1
    assert state is not None and cache_len is not None
    if int8_kv:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        slot = (cache_len % window) if window else cache_len
        st = {
            "k": _scatter_time(state["k"], kq, slot),
            "k_scale": _scatter_time(state["k_scale"], ks, slot),
            "v": _scatter_time(state["v"], vq, slot),
            "v_scale": _scatter_time(state["v_scale"], vs, slot),
        }
        k_full = _dequantize_kv(st["k"], st["k_scale"], k.dtype)
        v_full = _dequantize_kv(st["v"], st["v_scale"], v.dtype)
        eff_len = jnp.minimum(cache_len + 1, window) if window else cache_len + 1
        out = decode_attention_ref(q, k_full, v_full, eff_len)
        return out.reshape(B, S, H * dh) @ p["wo"], st
    if window:
        # ring buffer: write slot = cache_len % window
        slot = cache_len % window
        k_new = _scatter_time(state["k"], k, slot)
        v_new = _scatter_time(state["v"], v, slot)
        eff_len = jnp.minimum(cache_len + 1, window)
        # positions for masking inside ring: all entries valid up to eff_len
        out = decode_attention_ref(q, k_new, v_new, eff_len)
        st = {"k": k_new, "v": v_new}
    else:
        # dynamic per-batch write at cache_len
        k_new = _scatter_time(state["k"], k, cache_len)
        v_new = _scatter_time(state["v"], v, cache_len)
        out = decode_attention_ref(q, k_new, v_new, cache_len + 1)
        st = {"k": k_new, "v": v_new}
    return out.reshape(B, S, H * dh) @ p["wo"], st


def _scatter_time(cache: jax.Array, new: jax.Array, lengths: jax.Array) -> jax.Array:
    """Write new (B, 1, ...) at per-sequence time position lengths (B,).

    vmap of dynamic_update_slice keeps memory traffic at O(slice), not
    O(cache) — with buffer donation this is an in-place cache update.
    """

    def upd(c, n, start):
        return lax.dynamic_update_slice_in_dim(c, n.astype(c.dtype), start, axis=0)

    return jax.vmap(upd)(cache, new, lengths)


# ---------------------------------------------------------------------------
# Cross attention (enc-dec)
# ---------------------------------------------------------------------------


def init_cross_attention(cfg: ModelConfig, key) -> Params:
    dt = dtype_of(cfg)
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense(ks[0], (d, H * dh), dt),
        "wk": _dense(ks[1], (d, KV * dh), dt),
        "wv": _dense(ks[2], (d, KV * dh), dt),
        "wo": _dense(ks[3], (H * dh, d), dt),
    }


def apply_cross_attention(cfg: ModelConfig, p: Params, x, enc_kv):
    """enc_kv: dict with 'k','v' (B, Senc, KV, dh) precomputed from encoder."""
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    out = _sdpa_block(q, enc_kv["k"], enc_kv["v"], None, 1.0 / math.sqrt(dh))
    return out.reshape(B, S, H * dh) @ p["wo"]


def encode_cross_kv(cfg: ModelConfig, p: Params, enc_out: jax.Array) -> Params:
    B, Se, _ = enc_out.shape
    KV, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": (enc_out @ p["wk"]).reshape(B, Se, KV, dh),
        "v": (enc_out @ p["wv"]).reshape(B, Se, KV, dh),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(cfg: ModelConfig, seg: Segment, key) -> Params:
    dt = dtype_of(cfg)
    d, H = cfg.d_model, cfg.n_heads
    r, rp, np_, vd = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p: Params = {}
    if cfg.q_lora_rank:
        p["wq_a"] = _dense(ks[0], (d, cfg.q_lora_rank), dt)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), dt)
        p["wq_b"] = _dense(ks[1], (cfg.q_lora_rank, H * (np_ + rp)), dt)
    else:
        p["wq"] = _dense(ks[0], (d, H * (np_ + rp)), dt)
    p["wkv_a"] = _dense(ks[2], (d, r + rp), dt)
    p["kv_norm"] = jnp.ones((r,), dt)
    p["wk_b"] = _dense(ks[3], (r, H * np_), dt)
    p["wv_b"] = _dense(ks[4], (r, H * vd), dt)
    p["wo"] = _dense(ks[5], (H * vd, d), dt)
    return p


def mla_init_state(cfg: ModelConfig, batch: int, max_len: int):
    dt = dtype_of(cfg)
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "kpe": jnp.zeros((batch, max_len, cfg.rope_head_dim), dt),
    }


def _mla_q(cfg: ModelConfig, p: Params, x, positions):
    B, S, _ = x.shape
    H, rp, np_ = cfg.n_heads, cfg.rope_head_dim, cfg.nope_head_dim
    if cfg.q_lora_rank:
        qa = x @ p["wq_a"]
        qa = rms_norm_headwise(qa, p["q_norm"])
        q = (qa @ p["wq_b"]).reshape(B, S, H, np_ + rp)
    else:
        q = (x @ p["wq"]).reshape(B, S, H, np_ + rp)
    q_nope, q_pe = q[..., :np_], q[..., np_:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _mla_kv_latent(cfg: ModelConfig, p: Params, x, positions):
    r, rp = cfg.kv_lora_rank, cfg.rope_head_dim
    kv = x @ p["wkv_a"]
    ckv, kpe = kv[..., :r], kv[..., r:]
    ckv = rms_norm_headwise(ckv, p["kv_norm"])
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, kpe


def apply_mla(
    cfg: ModelConfig,
    seg: Segment,
    p: Params,
    x: jax.Array,
    *,
    mode: str,
    positions,
    state=None,
    cache_len=None,
    max_len: int = 0,
):
    B, S, _ = x.shape
    H = cfg.n_heads
    r, rp, np_, vd = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    q_nope, q_pe = _mla_q(cfg, p, x, positions)
    ckv, kpe = _mla_kv_latent(cfg, p, x, positions)

    if mode in ("train", "prefill"):
        # expand per-head K/V from the latent (standard prefill path)
        k_nope = constrain((ckv @ p["wk_b"]).reshape(B, S, H, np_), "dp", None, "tp", None)
        v = constrain((ckv @ p["wv_b"]).reshape(B, S, H, vd), "dp", None, "tp", None)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(kpe[:, :, None, :], (B, S, H, rp))], -1)
        q = constrain(jnp.concatenate([q_nope, q_pe], -1), "dp", None, "tp", None)
        # pad v's head dim so the blocked kernel sees equal d; slice after
        vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, np_ + rp - vd)))
        out = blocked_attention(q, k, vpad, causal=True, q_chunk=cfg.attn_q_chunk)
        out = out[..., :vd]
        y = out.reshape(B, S, H * vd) @ p["wo"]
        st = None
        if mode == "prefill":
            pad = max_len - S
            st = {
                "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                "kpe": jnp.pad(kpe, ((0, 0), (0, pad), (0, 0))),
            }
        return y, st

    # decode: absorbed formulation — attention in latent space, no per-head
    # K/V materialisation.  scores = q_nope @ Wk_b^T(head) @ ckv + q_pe @ kpe
    assert state is not None
    ckv_c = _scatter_time(state["ckv"], ckv, cache_len)
    kpe_c = _scatter_time(state["kpe"], kpe, cache_len)
    wk_b = p["wk_b"].reshape(r, H, np_)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(f32), wk_b.astype(f32))  # (B,1,H,r)
    scores = jnp.einsum("bshr,btr->bhst", q_lat, ckv_c.astype(f32))
    scores += jnp.einsum("bshp,btp->bhst", q_pe.astype(f32), kpe_c.astype(f32))
    scores *= 1.0 / math.sqrt(np_ + rp)
    Smax = ckv_c.shape[1]
    valid = jnp.arange(Smax)[None, :] < (cache_len + 1)[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    pattn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", pattn, ckv_c.astype(f32))  # latent ctx
    wv_b = p["wv_b"].reshape(r, H, vd)
    out = jnp.einsum("bshr,rhv->bshv", ctx, wv_b.astype(f32)).astype(x.dtype)
    y = out.reshape(B, S, H * vd) @ p["wo"]
    return y, {"ckv": ckv_c, "kpe": kpe_c}


# ---------------------------------------------------------------------------
# FFN family
# ---------------------------------------------------------------------------


def _act(cfg: ModelConfig, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def init_ffn(cfg: ModelConfig, seg: Segment, key) -> Params:
    dt = dtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if seg.ffn in ("swiglu", "geglu"):
        return {
            "w1": _dense(ks[0], (d, cfg.d_ff), dt),
            "w3": _dense(ks[1], (d, cfg.d_ff), dt),
            "w2": _dense(ks[2], (cfg.d_ff, d), dt),
        }
    if seg.ffn == "gelu_mlp":
        return {
            "w1": _dense(ks[0], (d, cfg.d_ff), dt),
            "b1": jnp.zeros((cfg.d_ff,), dt),
            "w2": _dense(ks[1], (cfg.d_ff, d), dt),
            "b2": jnp.zeros((d,), dt),
        }
    if seg.ffn == "rwkv_cmix":
        return {
            "mu_k": jnp.full((d,), 0.5, dt),
            "mu_r": jnp.full((d,), 0.5, dt),
            "wk": _dense(ks[0], (d, cfg.d_ff), dt),
            "wv": _dense(ks[1], (cfg.d_ff, d), dt),
            "wr": _dense(ks[2], (d, d), dt),
        }
    if seg.ffn == "moe":
        return init_moe(cfg, key)
    raise ValueError(seg.ffn)


def apply_ffn(cfg: ModelConfig, seg: Segment, p: Params, x, *, state=None, mode="train"):
    """Returns (out, new_state) — state only used by rwkv_cmix token shift."""
    if seg.ffn in ("swiglu", "geglu"):
        gate = _act(cfg, x @ p["w1"]) if seg.ffn == "swiglu" else jax.nn.gelu(x @ p["w1"])
        h = constrain(gate * (x @ p["w3"]), "dp", None, "tp")
        return constrain(h @ p["w2"], "dp", None, None), None
    if seg.ffn == "gelu_mlp":
        h = constrain(jax.nn.gelu(x @ p["w1"] + p["b1"]), "dp", None, "tp")
        return constrain(h @ p["w2"] + p["b2"], "dp", None, None), None
    if seg.ffn == "rwkv_cmix":
        if mode == "decode":
            prev = state  # (B, 1, d) last input
            xs = prev
        else:
            xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        xk = x + (xs - x) * p["mu_k"]
        xr = x + (xs - x) * p["mu_r"]
        k = jnp.square(jax.nn.relu(xk @ p["wk"]))
        out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
        new_state = x[:, -1:, :]
        return out, new_state
    if seg.ffn == "moe":
        return apply_moe(cfg, p, x), None
    raise ValueError(seg.ffn)


def ffn_init_state(cfg: ModelConfig, seg: Segment, batch: int):
    if seg.ffn == "rwkv_cmix":
        return jnp.zeros((batch, 1, cfg.d_model), dtype_of(cfg))
    return None


# ---------------------------------------------------------------------------
# MoE: top-k routing with capacity-based scatter dispatch (static shapes,
# expert dim shardable -> XLA emits all-to-all under pjit)
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> Params:
    dt = dtype_of(cfg)
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 8)
    p = {
        "router": _dense(ks[0], (d, E), jnp.float32),
        "w1": _dense(ks[1], (E, d, ff), dt),
        "w3": _dense(ks[2], (E, d, ff), dt),
        "w2": _dense(ks[3], (E, ff, d), dt),
    }
    if cfg.n_shared_experts:
        sf = ff * cfg.n_shared_experts
        p["sw1"] = _dense(ks[4], (d, sf), dt)
        p["sw3"] = _dense(ks[5], (d, sf), dt)
        p["sw2"] = _dense(ks[6], (sf, d), dt)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Top-k MoE with *shard-local* capacity dispatch + expert-parallel
    all-to-all.

    Tokens are viewed as (G, T/G) where G = the data-parallel pool size, so
    routing, sort and scatter are *batched per shard with a sharded leading
    dim* — the indices never cross shards and XLA partitions every scatter /
    gather cleanly.  Cross-device movement happens exactly once in each
    direction, as the buffer resharding (G-sharded -> E-sharded): the classic
    expert-parallel all-to-all.  (A global scatter with computed indices
    forces SPMD to replicate a (T*K, d)-shaped index tensor — 51 GB/layer at
    the train_4k shape; found in §Perf iteration 1 of deepseek train_4k.)
    Capacity is per shard: C_local = ceil(T/G * K * cf / E), so drop behaviour
    is shard-local (standard for EP implementations).
    """
    from repro.distributed.act_sharding import dp_total

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    G = dp_total()
    if T % G != 0:
        G = 1
    Tl = T // G
    xt = constrain(x.reshape(G, Tl, d), "dp", None, None)

    logits = xt.astype(f32) @ p["router"]  # (G, Tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, K)  # (G, Tl, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(G, Tl * K)
    # position-within-expert via per-shard stable sort (O(n log n))
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    first = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="left")
    )(sorted_e)  # (G, E)
    pos_sorted = (
        jnp.arange(Tl * K)[None, :] - jnp.take_along_axis(first, sorted_e, axis=1)
    )
    pos = jax.vmap(lambda o, ps: jnp.zeros_like(ps).at[o].set(ps))(order, pos_sorted)
    C = moe_capacity(cfg, Tl)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)  # dropped -> overflow row

    x_rep = jnp.repeat(xt, K, axis=1)  # (G, Tl*K, d)
    buf = jnp.zeros((G, E * C + 1, d), xt.dtype)
    buf = jax.vmap(lambda b, s, xr: b.at[s].set(xr))(buf, slot, x_rep)
    # reshard G-major -> E-major: the expert-parallel all-to-all
    bufe = buf[:, : E * C].reshape(G, E, C, d).transpose(1, 0, 2, 3)
    bufe = constrain(bufe, "tp", "dp", None, None)
    h = bufe.reshape(E, G * C, d)

    a = jnp.einsum("ecd,edf->ecf", h, p["w1"])
    g = _act(cfg, a) * jnp.einsum("ecd,edf->ecf", h, p["w3"])
    y = jnp.einsum("ecf,efd->ecd", g, p["w2"])  # (E, G*C, d)
    # reshard back E-major -> G-major (second all-to-all)
    y = y.reshape(E, G, C, d).transpose(1, 0, 2, 3).reshape(G, E * C, d)
    y = constrain(y, "dp", None, None)
    y = jnp.concatenate([y, jnp.zeros((G, 1, d), y.dtype)], axis=1)

    y_tok = jnp.take_along_axis(y, slot[..., None], axis=1)  # (G, Tl*K, d)
    out = (y_tok.reshape(G, Tl, K, d) * gate_vals[..., None].astype(y.dtype)).sum(axis=2)

    if cfg.n_shared_experts:
        out = out + (_act(cfg, xt @ p["sw1"]) * (xt @ p["sw3"])) @ p["sw2"]
    return out.reshape(B, S, d)


def moe_load_balance_loss(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """Switch-style aux loss — exported for the training substrate."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(jnp.argmax(probs, -1), cfg.n_experts, dtype=f32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
