"""SLO- and skew-aware dispatch of retrieval sub-stages to a worker pool.

The paper's inter-request skewness observation (§4.4, Fig. 8) says a small
set of IVF clusters absorbs most probes.  When the host side runs more than
one retrieval worker, that skew becomes a placement problem: routing a hot
cluster to the worker that served it recently keeps per-worker working sets
small (cache/NUMA locality in the real engine; preserved same-cluster query
batching in the simulated one), while cold clusters should simply go to
whoever is least loaded.  Orthogonally, per-request SLOs (RAGO-style
schedule search) need near-deadline requests admitted to sub-stage assembly
first, which is a pure ordering concern.

This module keeps both concerns out of the scheduler loop:

* ``RetrievalDispatcher`` — per-worker EMA cluster-affinity history plus
  accumulated busy time; ``pick_worker`` implements the policies
  ``affinity`` (history coverage, least-loaded fallback), ``least_loaded``
  and ``round_robin``.  The dispatcher is the single policy-side source of
  worker load: ``note_busy`` accumulates dispatched (in-flight) time and
  ``note_complete`` the completed share — ``Metrics.ret_busy_per_worker``
  mirrors the latter instead of double-booking its own accumulator.
* Cross-request extensions (``repro.crossreq``): an attached shared
  ``PopularityTracker`` receives every dispatched cluster (the global probe
  histogram superseding the per-worker EMA as the skew source of truth),
  and an attached ``ReplicaMap`` routes sub-stages touching replicated hot
  clusters to the least-loaded replica holder instead of serialising them
  on a single affinity owner.
* ``order_by_slack`` — sorts a wavefront by SLO slack
  ``deadline - now - estimated_remaining`` so the tightest requests are
  assembled (and therefore dispatched) first.
* ``AdmissionController`` — streaming admission control: a bounded pending
  queue plus deadline-infeasibility load shedding.  A request is shed when
  its remaining SLO slack cannot cover a cost-model lower bound of one pass
  over its graph — admitting it could only burn worker time on a guaranteed
  SLO violation and push *other* requests past their deadlines.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.ownership import owned_by

DISPATCH_POLICIES = ("affinity", "least_loaded", "round_robin")


@dataclasses.dataclass
class WorkerState:
    wid: int
    freq: np.ndarray  # per-cluster EMA of recently dispatched clusters
    # single policy-side load source: busy_us accumulates at dispatch time
    # (includes in-flight work, what load-aware placement needs) and
    # completed_us at completion time (what Metrics.ret_busy_per_worker
    # mirrors for occupancy reporting)
    busy_us: float = 0.0
    completed_us: float = 0.0
    dispatches: int = 0


@owned_by("scheduler")
class RetrievalDispatcher:
    """Assigns retrieval sub-stages (cluster lists) to a pool of workers."""

    def __init__(self, num_workers: int, n_clusters: int, *,
                 policy: str = "affinity", decay: float = 0.95,
                 tracker=None, replica_map=None, shard_map=None):
        if policy not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch policy {policy!r}; choose from {DISPATCH_POLICIES}")
        self.num_workers = max(1, int(num_workers))
        self.policy = policy
        self.decay = decay
        # optional crossreq state: the shared cluster-popularity histogram
        # (fed on every dispatch) and the hot-cluster replica map consulted
        # ahead of the configured policy
        self.tracker = tracker
        self.replica_map = replica_map
        self.replica_routes = 0
        # shard-mode ownership table (retrieval.distributed.ShardMap): set
        # when the serving path runs distributed IVF retrieval — placement
        # becomes ownership-constrained (pick_shard_worker) instead of
        # policy-driven
        self.shard_map = shard_map
        self.n_clusters = int(n_clusters)
        self.workers = [
            WorkerState(w, np.zeros(n_clusters, np.float64))
            for w in range(self.num_workers)
        ]
        self._rr = 0

    def add_worker(self) -> int:
        """Grow the pool by one worker (mid-run registration)."""
        wid = self.num_workers
        self.workers.append(
            WorkerState(wid, np.zeros(self.n_clusters, np.float64)))
        self.num_workers += 1
        return wid

    # ---------------------------------------------------------------- choice
    def least_loaded(self, candidates: Sequence[int],
                     extra_load: Optional[dict] = None) -> int:
        """Lowest accumulated busy time.  ``extra_load`` carries load already
        assigned *during the current assembly cycle* (before any note_busy)
        so that several sub-stages assembled at the same instant spread
        across the pool instead of piling onto one worker."""
        extra = extra_load or {}

        def load(w: int) -> float:
            return self.workers[w].busy_us + extra.get(w, 0.0)

        return min(candidates, key=lambda w: (load(w), w))

    def pick_worker(self, clusters: Iterable[int], candidates: Sequence[int],
                    extra_load: Optional[dict] = None) -> int:
        """Choose a worker among ``candidates`` (idle worker ids) for a
        sub-stage touching ``clusters``."""
        if not candidates:
            raise ValueError("no candidate workers")
        if len(candidates) == 1:
            return candidates[0]
        if self.policy == "round_robin":
            w = candidates[self._rr % len(candidates)]
            self._rr += 1
            return w
        if self.policy == "least_loaded":
            return self.least_loaded(candidates, extra_load)
        aff = self._affinity_pick(clusters, candidates, extra_load)
        if self.replica_map is not None:
            # replica-aware routing (affinity only — the other policies do
            # not serialise hot clusters): a sub-stage touching replicated
            # hot clusters may land on any idle replica holder, least-loaded
            # among them, instead of the single affinity owner.  Counted
            # only when the choice actually deviates from affinity's.
            holders = self.replica_map.owners_for(clusters)
            cands = [w for w in candidates if w in holders]
            if cands:
                pick = self.least_loaded(cands, extra_load)
                if pick != aff:
                    self.replica_routes += 1
                return pick
        return aff

    def pick_shard_worker(self, clusters: Sequence[int], owner: int,
                          candidates: Sequence[int],
                          extra_load: Optional[dict] = None,
                          count_routes: bool = True) -> Optional[int]:
        """Placement for one shard-mode partial scan: the part's clusters all
        belong to shard ``owner``, so the owning worker is the default
        target.  Hot clusters replicated onto other workers' device slabs
        (crossreq ``ReplicaMap``) widen the choice: a worker holding a
        visible replica of *every* cluster in the part can serve it too, and
        the least-loaded eligible holder wins (counted in
        ``replica_routes`` when the choice deviates from the owner;
        best-effort placements like speculative warmups pass
        ``count_routes=False`` so throwaway parts don't inflate the
        metric).  Returns ``None`` when neither the owner nor any
        full-coverage replica holder is among ``candidates`` (idle
        workers) — the part stays queued for a later cycle."""
        cands = [owner] if owner in candidates else []
        if self.replica_map is not None and clusters:
            common: Optional[set] = None
            for c in clusters:
                held = self.replica_map.owners(int(c))
                cover = set(held) if held else set()
                common = cover if common is None else (common & cover)
                if not common:
                    break
            if common:
                cands += [w for w in sorted(common)
                          if w in candidates and w != owner]
        if not cands:
            return None
        pick = self.least_loaded(cands, extra_load)
        if pick != owner and count_routes:
            self.replica_routes += 1
        return pick

    def _affinity_pick(self, clusters: Iterable[int],
                       candidates: Sequence[int],
                       extra_load: Optional[dict]) -> int:
        """Worker whose recent history best covers these clusters; cold
        clusters (no history anywhere) fall back to least-loaded."""
        extra = extra_load or {}
        cl = np.asarray(list(clusters), np.int64)
        scores = {w: float(self.workers[w].freq[cl].sum()) for w in candidates}
        # explicit -w tie-break: equal (coverage, load) must resolve to the
        # lowest worker id on every run, whatever order candidates arrive in
        best = max(candidates,
                   key=lambda w: (scores[w],
                                  -(self.workers[w].busy_us + extra.get(w, 0.0)),
                                  -w))
        if scores[best] <= 0.0:
            return self.least_loaded(candidates, extra_load)
        return best

    # --------------------------------------------------------------- updates
    def note_dispatch(self, wid: int, clusters: Iterable[int]) -> None:
        st = self.workers[wid]
        st.freq *= self.decay
        cl = np.asarray(list(clusters), np.int64)
        if cl.size:
            np.add.at(st.freq, cl, 1.0)
            if self.tracker is not None:
                self.tracker.record(cl)
        st.dispatches += 1

    def note_busy(self, wid: int, dur_us: float) -> None:
        self.workers[wid].busy_us += dur_us

    def note_complete(self, wid: int, dur_us: float) -> None:
        """A dispatched job finished; its duration moves from in-flight to
        completed occupancy (mirrored into Metrics by the scheduler)."""
        self.workers[wid].completed_us += dur_us

    # ----------------------------------------------------------------- stats
    def utilization(self, now_us: float) -> list:
        """Per-worker completed-busy fraction of the virtual timeline so far
        (telemetry sampling; in [0, 1] since completed_us only accrues for
        jobs whose end instant has passed)."""
        t = max(float(now_us), 1e-9)
        return [min(w.completed_us / t, 1.0) for w in self.workers]

    def report(self) -> dict:
        busy = np.asarray([w.busy_us for w in self.workers])
        return {
            "busy_us": busy.tolist(),
            "completed_us": [w.completed_us for w in self.workers],
            "dispatches": [w.dispatches for w in self.workers],
            "busy_skew": float(busy.max() / busy.mean()) if busy.mean() > 0 else 1.0,
            "replica_routes": self.replica_routes,
        }


# ---------------------------------------------------------------------------
# SLO slack ordering
# ---------------------------------------------------------------------------


def sharded_scan_cost_us(clusters: np.ndarray, cost_model, sizes,
                         shard_map, merge_us: float) -> float:
    """Service-time estimate of scanning ``clusters`` in shard mode: the
    per-shard partial scans run in *parallel* on their owning workers, so
    the scan term is the ``max`` over shards (not the sum), plus a k-way
    merge term per participating shard charged to the scheduler-side
    gather."""
    if clusters.size == 0:
        return 0.0
    owners = shard_map.owner[clusters]
    per_cost = cost_model.cost_vec_us(sizes[clusters],
                                      np.ones(clusters.size))
    per_shard = np.bincount(owners, weights=per_cost,
                            minlength=shard_map.n_shards)
    n_parts = int((np.bincount(owners,
                               minlength=shard_map.n_shards) > 0).sum())
    return float(per_shard.max()) + merge_us * n_parts


def estimate_remaining_us(req, budget, cost_model, sizes,
                          shard_map=None, merge_us: float = 0.0,
                          pool_scale: float = 1.0) -> float:
    """First-order estimate of a request's remaining service time: the cost
    of its unsearched clusters plus its ungenerated tokens at the current
    EMA decode rate.  Later stages of the workflow are not modelled — slack
    is used for *ordering*, so only relative magnitudes matter.  With a
    ``shard_map``, the retrieval term models shard-mode scatter-gather:
    ``max`` over per-shard partial-scan costs plus a merge term, instead of
    the single-worker sum.  ``pool_scale`` (static pool / effective pool)
    inflates the estimate when workers are dead or draining."""
    from repro.core import stages

    ctx = stages.CostCtx(budget=budget, cost_model=cost_model, sizes=sizes,
                         shard_map=shard_map, merge_us=merge_us)
    est = 0.0
    for prog, kind in stages.active_progress(req):
        est += stages.spec(kind).remaining_us(req, prog, ctx)
    if pool_scale != 1.0:
        est *= pool_scale
    return est


def slo_slack_us(req, now: float, budget, cost_model, sizes,
                 default_slo_us: float, shard_map=None,
                 merge_us: float = 0.0, pool_scale: float = 1.0) -> float:
    """deadline - now - estimated_remaining; negative -> already late."""
    slo = getattr(req, "slo_us", 0.0) or default_slo_us
    deadline = req.arrival_us + slo
    return deadline - now - estimate_remaining_us(
        req, budget, cost_model, sizes, shard_map, merge_us, pool_scale)


def order_by_slack(reqs, now: float, budget, cost_model, sizes,
                   default_slo_us: float, shard_map=None,
                   merge_us: float = 0.0, pool_scale: float = 1.0) -> list:
    """Wavefront order for sub-stage assembly: tightest slack first (ties
    broken by arrival so the order is deterministic)."""
    return sorted(
        reqs,
        key=lambda r: (slo_slack_us(r, now, budget, cost_model, sizes,
                                    default_slo_us, shard_map, merge_us,
                                    pool_scale),
                       r.arrival_us, r.request_id),
    )


# ---------------------------------------------------------------------------
# Streaming admission control
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdmissionDecision:
    admitted: bool
    reason: str = "admitted"  # admitted | queue_full | deadline_infeasible
    lower_bound_us: float = 0.0
    slack_us: float = float("inf")


@owned_by("scheduler")
class AdmissionController:
    """Admission policy for the streaming front-end.

    Two independent gates, each enabled by its SchedulerConfig knob:

    * ``max_pending > 0`` — bounded in-system queue: once ``max_pending``
      requests are in the system (queued + in flight), further submissions
      are shed (``queue_full``) instead of growing the backlog without
      bound past the saturation knee.
    * ``admission_control`` — deadline-infeasibility shedding: a request is
      shed (``deadline_infeasible``) when its remaining SLO slack is below
      ``shed_margin`` times a cost-model lower bound of serving it, where
      the bound is its own minimal service time (at least one smallest-
      cluster scan per retrieval node, at least one decode step per
      generation node) *plus* the queueing delay implied by the work
      already in the system (first-order remaining-time estimate of every
      in-flight request, spread over the retrieval pool).  Admitting such
      a request could only burn worker time on a guaranteed SLO violation
      and push other requests past their deadlines; ``shed_margin < 1``
      relaxes the gate (e.g. to keep requests a cross-request cache answer
      might still rescue), ``> 1`` adds headroom.

    Decisions are pure functions of (config, graph shape, clock, in-system
    load, EMA cost estimates), so a fixed workload seed yields the same
    shed set on every run.
    """

    def __init__(self, cfg, budget, cost_model, cluster_sizes,
                 shard_map=None, lifecycle=None):
        self.cfg = cfg
        self.budget = budget
        self.cost_model = cost_model
        self.sizes = np.asarray(cluster_sizes)
        self.min_cluster_size = int(self.sizes.min()) if self.sizes.size else 0
        # shard-mode cost semantics: a retrieval stage's service time is the
        # max over its per-shard partial scans plus a scheduler-side merge
        # term, not a single-worker sum over its clusters
        self.shard_map = shard_map
        self.merge_us = float(getattr(cfg, "shard_merge_us", 0.0)
                              ) if shard_map is not None else 0.0
        # worker lifecycle registry (serving.lifecycle.WorkerRegistry):
        # backlog spreads over the *effective* pool, not the static size
        self.lifecycle = lifecycle

    def effective_pool(self) -> int:
        """Workers actually able to absorb new retrieval work: the static
        pool size with every worker HEALTHY, shrinking as workers die or
        drain (0 = nothing can serve; backlog becomes unbounded)."""
        if self.lifecycle is not None and not self.lifecycle.all_healthy():
            return int(self.lifecycle.effective_pool_size())
        return max(1, int(self.cfg.num_ret_workers))

    def lower_bound_us(self, req) -> float:
        """Cost-model lower bound of serving ``req`` in isolation: each graph
        node contributes its StageSpec's minimal single-pass service time
        (one smallest-cluster scan per retrieval node, one decode step per
        generation node, one fixed+unit slice per host stage).  In shard
        mode sharding cannot shrink a single smallest-cluster scan (``max``
        over one shard == that shard), but every retrieval stage
        additionally pays at least one scatter-gather merge."""
        from repro.core import stages

        counts: dict[str, int] = {}
        for n in req.graph.nodes.values():
            counts[n.kind] = counts.get(n.kind, 0) + 1
        total = 0.0
        for kind in sorted(counts):
            total += counts[kind] * stages.spec(kind).min_service_us(self)
        # impaired pool: fewer workers to overlap sub-stages onto, so even
        # the single-pass bound stretches by the static/effective ratio
        if self.lifecycle is not None and not self.lifecycle.all_healthy():
            eff = self.effective_pool()
            n_static = max(1, int(self.cfg.num_ret_workers))
            if 0 < eff < n_static:
                total *= n_static / eff
        return total

    def backlog_us(self, active) -> float:
        """Queueing-delay lower bound seen by a new arrival: the first-order
        remaining service time of everything in flight.  Whole-index mode
        spreads independent stages over the retrieval pool (``/ N``); shard
        mode does *not* divide — each request's estimate is already the
        parallel (max-over-shards) service time and scatter sets occupy the
        whole pool at once, so in-flight requests queue behind each other."""
        total = sum(
            estimate_remaining_us(r, self.budget, self.cost_model, self.sizes,
                                  self.shard_map, self.merge_us)
            for r in active)
        if total <= 0.0:
            return 0.0
        pool = self.effective_pool()
        if pool <= 0:
            return float("inf")  # nothing left to serve retrieval work
        if self.shard_map is not None:
            return total
        return total / pool

    def evaluate(self, req, now: float, queue_len: int,
                 active=()) -> AdmissionDecision:
        # load-based gates (queue bound, in-flight backlog) only apply to
        # requests entering service *now* — the streaming path, where the
        # clock has been stepped to the arrival.  A pre-loaded future
        # arrival is judged against today's load for work that may have
        # fully drained by its arrival time, so it only faces the
        # load-independent isolated-service check.
        due_now = req.arrival_us <= now
        if (due_now and self.cfg.max_pending > 0
                and queue_len >= self.cfg.max_pending):
            return AdmissionDecision(False, "queue_full")
        if not self.cfg.admission_control:
            return AdmissionDecision(True)
        slo = getattr(req, "slo_us", 0.0) or self.cfg.slo_us
        lb = self.lower_bound_us(req)
        if due_now:
            lb += self.backlog_us(active)
        # slack remaining at service start: deadline minus the later of the
        # submission clock and the request's own arrival stamp (a pre-loaded
        # future arrival still has its whole SLO ahead of it)
        slack = req.arrival_us + slo - max(now, req.arrival_us)
        if slack < self.cfg.shed_margin * lb:
            return AdmissionDecision(False, "deadline_infeasible", lb, slack)
        return AdmissionDecision(True, "admitted", lb, slack)
