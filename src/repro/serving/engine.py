"""Slab-based continuous-batching generation engine (real execution mode).

The engine owns a fixed pool of ``max_batch`` sequence slots backed by one
decode-state pytree (``lm.init_decode_state``), so a decode step is a single
jitted call over the whole slab — the vLLM-style step() the wavefront
scheduler drives.  Sequences join via per-sequence prefill (bucketed padding
to bound recompilation) whose state is scattered into a free slot, and leave
when EOS/max-token hits, freeing the slot for the next request: continuous
batching.

This engine is what RealBackend binds to; the multi-pod serving path jits
the same ``decode_step`` over the production mesh (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass
class Sequence:
    seq_id: int
    slot: int
    prompt_len: int
    max_new: int
    tokens: list  # generated tokens
    done: bool = False


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class GenerationEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 512, eos_id: int = 0,
                 sampler: Optional[SamplerConfig] = None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.sampler = sampler if sampler is not None else SamplerConfig()
        self.state = lm.init_decode_state(cfg, max_batch, max_len)
        self.free_slots = list(range(max_batch))
        self.seqs: dict[int, Sequence] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(seed)
        self._last_tokens = jnp.zeros((max_batch,), jnp.int32)
        self._active = np.zeros((max_batch,), bool)

        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, cfg, t, max_len=max_len),
            static_argnames=(),
        )
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))

    # ------------------------------------------------------------- internals
    def _decode_impl(self, params, state, tokens, key, active):
        logits, state = lm.decode_step(params, self.cfg, tokens, state)
        nxt = sample(logits, key, self.sampler)
        # frozen slots keep emitting pad; their cache_len must not grow
        state["cache_len"] = jnp.where(active, state["cache_len"],
                                       state["cache_len"] - 1)
        return nxt, state

    def _insert_impl(self, slab_state, one_state, slot):
        def ins(slab, one):
            if slab.ndim == 1:  # cache_len (B,)
                return slab.at[slot].set(one[0])
            # (L, B, ...) vs (L, 1, ...)
            return jax.lax.dynamic_update_slice_in_dim(slab, one.astype(slab.dtype), slot, axis=1)

        return jax.tree.map(ins, slab_state, one_state)

    # ------------------------------------------------------------------ API
    def can_admit(self) -> bool:
        return bool(self.free_slots)

    def add_sequence(self, prompt_tokens: np.ndarray, max_new: int = 64) -> int:
        """Prefill a prompt into a free slot; returns seq id."""
        if not self.free_slots:
            raise RuntimeError("no free slots")
        slot = self.free_slots.pop()
        # decode writes land at cache_len, so the padded prompt width plus
        # the decode cap must fit the cache or late steps clamp at max_len
        # and corrupt the last KV slot.  Reserve decode room for max_new
        # (but at most half the cache — max_new is often a loose cap), keep
        # the prompt suffix (left-pad semantics), and shrink the effective
        # max_new to the headroom left after padding.
        decode_room = min(max_new, max(self.max_len // 2, 1))
        keep = max(self.max_len - decode_room, 1)
        prompt_tokens = np.asarray(prompt_tokens)
        if len(prompt_tokens) > keep:
            prompt_tokens = prompt_tokens[-keep:]
        n = len(prompt_tokens)
        pad_to = min(_bucket(n), keep)
        max_new = min(max_new, self.max_len - pad_to)
        toks = np.zeros((1, pad_to), np.int32)
        toks[0, pad_to - n:] = prompt_tokens  # left-pad (simplest causal-safe)
        logits, st1 = self._prefill(self.params, jnp.asarray(toks))
        self.state = self._insert(self.state, st1, slot)
        # note: left-padding slightly pollutes the prefix; acceptable for the
        # toy-model integration path (real deployment uses paged prefill)
        first = int(jnp.argmax(logits[0]))
        sid = self._next_id
        self._next_id += 1
        self.seqs[sid] = Sequence(sid, slot, n, max_new, [first])
        self._active[slot] = True
        lt = np.array(self._last_tokens)
        lt[slot] = first
        self._last_tokens = jnp.asarray(lt)
        return sid

    def step(self) -> dict[int, int]:
        """One decode step over the slab; returns {seq_id: new_token}."""
        if not self.seqs:
            return {}
        self._key, sub = jax.random.split(self._key)
        active = jnp.asarray(self._active)
        nxt, self.state = self._decode(self.params, self.state,
                                       self._last_tokens, sub, active)
        self._last_tokens = nxt
        out: dict[int, int] = {}
        nxt_np = np.asarray(nxt)
        for sid, seq in list(self.seqs.items()):
            if seq.done:
                continue
            tok = int(nxt_np[seq.slot])
            seq.tokens.append(tok)
            out[sid] = tok
            if tok == self.eos_id or len(seq.tokens) >= seq.max_new:
                seq.done = True
                self._active[seq.slot] = False
                self.free_slots.append(seq.slot)
                del self.seqs[sid]
        return out

    def step_batch(self, n_steps: int) -> None:
        for _ in range(n_steps):
            if not self.seqs:
                return
            self.step()

    @property
    def batch_size(self) -> int:
        return len(self.seqs)
