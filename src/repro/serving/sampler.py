"""Token sampling: greedy / temperature / top-k (pure JAX, jit-safe)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0  # 0 -> greedy
    top_k: int = 0  # 0 -> full softmax


def sample(logits: jax.Array, key: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """logits (B, V) -> tokens (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(l, cfg.top_k)[0][..., -1:]
        l = jnp.where(l < kth, -jnp.inf, l)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)
