"""Worker lifecycle: registration, heartbeats, drain/rebind, failure states.

The serving pool's control plane (the register/heartbeat/drain/rebind shape
of the astraflow worker-scheduler exemplar, adapted to the virtual event
clock): every retrieval worker is tracked by a :class:`WorkerRegistry` with
explicit states

    JOINING -> HEALTHY <-> SUSPECT -> DEAD
                  |                    ^
                  v                    |
               DRAINING  (rebind) -----+--> back to HEALTHY

* **JOINING** — registered, first heartbeat pending (promotion is immediate
  on the registration heartbeat; the state exists so timelines record the
  join).
* **HEALTHY** — heartbeating; eligible for new work.
* **SUSPECT** — heartbeats missed for ``suspect_after_us``: no *new* work,
  in-flight work is hedged (duplicate dispatch, first result wins).  A
  resumed heartbeat returns the worker to HEALTHY.
* **DRAINING** — operator-initiated leave: finishes in-flight work, takes
  no new work; ``rebind`` returns it to the pool.
* **DEAD** — heartbeats missed for ``dead_after_us`` (crash, or a wedge so
  long it is indistinguishable from one).  Terminal for fault-driven
  deaths while the underlying fault persists; in-flight work is recovered
  by the scheduler and any late results are fenced (discarded).

Heartbeats are *virtual*: with no fault plan a live worker's heartbeat is
always fresh, so with all knobs off nothing ever transitions and the
serving path is bit-identical to the pre-lifecycle loop.  A
``serving.faults.FaultPlan`` freezes heartbeats at a crash instant or
inside a severe stall window, and ``tick(now, plan)`` turns the resulting
gaps into state transitions at deterministic virtual-clock instants (the
scheduler folds ``next_transition_us`` into its event list).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.ownership import owned_by

JOINING = "joining"
HEALTHY = "healthy"
SUSPECT = "suspect"
DRAINING = "draining"
DEAD = "dead"

STATES = (JOINING, HEALTHY, SUSPECT, DRAINING, DEAD)


@dataclasses.dataclass
class WorkerHealth:
    wid: int
    state: str = JOINING
    last_heartbeat_us: float = 0.0
    registered_us: float = 0.0
    # [(t_us, state), ...] — every transition, for reports/tests
    timeline: list = dataclasses.field(default_factory=list)


@owned_by("scheduler")
class WorkerRegistry:
    """Health states for the retrieval-worker pool, driven by virtual-clock
    heartbeats.  The registry is always built (drain/rebind are operational
    APIs, not fault injection); with no fault plan and no drain calls every
    worker stays HEALTHY forever and the scheduler's behaviour is unchanged.
    """

    def __init__(self, num_workers: int, *,
                 heartbeat_interval_us: float = 50_000.0,
                 suspect_after_us: float = 150_000.0,
                 dead_after_us: float = 400_000.0,
                 external_heartbeats: bool = False):
        self.heartbeat_interval_us = float(heartbeat_interval_us)
        self.suspect_after_us = float(suspect_after_us)
        self.dead_after_us = float(dead_after_us)
        # external mode (wall-clock ingress): heartbeats come only from
        # explicit heartbeat() calls — the stored stamp is the truth and
        # real gaps drive SUSPECT/DEAD.  With a FaultPlan armed the plan
        # model stays authoritative either way, so chaos runs replay on the
        # virtual clock unchanged.
        self.external_heartbeats = bool(external_heartbeats)
        self.workers: dict[int, WorkerHealth] = {}
        self._n_not_healthy = 0
        # high-water mark of tick timestamps: wall-clock callers must never
        # run the gap math with a `now` behind one already processed
        self._last_tick_us = 0.0
        for _ in range(max(0, int(num_workers))):
            self.register(0.0)

    # --------------------------------------------------------------- states
    def state_of(self, wid: int) -> str:
        return self.workers[int(wid)].state

    def all_healthy(self) -> bool:
        """Fast path consulted every cycle: True iff no worker has ever left
        HEALTHY (the zero-fault, no-drain common case)."""
        return self._n_not_healthy == 0

    def can_schedule(self, wid: int) -> bool:
        """Eligible for *new* work this cycle."""
        return self.workers[int(wid)].state == HEALTHY

    def alive(self, wid: int) -> bool:
        return self.workers[int(wid)].state != DEAD

    def serving(self, wid: int) -> bool:
        """In the pool for new work now or after a SUSPECT recovery —
        i.e. not DEAD and not DRAINING.  Failover eligibility."""
        return self.workers[int(wid)].state not in (DEAD, DRAINING)

    def owner_serves(self, wid: int) -> bool:
        """A shard owner that is HEALTHY or SUSPECT keeps its parts (busy /
        possibly-recovering owners make parts *wait*, like a busy owner
        always has); DRAINING and DEAD owners hand their parts to failover."""
        return self.workers[int(wid)].state in (HEALTHY, SUSPECT)

    def alive_for_work(self) -> int:
        """Workers that can take new work now or eventually (not DEAD, not
        DRAINING).  Zero means retrieval-side work is stranded."""
        return sum(1 for w in self.workers.values()
                   if w.state not in (DEAD, DRAINING))

    def effective_pool_size(self) -> int:
        """Pool size the admission/slack cost model should divide by: the
        workers actually able to absorb new retrieval work."""
        return self.alive_for_work()

    # ----------------------------------------------------------- operations
    def _set_state(self, w: WorkerHealth, state: str, now: float) -> None:
        if w.state == state:
            return
        if w.state == HEALTHY:
            self._n_not_healthy += 1
        if state == HEALTHY:
            self._n_not_healthy -= 1
        w.state = state
        w.timeline.append((float(now), state))

    def register(self, now: float = 0.0, wid: Optional[int] = None) -> int:
        """Add a worker (JOINING, promoted by its registration heartbeat)."""
        if wid is None:
            wid = len(self.workers)
        wid = int(wid)
        if wid in self.workers:
            raise ValueError(f"worker {wid} already registered")
        w = WorkerHealth(wid=wid, registered_us=float(now),
                         last_heartbeat_us=float(now))
        w.timeline.append((float(now), JOINING))
        self._n_not_healthy += 1  # JOINING until the first heartbeat
        self.workers[wid] = w
        self.heartbeat(wid, now)
        return wid

    def heartbeat(self, wid: int, now: float) -> None:
        """Record a heartbeat stamped ``now``.  Wall-clock feeds must stamp
        with ``time.monotonic``-derived values (serving/ingress.py
        WallClock); the clamp below additionally guarantees that even a
        non-monotonic stamp can never regress ``last_heartbeat_us`` — a
        backward clock jump must not mark every worker SUSPECT at once."""
        w = self.workers[int(wid)]
        if w.state == DEAD:
            return  # fenced: a late heartbeat cannot resurrect a dead worker
        w.last_heartbeat_us = max(w.last_heartbeat_us, float(now))
        if w.state in (JOINING, SUSPECT):
            self._set_state(w, HEALTHY, now)

    def drain(self, wid: int, now: float) -> bool:
        """Operator-initiated leave: finish in-flight work, take no new
        work.  Returns False for a DEAD worker (nothing left to drain)."""
        w = self.workers[int(wid)]
        if w.state == DEAD:
            return False
        self._set_state(w, DRAINING, now)
        return True

    def rebind(self, wid: int, now: float) -> bool:
        """Reconnect a drained (or dead-and-replaced) worker to the pool.
        The worker re-enters through JOINING and is promoted by the rebind
        heartbeat.  Rebinding a worker whose scripted fault still holds
        (crash in the plan's past) is futile — the next tick re-kills it."""
        w = self.workers[int(wid)]
        self._set_state(w, JOINING, now)
        w.last_heartbeat_us = float(now)
        self.heartbeat(wid, now)
        return w.state == HEALTHY

    # ------------------------------------------------------------ heartbeat
    def _last_heartbeat(self, w: WorkerHealth, now: float, plan) -> float:
        """Virtual heartbeat model: a live worker's heartbeat is always
        fresh; a crash freezes it at the crash instant; a severe stall
        window freezes it at the window start (resuming when the window
        ends).  In external mode (no plan) the stored stamp — fed by
        ``heartbeat()`` from the wall-clock ingress — is the truth."""
        hb = float(now)
        if plan is not None:
            c = plan.crash_at(w.wid)
            if c is not None and now >= c:
                hb = min(hb, float(c))
            else:
                ps = plan.heartbeat_pause_start(w.wid, now)
                if ps is not None:
                    hb = min(hb, float(ps))
        elif self.external_heartbeats:
            hb = w.last_heartbeat_us
        return max(hb, w.registered_us)

    def tick(self, now: float, plan=None) -> list:
        """Fold heartbeat state at ``now`` into transitions.  Returns
        ``[(wid, old_state, new_state), ...]`` for every change.  The list
        is canonically wid-ordered — the scheduler's recovery path and the
        obs transition hooks consume it in order, so the order must come
        from the worker ids, not from registration history.

        Non-monotonic guard: ``now`` is clamped to the high-water mark of
        previous ticks, so a regressed timestamp (rebased wall clock,
        out-of-order drain) can neither compute negative gaps nor regress
        any ``last_heartbeat_us`` already recorded."""
        now = max(float(now), self._last_tick_us)
        self._last_tick_us = now
        out = []
        for w in sorted(self.workers.values(), key=lambda x: x.wid):
            if w.state == DEAD:
                continue  # terminal
            hb = self._last_heartbeat(w, now, plan)
            w.last_heartbeat_us = hb
            gap = float(now) - hb
            if w.state == DRAINING:
                # an operator-held worker can still crash; only the
                # DRAINING -> DEAD edge applies (no SUSPECT demotion, no
                # auto-promotion back to HEALTHY)
                if gap >= self.dead_after_us:
                    self._set_state(w, DEAD, now)
                    out.append((w.wid, DRAINING, DEAD))
                continue
            if gap >= self.dead_after_us:
                new = DEAD
            elif gap >= self.suspect_after_us:
                new = SUSPECT
            else:
                new = HEALTHY
            if new != w.state:
                old = w.state
                self._set_state(w, new, now)
                out.append((w.wid, old, new))
        return out

    def next_transition_us(self, now: float, plan) -> Optional[float]:
        """Earliest future instant any worker's state can change under
        ``plan`` — folded into the scheduler's event clock so detection
        happens exactly at crash+suspect_after / crash+dead_after etc.
        Conservative: may return an instant where nothing changes (the tick
        is then a no-op), never misses one where something does."""
        if plan is None:
            return None
        cands = []
        for w in self.workers.values():
            if w.state == DEAD:
                continue
            c = plan.crash_at(w.wid)
            if c is not None:
                for t in (c + self.suspect_after_us, c + self.dead_after_us):
                    if t > now:
                        cands.append(float(t))
            for win in plan.stalls:
                if w.wid != win.wid or not win.pauses_heartbeats:
                    continue
                for t in (win.start_us + self.suspect_after_us,
                          win.start_us + self.dead_after_us):
                    if now < t <= win.end_us + self.dead_after_us:
                        cands.append(float(t))
                if now < win.end_us:  # heartbeats resume: SUSPECT recovers
                    cands.append(float(win.end_us))
        return min(cands) if cands else None

    # --------------------------------------------------------------- report
    def state_counts(self) -> dict:
        """Worker population per lifecycle state, every state present (zeros
        included) so telemetry series keep a fixed label set."""
        out = {s: 0 for s in STATES}
        for w in self.workers.values():
            out[w.state] += 1
        return out

    def report(self) -> dict:
        by_state: dict[str, int] = {}
        for w in self.workers.values():
            by_state[w.state] = by_state.get(w.state, 0) + 1
        return {
            "num_workers": len(self.workers),
            "effective_pool_size": self.effective_pool_size(),
            "by_state": by_state,
            "workers": {
                w.wid: {
                    "state": w.state,
                    "last_heartbeat_us": w.last_heartbeat_us,
                    "registered_us": w.registered_us,
                    "timeline": [(float(t), s) for t, s in w.timeline],
                }
                for w in sorted(self.workers.values(), key=lambda x: x.wid)
            },
        }
