"""Workload profiles: arrival processes, prompt/generation length
distributions, iteration counts — the knobs behind paper Figs. 6/12/14.

Lengths are sampled per (request, node) with deterministic seeds so a run is
reproducible and sim/real modes see the same workload.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WorkloadProfile:
    name: str = "default"
    prompt_tokens_mean: float = 96.0
    prompt_tokens_sigma: float = 0.4  # lognormal sigma
    gen_tokens_mean: float = 96.0  # per generation stage
    gen_tokens_sigma: float = 0.6  # heavy-ish tail (paper Fig. 6a)
    max_gen_tokens: int = 512
    iterations_mean: float = 2.5  # rounds for iterative workflows
    iterations_max: int = 5
    # per-request latency SLO (us); mean 0 -> no per-request SLO, the
    # scheduler falls back to SchedulerConfig.slo_us for every request
    slo_us_mean: float = 0.0
    slo_us_sigma: float = 0.0  # lognormal spread of per-request deadlines
    # per-workflow-class SLO tiers (workflow/graph name -> deadline us);
    # a matching class overrides the sampled per-request SLO, which is how
    # a heterogeneous mix gives interactive one-shot traffic a tight
    # deadline while multi-hop workflows get a loose one
    slo_class_us: dict = dataclasses.field(default_factory=dict)
    seed: int = 7

    def _rng(self, request_id: int, node_id: int, tag: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, request_id, node_id, tag])
        )

    def prompt_tokens(self, request_id: int, node_id: int) -> int:
        r = self._rng(request_id, node_id, 0)
        v = r.lognormal(np.log(self.prompt_tokens_mean), self.prompt_tokens_sigma)
        return int(np.clip(v, 8, 4 * self.prompt_tokens_mean))

    def gen_tokens(self, request_id: int, node_id: int, cap: int) -> int:
        r = self._rng(request_id, node_id, 1)
        v = r.lognormal(np.log(self.gen_tokens_mean), self.gen_tokens_sigma)
        return int(np.clip(v, 4, min(cap, self.max_gen_tokens)))

    def iterations(self, request_id: int) -> int:
        r = self._rng(request_id, 0, 2)
        v = 1 + r.poisson(max(self.iterations_mean - 1.0, 0.0))
        return int(np.clip(v, 1, self.iterations_max))

    def slo_us(self, request_id: int, workflow: str | None = None) -> float:
        """Per-request deadline length; 0.0 means 'use the server default'.
        A workflow whose class has an ``slo_class_us`` tier gets that tier's
        deadline; otherwise the (lognormal) per-request sample applies."""
        if workflow is not None and workflow in self.slo_class_us:
            return float(self.slo_class_us[workflow])
        if self.slo_us_mean <= 0.0:
            return 0.0
        if self.slo_us_sigma <= 0.0:
            return float(self.slo_us_mean)
        r = self._rng(request_id, 0, 3)
        return float(r.lognormal(np.log(self.slo_us_mean), self.slo_us_sigma))


def poisson_arrivals(rate_per_s: float, n: int, seed: int = 11) -> np.ndarray:
    """Arrival times (us) of a Poisson process with the given rate."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_per_s, 1e-9), size=n)
    return (np.cumsum(gaps) * 1e6).astype(np.float64)


# Named profiles for the three evaluation datasets (topic skew + hop count
# differ; values chosen to reproduce the qualitative contrasts of §6).
PROFILES = {
    "nq": WorkloadProfile("nq", gen_tokens_mean=72, iterations_mean=1.6),
    "wikiqa": WorkloadProfile("wikiqa", gen_tokens_mean=96, iterations_mean=2.6),
    "hotpotqa": WorkloadProfile("hotpotqa", gen_tokens_mean=112, iterations_mean=3.0),
}


# ---------------------------------------------------------------------------
# Heterogeneous-mix load generation (streaming serving)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamItem:
    """One open-loop arrival: consumed by ``Server.serve`` / ``submit``."""
    arrival_us: float
    workflow: str
    text: str = ""


@dataclasses.dataclass
class MixSpec:
    """A heterogeneous request mix: sampling weights over workflow classes
    plus optional per-class SLO tiers — the paper's headline scenario of a
    sustained stream mixing one-shot/HyDE/multistep/IRG/recomp traffic with
    differing deadlines.

    ``sample`` draws a deterministic open-loop Poisson stream (class choice
    and inter-arrival gaps both seeded), ``profile`` stamps the SLO tiers
    onto a WorkloadProfile so the scheduler and the admission layer see the
    per-class deadlines.
    """

    name: str = "mixed"
    # workflow name -> relative weight; empty = uniform over the names given
    weights: dict = dataclasses.field(default_factory=dict)
    # workflow name -> deadline us (copied into WorkloadProfile.slo_class_us)
    slo_tiers_us: dict = dataclasses.field(default_factory=dict)
    seed: int = 13

    def classes(self) -> list[str]:
        return sorted(self.weights)

    def sample(self, n: int, rate_per_s: float,
               seed: int | None = None) -> list[StreamItem]:
        """n arrivals of a Poisson stream at ``rate_per_s``, workflow classes
        drawn by weight.  Deterministic for a fixed (spec, seed)."""
        if not self.weights:
            raise ValueError(f"MixSpec {self.name!r} has no workflow weights")
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed if seed is None else seed, n]))
        names = self.classes()
        w = np.asarray([self.weights[c] for c in names], np.float64)
        gaps = rng.exponential(1.0 / max(rate_per_s, 1e-9), size=n)
        arrivals = np.cumsum(gaps) * 1e6
        picks = rng.choice(len(names), size=n, p=w / w.sum())
        return [StreamItem(float(arrivals[i]), names[int(picks[i])], f"q{i}")
                for i in range(n)]

    def profile(self, base: WorkloadProfile | None = None) -> WorkloadProfile:
        """A WorkloadProfile carrying this mix's per-class SLO tiers."""
        return dataclasses.replace(base or WorkloadProfile(),
                                   name=self.name,
                                   slo_class_us=dict(self.slo_tiers_us))


# ---------------------------------------------------------------------------
# Closed-loop load generation (wall-clock serving front-end)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClientDraw:
    """One submission of a closed-loop client's plan: what to send, how long
    to think after the answer, and the token charge against the shared
    budget (est_tokens ~ prompt + expected generation)."""
    workflow: str
    text: str
    think_s: float
    est_tokens: int


@dataclasses.dataclass
class ClosedLoopSpec:
    """Closed-loop workload: ``num_clients`` clients each submit, wait for
    the finish, think, and repeat — the serving-system complement to the
    open-loop Poisson stream (offered load adapts to service rate instead
    of being fixed).  ``token_budget`` caps the *total* tokens the client
    population may charge (0 = unlimited), the standard way to bound a
    closed-loop run's length.

    ``plan(client_id)`` is a deterministic per-client draw sequence
    (seeded by (seed, client_id)); the *arrival instants* are wall-clock
    and recorded by the ingress trace — everything else about the
    workload replays from the plan.
    """

    name: str = "closed"
    # workflow name -> relative weight (same convention as MixSpec)
    weights: dict = dataclasses.field(default_factory=dict)
    num_clients: int = 4
    requests_per_client: int = 8
    think_time_s: float = 0.05  # mean of an exponential think time
    est_tokens_mean: float = 160.0  # per-request charge against the budget
    token_budget: int = 0  # total tokens across all clients; 0 = unlimited
    seed: int = 29

    @classmethod
    def from_mix(cls, mix: "MixSpec", **kw) -> "ClosedLoopSpec":
        """Closed-loop spec over a named mix's workflow weights."""
        kw.setdefault("seed", mix.seed)
        return cls(name=mix.name, weights=dict(mix.weights), **kw)

    def plan(self, client_id: int) -> list[ClientDraw]:
        """The full deterministic draw sequence of one client."""
        if not self.weights:
            raise ValueError(f"ClosedLoopSpec {self.name!r} has no weights")
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(client_id)]))
        names = sorted(self.weights)
        w = np.asarray([self.weights[c] for c in names], np.float64)
        n = int(self.requests_per_client)
        picks = rng.choice(len(names), size=n, p=w / w.sum())
        thinks = rng.exponential(max(self.think_time_s, 1e-9), size=n)
        toks = rng.lognormal(np.log(max(self.est_tokens_mean, 1.0)), 0.4,
                             size=n)
        return [ClientDraw(workflow=names[int(picks[i])],
                           text=f"c{int(client_id)}q{i}",
                           think_s=float(thinks[i]),
                           est_tokens=int(max(1, toks[i])))
                for i in range(n)]


# Named mixes used by benchmarks/bench_serving.py and the examples.  Tier
# values follow the interactive-vs-batch contrast: one-shot/HyDE answer a
# user waiting at a prompt, multi-hop pipelines tolerate seconds.
MIXES = {
    "pure-oneshot": MixSpec(
        "pure-oneshot",
        weights={"one-shot": 1.0},
        slo_tiers_us={"one-shot": 2.5e6}),
    "balanced": MixSpec(
        "balanced",
        weights={"one-shot": 1.0, "hyde": 1.0, "multistep": 1.0,
                 "irg": 1.0, "recomp": 1.0},
        slo_tiers_us={"one-shot": 2.5e6, "hyde": 4e6, "recomp": 6e6,
                      "multistep": 10e6, "irg": 10e6}),
    "interactive-heavy": MixSpec(
        "interactive-heavy",
        weights={"one-shot": 6.0, "hyde": 2.0, "multistep": 1.0,
                 "irg": 1.0, "recomp": 2.0},
        slo_tiers_us={"one-shot": 2e6, "hyde": 3e6, "recomp": 5e6,
                      "multistep": 12e6, "irg": 12e6}),
    # retrieval-bound traffic (multi-hop pipelines dominate): the mix the
    # shard-mode serving sweep (benchmarks/bench_sharded_serving.py) uses —
    # retrieval-worker scaling and scatter-gather overheads only show when
    # probe volume, not decoding, is the bottleneck
    "retrieval-heavy": MixSpec(
        "retrieval-heavy",
        weights={"one-shot": 1.0, "multistep": 3.0, "irg": 3.0,
                 "recomp": 2.0},
        slo_tiers_us={"one-shot": 2.5e6, "recomp": 8e6,
                      "multistep": 12e6, "irg": 12e6}),
    # the stage-registry mix: the paper five plus the polymorphic stage
    # workflows (rerank / multiquery / hybrid / compress / pipeline), so the
    # goodput knee is measured on traffic whose host work is NOT just IVF
    # scans — cross-encoder blocks, query-variant fans and compression
    # blocks compete for the same retrieval pool under distinct SLO tiers
    "heterogeneous": MixSpec(
        "heterogeneous",
        weights={"one-shot": 2.0, "hyde": 1.0, "multistep": 1.0,
                 "irg": 1.0, "recomp": 1.0, "rerank": 2.0,
                 "multiquery": 2.0, "hybrid": 2.0, "compress": 1.0,
                 "pipeline": 1.0},
        slo_tiers_us={"one-shot": 2.5e6, "hyde": 4e6, "recomp": 6e6,
                      "multistep": 10e6, "irg": 10e6, "rerank": 4e6,
                      "multiquery": 5e6, "hybrid": 3e6, "compress": 6e6,
                      "pipeline": 12e6}),
}
