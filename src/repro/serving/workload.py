"""Workload profiles: arrival processes, prompt/generation length
distributions, iteration counts — the knobs behind paper Figs. 6/12/14.

Lengths are sampled per (request, node) with deterministic seeds so a run is
reproducible and sim/real modes see the same workload.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WorkloadProfile:
    name: str = "default"
    prompt_tokens_mean: float = 96.0
    prompt_tokens_sigma: float = 0.4  # lognormal sigma
    gen_tokens_mean: float = 96.0  # per generation stage
    gen_tokens_sigma: float = 0.6  # heavy-ish tail (paper Fig. 6a)
    max_gen_tokens: int = 512
    iterations_mean: float = 2.5  # rounds for iterative workflows
    iterations_max: int = 5
    # per-request latency SLO (us); mean 0 -> no per-request SLO, the
    # scheduler falls back to SchedulerConfig.slo_us for every request
    slo_us_mean: float = 0.0
    slo_us_sigma: float = 0.0  # lognormal spread of per-request deadlines
    seed: int = 7

    def _rng(self, request_id: int, node_id: int, tag: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, request_id, node_id, tag])
        )

    def prompt_tokens(self, request_id: int, node_id: int) -> int:
        r = self._rng(request_id, node_id, 0)
        v = r.lognormal(np.log(self.prompt_tokens_mean), self.prompt_tokens_sigma)
        return int(np.clip(v, 8, 4 * self.prompt_tokens_mean))

    def gen_tokens(self, request_id: int, node_id: int, cap: int) -> int:
        r = self._rng(request_id, node_id, 1)
        v = r.lognormal(np.log(self.gen_tokens_mean), self.gen_tokens_sigma)
        return int(np.clip(v, 4, min(cap, self.max_gen_tokens)))

    def iterations(self, request_id: int) -> int:
        r = self._rng(request_id, 0, 2)
        v = 1 + r.poisson(max(self.iterations_mean - 1.0, 0.0))
        return int(np.clip(v, 1, self.iterations_max))

    def slo_us(self, request_id: int) -> float:
        """Per-request deadline length; 0.0 means 'use the server default'."""
        if self.slo_us_mean <= 0.0:
            return 0.0
        if self.slo_us_sigma <= 0.0:
            return float(self.slo_us_mean)
        r = self._rng(request_id, 0, 3)
        return float(r.lognormal(np.log(self.slo_us_mean), self.slo_us_sigma))


def poisson_arrivals(rate_per_s: float, n: int, seed: int = 11) -> np.ndarray:
    """Arrival times (us) of a Poisson process with the given rate."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_per_s, 1e-9), size=n)
    return (np.cumsum(gaps) * 1e6).astype(np.float64)


# Named profiles for the three evaluation datasets (topic skew + hop count
# differ; values chosen to reproduce the qualitative contrasts of §6).
PROFILES = {
    "nq": WorkloadProfile("nq", gen_tokens_mean=72, iterations_mean=1.6),
    "wikiqa": WorkloadProfile("wikiqa", gen_tokens_mean=96, iterations_mean=2.6),
    "hotpotqa": WorkloadProfile("hotpotqa", gen_tokens_mean=112, iterations_mean=3.0),
}
