"""Wall-clock ingress: the threaded serving front-end, with the virtual
clock as its deterministic replay oracle.

Producer threads (open-loop stream replayers, closed-loop clients, the
heartbeat pump) timestamp real arrivals off a monotonic :class:`WallClock`
and hand them to the scheduler thread through a single-lock bounded
:class:`IngressQueue` — the queue crossing is the lock behind the
``@handoff`` points PR 9's ownership markers enumerated.  The scheduler
thread runs :class:`ServingLoop`, which drains the queue in submission
order and applies each row against the existing virtual-clock machinery::

    producers ──put()──▶ IngressQueue ──drain()──▶ ServingLoop
      (wall stamps,          (single lock,            │ step(eff)
       monotonic)             bounded, MPSC)          │ submit/heartbeat
                                                      ▼
                                              WavefrontScheduler
                                              (virtual event clock)

**The oracle / replay contract.**  Every clock advance of a wall-clock run
comes from a recorded :class:`ArrivalTrace` row — arrivals, heartbeats,
re-admission attempts, and idle ticks all carry the effective virtual
instant they were applied at (``eff = max(wall stamp, event clock)``).
:func:`replay_trace` mechanically re-applies those rows on a fresh server
over the pure virtual clock, then drains; because the scheduler itself is
deterministic given (submission order, instants), the replay produces
**bit-identical per-request event fingerprints** (``Server.fingerprints``)
to the threaded run — including chaos runs with a ``FaultPlan`` armed.
The deterministic path stays the test oracle for the threaded one.

Closed-loop serving (:func:`closed_loop_serve`) runs ``spec.num_clients``
client threads that each submit, wait for the finish over a
:class:`Ticket`, think, and repeat, under a shared token budget
(``serving.workload.ClosedLoopSpec``).  Requests shed by the admission
controller are parked and re-admitted once the controller's backlog
estimate drops (``Server.admission_load``); re-admission attempts are
trace rows, so they replay exactly.

This module is the *only* place in the serving packages allowed to read
the wall clock (``repro-lint`` policy ``wallclock_ingress_paths``); obs
taps receive wall values as arguments and never read time themselves.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Callable, Iterable, Optional

from repro.core.ownership import handoff, owned_by

# trace/queue row kinds (not stage kinds — scheduling never branches on
# these; they only select which Server entry point re-applies the row)
ARRIVAL = "arrival"
HEARTBEAT = "heartbeat"
READMIT = "readmit"
TICK = "tick"


class ReplayDivergence(RuntimeError):
    """A trace replay disagreed with the recorded run (admission outcome or
    request-id mismatch) — the determinism contract is broken."""


@owned_by("ingress")
class WallClock:
    """Monotonic wall clock mapped to virtual microseconds.

    ``time.monotonic`` never jumps backward on a rebased system clock (the
    reason ``time.time`` is banned here), and the high-water clamp makes
    even an injected non-monotonic source safe: ``now_us`` never regresses.
    ``speedup`` compresses wall time into virtual time (speedup 100 ->
    1 ms of wall is 100 000 virtual µs), which is how tests and benches
    run second-scale virtual workloads in milliseconds of wall time.
    """

    def __init__(self, speedup: float = 1.0,
                 source: Callable[[], float] = time.monotonic):
        self.speedup = float(speedup)
        self._source = source
        self._lock = threading.Lock()
        self._t0 = float(source())
        self._last_us = 0.0

    @handoff("*")
    def now_us(self) -> float:
        with self._lock:
            raw = (float(self._source()) - self._t0) * 1e6 * self.speedup
            self._last_us = max(self._last_us, raw)
            return self._last_us


@dataclasses.dataclass
class IngressItem:
    """One queue crossing: producer-stamped, drained by the scheduler
    thread.  ``seq`` is assigned under the queue lock, so it is the total
    submission order across all producer threads."""
    seq: int
    t_us: float
    kind: str
    workflow: str = ""
    text: str = ""
    wid: int = -1
    ticket: Optional["Ticket"] = None


@owned_by("ingress")
class IngressQueue:
    """Single-lock bounded MPSC queue between producer threads and the
    scheduler thread.  ``put`` blocks (bounded backpressure) while full;
    ``drain`` swaps the whole batch out under the lock, so the scheduler
    thread holds it for O(1) list moves, never while scheduling."""

    def __init__(self, maxsize: int = 4096):
        self.maxsize = max(1, int(maxsize))
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._items: list[IngressItem] = []
        self._seq = 0
        self._closed = False

    @handoff("*")
    def put(self, kind: str, t_us: float, *, workflow: str = "",
            text: str = "", wid: int = -1, ticket: Optional["Ticket"] = None,
            timeout_s: float = 30.0) -> Optional[int]:
        """Producer side: enqueue a row, blocking while the queue is full.
        Returns the assigned submission sequence number, or ``None`` when
        the queue closed (or stayed full past ``timeout_s``)."""
        deadline = time.monotonic() + float(timeout_s)
        with self._not_full:
            while len(self._items) >= self.maxsize and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._not_full.wait(remaining):
                    return None
            if self._closed:
                return None
            seq = self._seq
            self._seq += 1
            self._items.append(IngressItem(
                seq=seq, t_us=float(t_us), kind=kind, workflow=workflow,
                text=text, wid=int(wid), ticket=ticket))
            return seq

    @handoff("server")
    def drain(self) -> list[IngressItem]:
        """Scheduler side: take every queued row (submission order)."""
        with self._not_full:
            items, self._items = self._items, []
            if items:
                self._not_full.notify_all()
            return items

    @handoff("server")
    def pending_count(self) -> int:
        with self._lock:
            return len(self._items)

    @handoff("*")
    def close(self) -> None:
        with self._not_full:
            self._closed = True
            self._not_full.notify_all()


@owned_by("ingress")
class Ticket:
    """Completion handle handed back to a producer: resolved exactly once
    by the scheduler thread with ``"finished"`` or ``"shed"``."""

    def __init__(self):
        self._event = threading.Event()
        self.status = "pending"
        self.request_id: Optional[int] = None
        self.finish_us: Optional[float] = None
        self.latency_us: Optional[float] = None

    @handoff("server")
    def resolve(self, status: str, request_id: Optional[int] = None,
                finish_us: Optional[float] = None,
                latency_us: Optional[float] = None) -> None:
        self.status = status
        self.request_id = request_id
        self.finish_us = finish_us
        self.latency_us = latency_us
        self._event.set()

    @handoff("*")
    def wait(self, timeout_s: Optional[float] = None) -> bool:
        return self._event.wait(timeout_s)


@dataclasses.dataclass
class TraceRow:
    """One recorded event of a wall-clock run.  ``t_us`` is the *effective*
    virtual instant the row was applied at (never behind the event clock),
    so rows are non-decreasing in time and replay is a pure fold."""
    seq: int  # queue submission seq (-1 for loop-generated tick/readmit)
    t_us: float
    kind: str
    workflow: str = ""
    text: str = ""
    wid: int = -1
    ref: int = -1  # readmit rows: seq of the original shed arrival
    admitted: bool = True
    request_id: int = -1


@owned_by("server")
class ArrivalTrace:
    """The recorded arrival/heartbeat/readmit/tick log of a wall-clock run;
    JSON round-trips so traces can be archived and replayed offline."""

    SCHEMA_VERSION = 1

    def __init__(self, rows: Optional[list] = None):
        self.rows: list[TraceRow] = list(rows or [])

    def record(self, row: TraceRow) -> None:
        self.rows.append(row)

    def to_dict(self) -> dict:
        return {"schema_version": self.SCHEMA_VERSION,
                "rows": [dataclasses.asdict(r) for r in self.rows]}

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def from_dict(cls, d: dict) -> "ArrivalTrace":
        return cls(rows=[TraceRow(**r) for r in d.get("rows", ())])

    @classmethod
    def load(cls, path: str) -> "ArrivalTrace":
        with open(path) as f:
            return cls.from_dict(json.load(f))


class DurationTape:
    """FIFO log of the charges returned by a backend's measured surfaces
    (``gen_duration`` / ``search_charged`` / ``stage_charged``).

    The arrival trace pins every *external* clock advance of a wall run,
    but a measured backend (``RealBackend``) re-times its own execution
    on every pass, so a replayed replica drifts even when every arrival
    is reproduced exactly.  The tape closes that last hole: record mode
    appends each charge as it is measured; replay mode executes the same
    real compute (results and engine state stay live) but charges the
    *recorded* duration, which makes the replica's virtual timeline — and
    therefore its event fingerprints — bit-identical to the wall run.
    Scheduling is deterministic given arrivals + charges, so the replayed
    call sequence matches the recording; any mismatch in call kind, or an
    exhausted/unconsumed tape, raises :class:`ReplayDivergence` instead
    of silently diverging."""

    SCHEMA_VERSION = 1

    def __init__(self, rows: Optional[list] = None):
        self.rows: list = [(str(k), float(v)) for k, v in (rows or ())]
        self._idx = 0

    def record(self, kind: str, charge_us: float) -> None:
        self.rows.append((kind, float(charge_us)))

    def next(self, kind: str) -> float:
        if self._idx >= len(self.rows):
            raise ReplayDivergence(
                f"duration tape exhausted: replay issued backend call "
                f"#{self._idx} ({kind}) but only {len(self.rows)} were "
                f"recorded")
        k, charge = self.rows[self._idx]
        if k != kind:
            raise ReplayDivergence(
                f"duration tape call #{self._idx}: recorded kind {k!r}, "
                f"replay asked for {kind!r}")
        self._idx += 1
        return charge

    def rewind(self) -> None:
        self._idx = 0

    def remaining(self) -> int:
        return len(self.rows) - self._idx

    def to_dict(self) -> dict:
        return {"schema_version": self.SCHEMA_VERSION,
                "rows": [[k, v] for k, v in self.rows]}

    @classmethod
    def from_dict(cls, d: dict) -> "DurationTape":
        return cls(rows=d.get("rows", ()))


def tape_backend(backend, tape: DurationTape, *, mode: str):
    """Wrap a backend's measured charge surfaces with *tape* (in place).

    ``mode="record"`` appends every returned charge; ``mode="replay"``
    still runs the real compute (so results, engine KV state and cache
    residency evolve exactly as in the recording) but returns the taped
    charge, re-pointing the per-worker busy accounting at the taped value
    so ``worker_report`` matches too.  Wraps whatever is installed at
    call time, so launcher-style shims (e.g. an admission hook around
    ``gen_duration``) stay inside the tape in both modes.  Returns the
    backend."""
    if mode not in ("record", "replay"):
        raise ValueError(f"tape_backend mode must be record|replay: {mode!r}")
    orig_gen = backend.gen_duration
    orig_search = backend.search_charged
    orig_stage = backend.stage_charged

    if mode == "record":
        def gen_duration(n_prefill_tokens, batch, n_steps):
            charge = orig_gen(n_prefill_tokens, batch, n_steps)
            tape.record("gen", charge)
            return charge

        def search_charged(work, worker_id=0):
            charge, fn = orig_search(work, worker_id)
            tape.record("search", charge)
            return charge, fn

        def stage_charged(task, worker_id=0):
            charge, fn = orig_stage(task, worker_id)
            tape.record("stage", charge)
            return charge, fn
    else:
        def _rebook(worker_id, measured, taped):
            busy = getattr(backend, "worker_busy_us", None)
            if busy is not None:
                busy[worker_id] = (busy.get(worker_id, 0.0)
                                   - measured + taped)

        def gen_duration(n_prefill_tokens, batch, n_steps):
            orig_gen(n_prefill_tokens, batch, n_steps)
            return tape.next("gen")

        def search_charged(work, worker_id=0):
            measured, fn = orig_search(work, worker_id)
            taped = tape.next("search")
            _rebook(worker_id, measured, taped)
            return taped, fn

        def stage_charged(task, worker_id=0):
            measured, fn = orig_stage(task, worker_id)
            taped = tape.next("stage")
            _rebook(worker_id, measured, taped)
            return taped, fn

    backend.gen_duration = gen_duration
    backend.search_charged = search_charged
    backend.stage_charged = stage_charged
    return backend


@dataclasses.dataclass
class _Parked:
    """A shed request waiting for the admission backlog to drop."""
    seq: int
    req: object
    ticket: Optional[Ticket]
    attempts: int = 0
    next_try_us: float = 0.0


@owned_by("server")
class ServingLoop:
    """Scheduler-thread driver of a wall-clock run.

    Owns the ingress queue, the recorded trace, the ticket table, and the
    shed-request parking lot.  All scheduler state is touched from the
    thread calling :meth:`pump` — producer threads only ever touch the
    queue (``put``) and their own tickets (``wait``), which is exactly the
    single-writer discipline the ``ownership/*`` lint rules enforce.

    Every virtual-clock advance goes through a recorded row: arrivals and
    heartbeats carry producer stamps, and idle ticks (no queued rows, wall
    time moved on) are recorded too — so the replay visits the identical
    sequence of event-clock instants and the per-request event fingerprints
    match bit-for-bit.
    """

    def __init__(self, server, *, clock: Optional[WallClock] = None,
                 trace: Optional[ArrivalTrace] = None,
                 queue_maxsize: int = 4096,
                 tick_interval_us: float = 50_000.0,
                 readmit: bool = True,
                 readmit_backlog_us: float = float("inf"),
                 readmit_retry_us: float = 100_000.0,
                 max_readmit_attempts: int = 8,
                 poll_interval_s: float = 0.0005):
        self.server = server
        self.clock = clock if clock is not None else WallClock()
        self.queue = IngressQueue(maxsize=queue_maxsize)
        self.trace = trace if trace is not None else ArrivalTrace()
        self.tick_interval_us = float(tick_interval_us)
        self.readmit_enabled = bool(readmit)
        self.readmit_backlog_us = float(readmit_backlog_us)
        self.readmit_retry_us = float(readmit_retry_us)
        self.max_readmit_attempts = max(1, int(max_readmit_attempts))
        self.poll_interval_s = float(poll_interval_s)
        self._tickets: dict[int, Ticket] = {}  # request_id -> ticket
        self._parked: list[_Parked] = []
        self._done_idx = 0
        self._next_wall_sample_us = 0.0

    # ------------------------------------------------------------ plumbing
    def submit(self, workflow: str, text: str = "",
               ticket: Optional[Ticket] = None) -> Optional[int]:
        """Producer-side convenience: stamp now and enqueue an arrival.
        Safe from any thread; returns the queue submission seq."""
        return self.queue.put(ARRIVAL, self.clock.now_us(),
                              workflow=workflow, text=text, ticket=ticket)

    def unsettled(self) -> int:
        """Ticketed requests not yet resolved (admitted-in-flight or parked
        awaiting re-admission)."""
        return (len(self._tickets)
                + sum(1 for p in self._parked if p.ticket is not None))

    def _advance(self, t_us: float) -> float:
        """Step the event clock to the effective instant for a stamp."""
        eff = max(float(t_us), self.server.sched.now)
        self.server.step(eff)
        return eff

    def _note_row(self, kind: str) -> None:
        tel = self.server.sched.telemetry
        if tel is not None:
            tel.on_ingress_row(kind)

    # ------------------------------------------------------------ applying
    def _apply_arrival(self, it: IngressItem) -> None:
        eff = self._advance(it.t_us)
        req = self.server.build_request(it.text, it.workflow, eff)
        rid = self.server.submit_built(req)
        self.trace.record(TraceRow(
            seq=it.seq, t_us=eff, kind=ARRIVAL, workflow=it.workflow,
            text=it.text, admitted=rid is not None,
            request_id=-1 if rid is None else rid))
        self._note_row(ARRIVAL)
        if rid is not None:
            if it.ticket is not None:
                self._tickets[rid] = it.ticket
        elif self.readmit_enabled:
            self._parked.append(_Parked(
                seq=it.seq, req=req, ticket=it.ticket,
                next_try_us=self.server.sched.now + self.readmit_retry_us))
        elif it.ticket is not None:
            it.ticket.resolve("shed")

    def _apply_heartbeat(self, it: IngressItem) -> None:
        eff = self._advance(it.t_us)
        self.server.heartbeat_worker(it.wid, eff)
        self.trace.record(TraceRow(seq=it.seq, t_us=eff, kind=HEARTBEAT,
                                   wid=it.wid))
        self._note_row(HEARTBEAT)

    def _maybe_tick(self) -> None:
        """Idle advance: no queued rows but wall time moved on — record the
        advance so the replay visits the same instant."""
        wall = self.clock.now_us()
        if wall >= self.server.sched.now + self.tick_interval_us:
            eff = self._advance(wall)
            self.trace.record(TraceRow(seq=-1, t_us=eff, kind=TICK))
            self._note_row(TICK)

    def _post_completions(self) -> None:
        done = self.server.sched.done
        while self._done_idx < len(done):
            r = done[self._done_idx]
            self._done_idx += 1
            t = self._tickets.pop(r.request_id, None)
            if t is not None:
                t.resolve("finished", request_id=r.request_id,
                          finish_us=r.finish_us,
                          latency_us=float(r.finish_us) - float(r.arrival_us))

    def _maybe_readmit(self) -> None:
        if not self._parked:
            return
        load = self.server.admission_load()
        has_room = (load["max_pending"] <= 0
                    or load["in_system"] < load["max_pending"])
        if not has_room or load["backlog_us"] > self.readmit_backlog_us:
            return
        now = self.server.sched.now
        still: list[_Parked] = []
        for p in self._parked:
            if now < p.next_try_us:
                still.append(p)
                continue
            rid = self.server.readmit_request(p.req)
            self.trace.record(TraceRow(
                seq=-1, t_us=self.server.sched.now, kind=READMIT, ref=p.seq,
                admitted=rid is not None,
                request_id=-1 if rid is None else rid))
            self._note_row(READMIT)
            if rid is not None:
                if p.ticket is not None:
                    self._tickets[rid] = p.ticket
                continue
            p.attempts += 1
            if p.attempts >= self.max_readmit_attempts:
                if p.ticket is not None:
                    p.ticket.resolve("shed")
                continue  # final shed: stays counted in shed_final
            p.next_try_us = now + self.readmit_retry_us * (p.attempts + 1)
            still.append(p)
        self._parked = still

    def _sample_wall(self) -> None:
        """Passive obs tap: hand wall/virtual clock values to the telemetry
        sampler (obs never reads the wall clock itself).  Unrecorded — it
        changes no scheduling decision, so replay identity is unaffected."""
        tel = self.server.sched.telemetry
        if tel is None:
            return
        wall = self.clock.now_us()
        if wall < self._next_wall_sample_us:
            return
        self._next_wall_sample_us = wall + self.tick_interval_us
        tel.on_wall_sample(wall_us=wall, virtual_us=self.server.sched.now,
                           queue_depth=self.queue.pending_count(),
                           parked=len(self._parked))

    # ---------------------------------------------------------------- pump
    def pump(self, done: Callable[[], bool],
             max_wall_s: float = 120.0) -> None:
        """Drain/apply until ``done()`` holds with the queue empty and no
        work or unsettled tickets outstanding.  Runs on the scheduler
        thread; raises ``TimeoutError`` after ``max_wall_s`` of wall time
        (a liveness bar, not a correctness knob)."""
        deadline = time.monotonic() + float(max_wall_s)
        while True:
            items = self.queue.drain()
            for it in items:
                if it.kind == ARRIVAL:
                    self._apply_arrival(it)
                elif it.kind == HEARTBEAT:
                    self._apply_heartbeat(it)
                else:
                    raise ValueError(f"unexpected ingress row {it.kind!r}")
            self._post_completions()
            self._maybe_readmit()
            self._sample_wall()
            if not items:
                self._maybe_tick()
                self._post_completions()
                self._maybe_readmit()
                sched = self.server.sched
                if (done() and self.queue.pending_count() == 0
                        and self.unsettled() == 0 and not self._parked
                        and not sched.active and not sched.pending):
                    return
                time.sleep(self.poll_interval_s)
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"wall-clock serve exceeded max_wall_s={max_wall_s}")


# ---------------------------------------------------------------------------
# Heartbeat pump (producer thread)
# ---------------------------------------------------------------------------


def _pump_heartbeats(loop: ServingLoop, stop: threading.Event,
                     interval_s: float) -> None:
    """Producer thread: enqueue wall-stamped heartbeats for every worker.
    With a FaultPlan armed the pump mirrors the plan (a crashed or stalled
    worker stops heartbeating), so chaos runs behave — and replay — exactly
    like the plan-driven virtual model."""
    server = loop.server
    plan = getattr(server.backend, "fault_plan", None)
    while not stop.is_set():
        t = loop.clock.now_us()
        for wid in range(server.sched.num_ret_workers):
            if plan is not None:
                c = plan.crash_at(wid)
                if c is not None and t >= c:
                    continue
                if plan.heartbeat_pause_start(wid, t) is not None:
                    continue
            loop.queue.put(HEARTBEAT, t, wid=wid)
        stop.wait(interval_s)


def _start_heartbeats(loop: ServingLoop, heartbeats: Optional[bool],
                      speedup: float):
    """Start the pump when asked (or by default when the registry actually
    watches heartbeat gaps).  Returns (thread, stop_event) or (None, None)."""
    server = loop.server
    if heartbeats is None:
        heartbeats = (server.config.external_heartbeats
                      or getattr(server.backend, "fault_plan", None)
                      is not None)
    if not heartbeats:
        return None, None
    interval_s = server.config.heartbeat_interval_us / (1e6 * speedup)
    stop = threading.Event()
    th = threading.Thread(target=_pump_heartbeats,
                          args=(loop, stop, interval_s), daemon=True)
    th.start()
    return th, stop


# ---------------------------------------------------------------------------
# Front-ends: open-loop replayer / closed-loop clients
# ---------------------------------------------------------------------------


def serve_wallclock(server, stream: Iterable, *, speedup: float = 1.0,
                    heartbeats: Optional[bool] = None,
                    max_wall_s: float = 120.0,
                    loop: Optional[ServingLoop] = None, **loop_kw):
    """Open-loop wall-clock serve: a producer thread replays ``stream``
    (StreamItem-likes or ``(arrival_us, text, workflow)`` tuples) in wall
    time — arrival stamps are *real* clock readings, not the stream's
    virtual stamps — while the calling thread pumps the scheduler.
    Returns ``(Metrics, ArrivalTrace)``."""
    loop = loop if loop is not None else ServingLoop(
        server, clock=WallClock(speedup=speedup), **loop_kw)
    items = list(stream)
    producers_done = threading.Event()

    def produce() -> None:
        try:
            for it in items:
                if hasattr(it, "arrival_us"):
                    target, text, wf = (float(it.arrival_us), it.text,
                                        it.workflow)
                else:
                    target, text, wf = (float(it[0]), it[1], it[2])
                while True:
                    now = loop.clock.now_us()
                    if now >= target:
                        break
                    time.sleep(min((target - now) / (1e6 * speedup), 0.05))
                loop.queue.put(ARRIVAL, loop.clock.now_us(),
                               workflow=wf, text=text)
        finally:
            producers_done.set()

    producer = threading.Thread(target=produce, daemon=True)
    hb_thread, hb_stop = _start_heartbeats(loop, heartbeats, speedup)
    producer.start()
    try:
        loop.pump(done=producers_done.is_set, max_wall_s=max_wall_s)
    finally:
        if hb_stop is not None:
            hb_stop.set()
        loop.queue.close()
        producer.join(timeout=5.0)
        if hb_thread is not None:
            hb_thread.join(timeout=5.0)
    metrics = server.run()
    loop._post_completions()
    return metrics, loop.trace


def closed_loop_serve(server, spec, *, speedup: float = 1.0,
                      heartbeats: Optional[bool] = None,
                      max_wall_s: float = 120.0,
                      loop: Optional[ServingLoop] = None, **loop_kw):
    """Closed-loop wall-clock serve: ``spec.num_clients`` client threads
    each submit, block on their ticket, think, and repeat, under the
    spec's shared token budget (``serving.workload.ClosedLoopSpec``).
    Returns ``(Metrics, ArrivalTrace)``."""
    loop = loop if loop is not None else ServingLoop(
        server, clock=WallClock(speedup=speedup), **loop_kw)
    budget = _TokenBudget(spec.token_budget)

    def client(cid: int) -> None:
        for draw in spec.plan(cid):
            if not budget.take(draw.est_tokens):
                break
            ticket = Ticket()
            seq = loop.queue.put(ARRIVAL, loop.clock.now_us(),
                                 workflow=draw.workflow, text=draw.text,
                                 ticket=ticket)
            if seq is None:
                break
            if not ticket.wait(timeout_s=max_wall_s):
                break
            time.sleep(draw.think_s / speedup)

    clients = [threading.Thread(target=client, args=(cid,), daemon=True)
               for cid in range(spec.num_clients)]
    hb_thread, hb_stop = _start_heartbeats(loop, heartbeats, speedup)
    for th in clients:
        th.start()
    try:
        loop.pump(done=lambda: all(not th.is_alive() for th in clients),
                  max_wall_s=max_wall_s)
    finally:
        if hb_stop is not None:
            hb_stop.set()
        loop.queue.close()
        for th in clients:
            th.join(timeout=5.0)
        if hb_thread is not None:
            hb_thread.join(timeout=5.0)
    metrics = server.run()
    loop._post_completions()
    return metrics, loop.trace


class _TokenBudget:
    """Thread-safe shared token budget for closed-loop load generation
    (0 = unlimited)."""

    def __init__(self, budget: int):
        self._lock = threading.Lock()
        self.budget = int(budget)
        self.spent = 0

    def take(self, n: int) -> bool:
        with self._lock:
            if self.budget > 0 and self.spent + int(n) > self.budget:
                return False
            self.spent += int(n)
            return True


# ---------------------------------------------------------------------------
# The oracle: deterministic replay on the virtual clock
# ---------------------------------------------------------------------------


def replay_trace(server, trace: ArrivalTrace, max_time_us: float = 4e9):
    """Re-apply a recorded wall-clock run on a *fresh* server over the pure
    virtual clock: step to each row's effective instant, re-issue the same
    submissions/heartbeats/re-admissions in the same order, then drain.

    The admission outcome of every arrival/readmit row is recomputed live
    and checked against the recording — a mismatch raises
    :class:`ReplayDivergence` (it would mean scheduler state diverged).
    Returns the drained ``Metrics``; compare ``server.fingerprints()``
    against the recorded run's for the bit-identity check."""
    parked: dict[int, object] = {}
    for row in trace.rows:
        eff = max(float(row.t_us), server.sched.now)
        server.step(eff)
        if row.kind == ARRIVAL:
            req = server.build_request(row.text, row.workflow, eff)
            rid = server.submit_built(req)
            _expect(row, rid)
            if rid is None:
                parked[row.seq] = req
        elif row.kind == READMIT:
            req = parked.get(row.ref)
            if req is None:
                raise ReplayDivergence(
                    f"readmit row references unknown shed arrival seq "
                    f"{row.ref}")
            rid = server.readmit_request(req)
            _expect(row, rid)
            if rid is not None:
                del parked[row.ref]
        elif row.kind == HEARTBEAT:
            server.heartbeat_worker(row.wid, eff)
        elif row.kind != TICK:
            raise ReplayDivergence(f"unknown trace row kind {row.kind!r}")
    return server.run(max_time_us=max_time_us)


def _expect(row: TraceRow, rid: Optional[int]) -> None:
    admitted = rid is not None
    if admitted != row.admitted:
        raise ReplayDivergence(
            f"{row.kind} row seq={row.seq} t={row.t_us}: recorded "
            f"admitted={row.admitted}, replay got {admitted}")
    if admitted and row.request_id >= 0 and rid != row.request_id:
        raise ReplayDivergence(
            f"{row.kind} row seq={row.seq}: recorded request_id="
            f"{row.request_id}, replay assigned {rid}")
