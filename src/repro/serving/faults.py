"""Deterministic fault injection for the serving pool.

A :class:`FaultPlan` is a *seeded script* of everything that goes wrong in a
chaos run: worker crashes pinned to virtual-clock instants, stall windows
that inflate service time (and, when severe, pause the worker's heartbeats),
and transient per-task failures drawn from a counter-indexed seeded stream.
Because every draw is a pure function of ``(plan.seed, worker_id, counter)``
and the wavefront scheduler itself is deterministic, the *same plan replays
the same run event-for-event* — the property the chaos tests pin.

The plan is injected through the backend timing hooks
(``SimBackend.fault_latency``) and consulted by the worker lifecycle
registry (``serving/lifecycle.py``) to drive heartbeat-based state
transitions; the recovery machinery lives in ``core/wavefront.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# a stall must slow the worker at least this much before its heartbeat
# thread is considered wedged too (milder latency spikes keep heartbeating
# and are covered by per-task timeouts instead of SUSPECT transitions)
HEARTBEAT_STALL_FACTOR = 3.0


@dataclasses.dataclass(frozen=True)
class WorkerCrash:
    """Worker ``wid`` dies at ``at_us`` (virtual clock) and never returns.
    Work in flight at the crash is lost; results of jobs that would have
    completed after the crash are fenced (discarded) even if the scheduler
    only detects the death later through missed heartbeats."""

    wid: int
    at_us: float


@dataclasses.dataclass(frozen=True)
class StallWindow:
    """Worker ``wid`` runs ``factor``x slower for jobs dispatched inside
    ``[start_us, end_us)``.  Severe stalls (factor >=
    ``HEARTBEAT_STALL_FACTOR``) also pause the worker's heartbeats for the
    duration, so the lifecycle registry marks it SUSPECT."""

    wid: int
    start_us: float
    end_us: float
    factor: float = 4.0

    @property
    def pauses_heartbeats(self) -> bool:
        return self.factor >= HEARTBEAT_STALL_FACTOR


@dataclasses.dataclass
class FaultPlan:
    """A replayable chaos script over the retrieval-worker pool."""

    crashes: list = dataclasses.field(default_factory=list)
    stalls: list = dataclasses.field(default_factory=list)
    # probability that one dispatched task unit (sub-stage plan group /
    # scatter part / stage batch) fails transiently and must be retried
    transient_fail_prob: float = 0.0
    seed: int = 0

    # ------------------------------------------------------------- queries
    def crash_at(self, wid: int) -> Optional[float]:
        """Earliest crash instant scripted for ``wid`` (None = never)."""
        times = [c.at_us for c in self.crashes if c.wid == int(wid)]
        return min(times) if times else None

    def crashed_by(self, wid: int, t_us: float) -> bool:
        c = self.crash_at(wid)
        return c is not None and t_us >= c

    def stall_factor(self, wid: int, t_us: float) -> float:
        """Service-time multiplier for work dispatched to ``wid`` at
        ``t_us`` (max over active windows; 1.0 = no stall)."""
        f = 1.0
        for w in self.stalls:
            if w.wid == int(wid) and w.start_us <= t_us < w.end_us:
                f = max(f, float(w.factor))
        return f

    def heartbeat_pause_start(self, wid: int, t_us: float) -> Optional[float]:
        """Start of the severe stall window wedging ``wid``'s heartbeats at
        ``t_us`` (None when heartbeats are flowing)."""
        start = None
        for w in self.stalls:
            if (w.wid == int(wid) and w.pauses_heartbeats
                    and w.start_us <= t_us < w.end_us):
                start = w.start_us if start is None else min(start, w.start_us)
        return start

    def transient_fault(self, wid: int, seq: int) -> bool:
        """Deterministic per-dispatch failure draw: the ``seq``-th unit ever
        dispatched (a scheduler-maintained counter) fails iff the seeded
        stream for ``(seed, wid, seq)`` says so — same seed, same run, same
        failures."""
        if self.transient_fail_prob <= 0.0:
            return False
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 7919, int(wid), int(seq)]))
        return bool(rng.random() < self.transient_fail_prob)

    def change_times(self) -> list:
        """Every instant the plan's state can change (crash instants, stall
        window edges), ascending — the lifecycle registry folds these into
        the scheduler's event clock."""
        ts = {float(c.at_us) for c in self.crashes}
        for w in self.stalls:
            ts.add(float(w.start_us))
            ts.add(float(w.end_us))
        return sorted(ts)

    @property
    def is_empty(self) -> bool:
        return (not self.crashes and not self.stalls
                and self.transient_fail_prob <= 0.0)

    def describe(self) -> dict:
        return {
            "seed": int(self.seed),
            "crashes": [(int(c.wid), float(c.at_us)) for c in self.crashes],
            "stalls": [(int(w.wid), float(w.start_us), float(w.end_us),
                        float(w.factor)) for w in self.stalls],
            "transient_fail_prob": float(self.transient_fail_prob),
        }

    # --------------------------------------------------------- constructors
    @classmethod
    def random(cls, seed: int, n_workers: int, horizon_us: float, *,
               crash_frac: float = 0.25, stall_rate: float = 0.5,
               stall_len_us: float = 300_000.0, stall_factor: float = 6.0,
               transient_prob: float = 0.0) -> "FaultPlan":
        """A seeded random chaos script.  ``round(crash_frac * n_workers)``
        workers crash (choice of victim and instant is seeded), capped at
        ``n_workers - 1`` so the pool is never fully destroyed and
        whole-index failover always has a landing spot; stall windows arrive
        per-worker with probability ``stall_rate``, and transient failures
        fire with ``transient_prob``."""
        rng = np.random.default_rng(np.random.SeedSequence([int(seed), 4099]))
        n_workers = max(1, int(n_workers))
        crashes = []
        n_crashes = min(max(0, n_workers - 1),
                        int(round(crash_frac * n_workers)))
        victims = [int(w) for w in rng.permutation(n_workers)[:n_crashes]]
        for wid in victims:
            at = float(rng.uniform(0.1, 0.8) * horizon_us)
            crashes.append(WorkerCrash(wid=wid, at_us=at))
        stalls = []
        for wid in range(n_workers):
            if rng.random() < stall_rate:
                start = float(rng.uniform(0.0, 0.7) * horizon_us)
                length = float(rng.uniform(0.5, 1.5) * stall_len_us)
                factor = float(rng.uniform(2.0, stall_factor))
                stalls.append(StallWindow(wid=wid, start_us=start,
                                          end_us=start + length,
                                          factor=factor))
        return cls(crashes=crashes, stalls=stalls,
                   transient_fail_prob=float(transient_prob), seed=int(seed))
