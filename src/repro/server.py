"""HedraRAG Server façade (paper Listing 1):

    from repro.server import Server
    s = Server(index=..., embedder=..., mode="hedra")
    s.add_request("What is RAG?", g1)
    s.add_request("Compare RAG with long-context models.", g2)
    metrics = s.run()

The server owns admission (arrival times / Poisson open-loop), request-state
journaling (fault tolerance: completed requests are replayable), and the
wavefront scheduler + backend pair.

Streaming front-end (the paper's heterogeneous open-loop scenario): requests
can be submitted *mid-run* and the event clock advanced incrementally::

    s = Server(index, embedder, mode="hedra",
               max_pending=64,           # bounded arrival queue
               admission_control=True)   # deadline-infeasibility shedding
    for item in mix.sample(n=500, rate_per_s=12.0):   # serving/workload.py
        s.step(item.arrival_us)                        # advance the clock
        s.submit(item.text, item.workflow, arrival_us=item.arrival_us)
    metrics = s.run()                                  # drain
    metrics.window_summary(warmup_us, end_us)          # steady-state goodput

or equivalently in one call: ``metrics = s.serve(mix.sample(500, 12.0))``.
With no mid-run submissions and admission control disabled, the pre-loaded
batch path is bit-identical (per-request event fingerprints) to the legacy
run-to-completion loop.

Cross-request coordination (``repro.crossreq``) is enabled through the same
keyword overrides as every other scheduler knob::

    s = Server(index, embedder, mode="hedra",
               global_cache_size=256,   # shared semantic cache entries
               dedup_threshold=0.95,    # in-flight query fusion (cosine)
               replication_factor=2)    # hot-cluster replicas across workers
    ...
    s.run(); s.crossreq_report()
"""
from __future__ import annotations

import glob
import json
import os
from typing import Iterable, Optional, Union

from repro.core.backends import SimBackend
from repro.core.ownership import owned_by
from repro.core.ragraph import RAGraph
from repro.core.runtime import RequestContext
from repro.core.wavefront import Metrics, SchedulerConfig, WavefrontScheduler
from repro.serving.workload import WorkloadProfile


def _json_safe(payload):
    """Journal event payloads must round-trip through JSON: native scalars
    pass through, numpy scalars unwrap, anything structured stringifies."""
    if payload is None or isinstance(payload, (bool, int, float, str)):
        return payload
    if hasattr(payload, "item") and getattr(payload, "ndim", None) == 0:
        return _json_safe(payload.item())
    return repr(payload)


@owned_by("server")
class Server:
    def __init__(
        self,
        index,
        embedder,
        *,
        mode: str = "hedra",
        backend=None,
        config: Optional[SchedulerConfig] = None,
        workload: Optional[WorkloadProfile] = None,
        journal_path: Optional[str] = None,
        fault_plan=None,
        **cfg_overrides,
    ):
        self.index = index
        self.embedder = embedder
        self.config = config or SchedulerConfig.preset(mode, **cfg_overrides)
        self.backend = backend or SimBackend(index, embedder)
        if fault_plan is not None:
            # injected faults ride on the backend's timing hooks; the
            # scheduler picks the plan up from there and arms recovery
            self.backend.fault_plan = fault_plan
        self.workload = workload or WorkloadProfile()
        self.sched = WavefrontScheduler(self.backend, index, self.config,
                                        self.workload)
        self.journal_path = journal_path
        self._next_id = 0
        # crash recovery is automatic on a journal-backed start: unfinished
        # rows in an existing journal re-enter the queue with their original
        # request ids and pre-crash event prefixes
        self.recovered_ids: list = []
        if journal_path:
            self._sweep_journal_tmp(journal_path)
            if os.path.exists(journal_path):
                self.recovered_ids = self.readmit(
                    self.replay_unfinished(journal_path))

    # ------------------------------------------------------------------ API
    def _alloc_id(self) -> int:
        rid = self._next_id
        self._next_id += 1
        return rid

    def _build_request(self, input_text: str, graph: RAGraph,
                       arrival_us: float,
                       request_id: Optional[int] = None) -> RequestContext:
        if request_id is None:
            rid = self._alloc_id()
        else:
            # journal recovery pins the original id; future native ids must
            # never collide with it
            rid = int(request_id)
            self._next_id = max(self._next_id, rid + 1)
        graph.validate()
        state = {"input": input_text,
                 "_target_rounds": self.workload.iterations(rid)}
        return RequestContext(request_id=rid, graph=graph, state=state,
                              arrival_us=float(arrival_us),
                              slo_us=self.workload.slo_us(rid, graph.name))

    def add_request(self, input_text: str, graph: RAGraph,
                    arrival_us: float = 0.0) -> Optional[int]:
        """Pre-load a request (batch path).  Returns its id, or ``None``
        when an enabled admission-control knob sheds it (check
        ``is not None`` — id 0 is a valid request)."""
        req = self._build_request(input_text, graph, arrival_us)
        if not self.sched.add_request(req):
            return None
        return req.request_id

    def submit(self, input_text: str, graph: Union[RAGraph, str],
               arrival_us: Optional[float] = None) -> Optional[int]:
        """Admit a request *mid-run* (streaming path).  ``graph`` may be a
        built RAGraph or a workflow name; ``arrival_us`` defaults to the
        current event clock and must not lie in its past — the virtual
        clock cannot honor a stale stamp, and silently rewriting it would
        corrupt latency/SLO accounting.  Returns the request id, or
        ``None`` when the admission layer sheds it (check ``is not None``
        — id 0 is a valid request; ``Metrics.shed_*`` has the reason)."""
        if isinstance(graph, str):
            from repro import workflows

            graph = workflows.build(graph)
        now = self.sched.now
        arrival = now if arrival_us is None else float(arrival_us)
        if arrival < now:
            raise ValueError(
                f"arrival_us={arrival} is in the past (event clock at "
                f"{now}); submissions must be arrival-ordered")
        req = self._build_request(input_text, graph, arrival)
        if not self.sched.add_request(req):
            return None
        return req.request_id

    def build_request(self, input_text: str, graph: Union[RAGraph, str],
                      arrival_us: float) -> RequestContext:
        """Build (but do not submit) a request.  The ingress loop builds
        once and resubmits the *same* context across re-admission attempts,
        which preserves its id-keyed workload draws (iterations, SLO)."""
        if isinstance(graph, str):
            from repro import workflows

            graph = workflows.build(graph)
        return self._build_request(input_text, graph, float(arrival_us))

    def submit_built(self, req: RequestContext) -> Optional[int]:
        """Submit a ``build_request`` context at its stamped arrival (same
        stale-arrival contract as ``submit``).  Returns the id, or ``None``
        when an admission knob sheds it."""
        if req.arrival_us < self.sched.now:
            raise ValueError(
                f"arrival_us={req.arrival_us} is in the past (event clock "
                f"at {self.sched.now}); submissions must be arrival-ordered")
        if not self.sched.add_request(req):
            return None
        return req.request_id

    def readmit_request(self, req: RequestContext,
                        arrival_us: Optional[float] = None) -> Optional[int]:
        """Re-admission attempt for a previously shed request (closed-loop
        ingress path): the request is re-stamped to the later of
        ``arrival_us`` and the event clock — its latency/SLO window restarts
        at re-admission — and re-offered.  Counted as a resubmission, never
        as a second shed/submit of the same logical request; the journal
        sees the context at most once because shed requests never enter
        done/active/pending.  Returns the id, or ``None`` while the
        admission layer still refuses it."""
        base = self.sched.now if arrival_us is None else float(arrival_us)
        req.arrival_us = max(base, self.sched.now)
        if not self.sched.add_request(req):
            return None
        return req.request_id

    def heartbeat_worker(self, wid: int, now_us: float) -> None:
        """Feed an external heartbeat for ``wid`` (wall-clock ingress path;
        see SchedulerConfig.external_heartbeats)."""
        self.sched.worker_heartbeat(wid, now_us)

    def admission_load(self) -> dict:
        """In-system population / queue bound / backlog estimate — the
        signal the ingress loop's re-admission gate polls."""
        return self.sched.admission_load()

    def step(self, until_us: float) -> Metrics:
        """Advance the serving clock to ``until_us`` (streaming)."""
        return self.sched.step(until_us)

    def fingerprints(self) -> dict:
        """Per-request event fingerprints of every finished request: the
        bit-identity contract between a wall-clock ingress run and its
        virtual-clock replay (and between streaming and batch paths)."""
        return {r.request_id: [(float(t), e, repr(p)) for t, e, p in r.events]
                for r in self.sched.done}

    def serve_wallclock(self, stream: Optional[Iterable] = None, *,
                        closed_loop=None, speedup: float = 1.0,
                        max_wall_s: float = 120.0, **kw):
        """Threaded wall-clock serve (serving/ingress.py): producer threads
        timestamp real arrivals into the ingress queue while this thread
        drains it into the scheduler.  Returns ``(Metrics, ArrivalTrace)``;
        the trace replays through ``serving.ingress.replay_trace`` to
        bit-identical per-request event fingerprints."""
        from repro.serving import ingress

        if (stream is None) == (closed_loop is None):
            raise ValueError("pass exactly one of stream / closed_loop")
        if closed_loop is not None:
            return ingress.closed_loop_serve(
                self, closed_loop, speedup=speedup, max_wall_s=max_wall_s,
                **kw)
        return ingress.serve_wallclock(
            self, stream, speedup=speedup, max_wall_s=max_wall_s, **kw)

    def serve(self, stream: Iterable, max_time_us: float = 4e9) -> Metrics:
        """Open-loop streaming serve: walk an arrival-ordered ``stream`` of
        requests, stepping the event clock to each arrival before submitting
        it (so admission decisions see true in-flight load), then drain.

        Stream items are either ``serving.workload.StreamItem``-likes (with
        ``.arrival_us``/``.workflow``/``.text``) or ``(arrival_us, text,
        graph_or_workflow_name)`` tuples."""
        for item in stream:
            if hasattr(item, "arrival_us"):
                arrival, text, graph = (item.arrival_us, item.text,
                                        item.workflow)
            else:
                arrival, text, graph = item
            arrival = float(arrival)
            if arrival > max_time_us:
                break
            self.sched.step(min(arrival, max_time_us))
            self.submit(text, graph, arrival_us=arrival)
        return self.run(max_time_us=max_time_us)

    def run(self, max_time_us: float = 4e9) -> Metrics:
        m = self.sched.run(max_time_us=max_time_us)
        if self.journal_path:
            self.write_journal(self.journal_path)
        return m

    def crossreq_report(self) -> dict:
        """Cross-request coordination counters (empty when disabled)."""
        if self.sched.crossreq is None:
            return {}
        return self.sched.crossreq.report()

    # -------------------------------------------------------- observability
    def export_trace(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event / Perfetto JSON of the run so far (requires
        ``tracing=True``).  Returns the trace object; with ``path`` also
        writes it to disk (open in https://ui.perfetto.dev or
        ``chrome://tracing``)."""
        if self.sched.obs is None:
            raise RuntimeError(
                "tracing is off — construct the Server with tracing=True "
                "(SchedulerConfig.tracing) to record spans")
        trace = self.sched.obs.to_chrome()
        if path:
            with open(path, "w") as f:
                json.dump(trace, f, indent=1)
        return trace

    def metrics_snapshot(self, path: Optional[str] = None) -> dict:
        """Labeled-registry snapshot (requires ``telemetry=True``): the
        structured samples plus the Prometheus text exposition under
        ``"prometheus"`` and the virtual-clock sample timeline under
        ``"timeline"``.  With ``path`` also writes the JSON to disk."""
        tel = self.sched.telemetry
        if tel is None:
            raise RuntimeError(
                "telemetry is off — construct the Server with telemetry=True "
                "(SchedulerConfig.telemetry) to sample metrics")
        snap = tel.snapshot()
        snap["prometheus"] = tel.registry.render()
        if path:
            with open(path, "w") as f:
                json.dump(snap, f, indent=1)
        return snap

    def attribution_report(self, *, check: bool = True,
                           rel_tol: float = 1e-6) -> dict:
        """Per-request latency attribution + run-level bottleneck report
        (requires ``tracing=True``).  With ``check=True`` raises if any
        finished request's components fail to sum to its measured latency
        within ``rel_tol`` relative tolerance."""
        if self.sched.obs is None:
            raise RuntimeError(
                "tracing is off — construct the Server with tracing=True "
                "to enable latency attribution")
        from repro.obs.attribution import attribution_report

        return attribution_report(self.sched.obs, check=check,
                                  rel_tol=rel_tol)

    # ------------------------------------------------------ worker lifecycle
    def register_worker(self) -> int:
        """Grow the pool mid-run: add a retrieval worker, returns its id."""
        return self.sched.register_worker()

    def drain_worker(self, wid: int) -> bool:
        """Stop scheduling new work on ``wid``; in-flight work finishes."""
        return self.sched.drain_worker(wid)

    def rebind_worker(self, wid: int) -> bool:
        """Bring a drained/dead worker back into the schedulable pool."""
        return self.sched.rebind_worker(wid)

    def lifecycle_report(self) -> dict:
        """Per-worker health states, heartbeats, and state-change timelines
        plus the pool-level recovery counters."""
        rep = self.sched.lifecycle.report()
        m = self.sched.metrics
        rep["counters"] = {
            "worker_suspects": m.worker_suspects,
            "worker_deaths": m.worker_deaths,
            "task_timeouts": m.task_timeouts,
            "redispatches": m.redispatches,
            "retries": m.retries,
            "transient_failures": m.transient_failures,
            "hedged_dispatches": m.hedged_dispatches,
            "hedged_wins": m.hedged_wins,
            "failovers": m.failovers,
            "degraded_drops": m.degraded_drops,
            "degraded_completions": m.degraded_completions,
        }
        return rep

    def shard_report(self) -> dict:
        """Shard-mode serving state (empty when ``index_sharding`` is off):
        the cluster-range ownership table, scatter/merge counters, and —
        when a hybrid engine is attached — per-worker device-slab
        residency."""
        sm = self.sched.shard_map
        if sm is None:
            return {}
        out = {
            "n_shards": sm.n_shards,
            "bounds": (sm.bounds.tolist() if sm.bounds is not None else None),
            "shard_vectors": sm.shard_sizes(
                self.index.cluster_sizes()).tolist(),
            "shard_scatters": self.sched.metrics.shard_scatters,
            "shard_parts": self.sched.metrics.shard_parts,
            "shard_merges": self.sched.metrics.shard_merges,
            "failovers": self.sched.metrics.failovers,
            "degraded_completions": self.sched.metrics.degraded_completions,
        }
        hyb = getattr(self.backend, "hybrid", None)
        if hyb is not None:
            out["per_owner_resident"] = hyb.cache.per_owner_resident()
        return out

    # ------------------------------------------------------- fault tolerance
    def write_journal(self, path: str) -> None:
        """Request journal: enough to replay / resume after a crash.

        One JSON row per line, written to a temp file and atomically
        ``os.replace``d into place — a crash mid-write leaves the previous
        journal intact instead of a truncated one, and a crash between
        write and rename at worst leaves a stale temp file behind."""
        rows = []
        for r in self.sched.done + self.sched.active + self.sched.pending:
            rows.append({
                "request_id": r.request_id,
                "graph": r.graph.name,
                "input": r.state.get("input"),
                "arrival_us": r.arrival_us,
                "finished": r.finished,
                "finish_us": r.finish_us,
                "events": [(t, e, _json_safe(p)) for t, e, p in r.events],
            })
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        self._sweep_journal_tmp(path)

    @staticmethod
    def _sweep_journal_tmp(path: str) -> None:
        """Remove orphaned ``<journal>.tmp.<pid>`` siblings.

        A crash between temp-file write and ``os.replace`` strands the temp
        file; since the pid suffix changes across restarts, those orphans
        would otherwise accumulate forever.  Swept on journal-backed server
        start and after each successful replace — at both points every
        surviving ``.tmp.*`` is known stale (this process's own temp file is
        already renamed away or not yet created)."""
        for stale in glob.glob(glob.escape(path) + ".tmp.*"):
            try:
                os.remove(stale)
            except OSError:
                pass  # concurrent sweep or permissions: leave it

    @staticmethod
    def read_journal(path: str) -> list[dict]:
        """All journal rows.  Reads the JSONL format (one request per line),
        tolerating a truncated trailing line from a crash mid-append; the
        legacy single-JSON-array format is still accepted."""
        with open(path) as f:
            text = f.read()
        if text.lstrip().startswith("["):  # legacy array journal
            return json.loads(text)
        rows = []
        lines = text.splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break  # partial trailing row: drop it, keep the rest
                raise
        return rows

    @staticmethod
    def replay_unfinished(path: str) -> list[dict]:
        """Requests that must be re-admitted after restart."""
        return [r for r in Server.read_journal(path) if not r["finished"]]

    def readmit(self, rows: Iterable[dict]) -> list[Optional[int]]:
        """Re-admit journal rows (``replay_unfinished`` output) into this —
        possibly warm, possibly shard-mode — server: each row's workflow is
        rebuilt by name and re-queued at the later of its journaled arrival
        and the current event clock (the virtual clock cannot honor a stamp
        in its past).  The row's *original* request id is preserved (so
        per-request SLO/iteration draws and downstream trace joins survive
        the restart) unless a live request already holds it — then, and
        only then, a fresh id is allocated; the journaled partial event log
        is carried over so the post-restart trace keeps its pre-crash
        prefix.  Routing state (shard map, dispatcher, caches) is the live
        server's own, so recovered requests dispatch exactly like fresh
        ones.  Returns one request id per row (``None`` where an enabled
        admission knob sheds the recovered request)."""
        from repro import workflows

        live = {r.request_id for r in (self.sched.done + self.sched.active
                                       + self.sched.pending)}
        ids: list[Optional[int]] = []
        for row in rows:
            graph = workflows.build(row["graph"])
            arrival = max(float(row.get("arrival_us", 0.0)), self.sched.now)
            rid = row.get("request_id")
            if rid is not None and int(rid) in live:
                rid = None  # collides with a live request: remap fresh
            req = self._build_request(row.get("input") or "", graph,
                                      arrival_us=arrival, request_id=rid)
            req.events = [
                (float(ev[0]), ev[1], ev[2] if len(ev) > 2 else None)
                for ev in row.get("events", ())
            ]
            if not self.sched.add_request(req):
                ids.append(None)
                continue
            live.add(req.request_id)
            ids.append(req.request_id)
        return ids
