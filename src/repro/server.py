"""HedraRAG Server façade (paper Listing 1):

    from repro.server import Server
    s = Server(index=..., embedder=..., mode="hedra")
    s.add_request("What is RAG?", g1)
    s.add_request("Compare RAG with long-context models.", g2)
    metrics = s.run()

The server owns admission (arrival times / Poisson open-loop), request-state
journaling (fault tolerance: completed requests are replayable), and the
wavefront scheduler + backend pair.

Cross-request coordination (``repro.crossreq``) is enabled through the same
keyword overrides as every other scheduler knob::

    s = Server(index, embedder, mode="hedra",
               global_cache_size=256,   # shared semantic cache entries
               dedup_threshold=0.95,    # in-flight query fusion (cosine)
               replication_factor=2)    # hot-cluster replicas across workers
    ...
    s.run(); s.crossreq_report()
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Optional

import numpy as np

from repro.core.backends import SimBackend
from repro.core.ragraph import RAGraph
from repro.core.runtime import RequestContext
from repro.core.wavefront import Metrics, SchedulerConfig, WavefrontScheduler
from repro.serving.workload import WorkloadProfile


class Server:
    def __init__(
        self,
        index,
        embedder,
        *,
        mode: str = "hedra",
        backend=None,
        config: Optional[SchedulerConfig] = None,
        workload: Optional[WorkloadProfile] = None,
        journal_path: Optional[str] = None,
        **cfg_overrides,
    ):
        self.index = index
        self.embedder = embedder
        self.config = config or SchedulerConfig.preset(mode, **cfg_overrides)
        self.backend = backend or SimBackend(index, embedder)
        self.workload = workload or WorkloadProfile()
        self.sched = WavefrontScheduler(self.backend, index, self.config,
                                        self.workload)
        self.journal_path = journal_path
        self._ids = itertools.count()

    # ------------------------------------------------------------------ API
    def add_request(self, input_text: str, graph: RAGraph,
                    arrival_us: float = 0.0) -> int:
        rid = next(self._ids)
        graph.validate()
        state = {"input": input_text,
                 "_target_rounds": self.workload.iterations(rid)}
        req = RequestContext(request_id=rid, graph=graph, state=state,
                             arrival_us=float(arrival_us),
                             slo_us=self.workload.slo_us(rid))
        self.sched.add_request(req)
        return rid

    def run(self, max_time_us: float = 4e9) -> Metrics:
        m = self.sched.run(max_time_us=max_time_us)
        if self.journal_path:
            self.write_journal(self.journal_path)
        return m

    def crossreq_report(self) -> dict:
        """Cross-request coordination counters (empty when disabled)."""
        if self.sched.crossreq is None:
            return {}
        return self.sched.crossreq.report()

    # ------------------------------------------------------- fault tolerance
    def write_journal(self, path: str) -> None:
        """Request journal: enough to replay / resume after a crash."""
        rows = []
        for r in self.sched.done + self.sched.active + self.sched.pending:
            rows.append({
                "request_id": r.request_id,
                "graph": r.graph.name,
                "input": r.state.get("input"),
                "arrival_us": r.arrival_us,
                "finished": r.finished,
                "finish_us": r.finish_us,
                "events": [(t, e) for t, e, _ in r.events],
            })
        with open(path, "w") as f:
            json.dump(rows, f)

    @staticmethod
    def replay_unfinished(path: str) -> list[dict]:
        """Requests that must be re-admitted after restart."""
        with open(path) as f:
            rows = json.load(f)
        return [r for r in rows if not r["finished"]]
