"""Cross-request coordination layer: shared state between concurrent requests.

HedraRAG's §4.3 similarity machinery (LocalCache answers, O2/O3 cluster
reordering, triangle-bound early termination) is per-request, and its §4.4
skewness observation is exploited per-worker (device residency, dispatch
affinity).  This package lifts both to the *inter-request* scale the paper
measures but serves one request at a time:

===================  =====================================================
component            paper anchor
===================  =====================================================
``GlobalCache``      §4.3 O1-O3 across requests: a bounded, LRU +
(globalcache.py)     popularity-evicted semantic cache of completed
                     searches ``(query_vec, top-k', H_v, C_v)``.  Entries
                     duck-type ``LocalCache``, so the existing
                     ``answer_from_cache`` conclusive check and
                     ``reorder_clusters`` seeding apply unchanged — cold
                     requests inherit hot requests' history.
``FusionPass``       §4.3 similarity + §4.4 skew applied to the in-flight
(dedup.py)           query stream: near-identical retrieval sub-stages in
                     one wavefront fuse into a single executing group
                     (exact-duplicate byte-hash fast path; cosine
                     threshold for near-duplicates) whose merged top-k
                     rows fan out to every subscriber, so N lookalike
                     requests charge one segment scan instead of N.
``PopularityTracker``  §4.4 cluster skew as a *shared* signal: one global
``ReplicaMap``       decayed probe histogram superseding the per-worker
(popularity.py)      EMA, driving popularity-aware replication — hot
                     clusters become resident on multiple workers' device
                     slabs and the dispatcher routes to any replica
                     holder instead of serialising on a single owner.
===================  =====================================================

All features are off by default (``SchedulerConfig.global_cache_size=0``,
``dedup_threshold=0.0``, ``replication_factor=1``); disabled, the serving
loop is bit-identical to the uncoordinated path.
"""
from __future__ import annotations

from repro.crossreq.dedup import FusionPass, FusionStats
from repro.crossreq.globalcache import GlobalCache, GlobalCacheEntry, GlobalCacheStats
from repro.crossreq.popularity import PopularityTracker, ReplicaMap

__all__ = [
    "CrossRequestCoordinator",
    "FusionPass",
    "FusionStats",
    "GlobalCache",
    "GlobalCacheEntry",
    "GlobalCacheStats",
    "PopularityTracker",
    "ReplicaMap",
]


class CrossRequestCoordinator:
    """Facade owning the cross-request state for one scheduler instance.

    Built by ``WavefrontScheduler`` when any crossreq knob is enabled; the
    scheduler threads the tracker/replica map into the dispatcher and (when
    a hybrid engine is attached) into the hot-cluster cache.
    """

    def __init__(self, config, index, num_workers: int):
        self.global_cache = (
            GlobalCache(config.global_cache_size)
            if config.global_cache_size > 0 else None
        )
        self.fusion = (
            FusionPass(config.dedup_threshold)
            if config.dedup_threshold > 0.0 else None
        )
        self.tracker = PopularityTracker(index.n_clusters)
        self.replicas = (
            ReplicaMap(num_workers, config.replication_factor)
            if (config.replication_factor > 1 and num_workers > 1) else None
        )
        self._replicated_cache = None  # hybrid cache mirrored by the map

    def attach_cache(self, cache, num_workers: int, factor: int) -> None:
        """Extend an existing hot-cluster cache with replicated residency and
        point its refresh ranking at the shared tracker."""
        cache.replication = max(1, int(factor))
        cache.num_owners = max(1, int(num_workers))
        cache.shared_tracker = self.tracker
        self._replicated_cache = cache

    def tick(self) -> None:
        """Once per assembly cycle: decay the shared histogram and refresh
        the replica map from its source of truth (device residency when a
        replicated cache is attached, tracker ranking otherwise)."""
        self.tracker.tick()
        if self.replicas is None:
            return
        if self._replicated_cache is not None:
            self.replicas.refresh_from_cache(self._replicated_cache)
        else:
            self.replicas.refresh_from_tracker(self.tracker)

    def report(self) -> dict:
        out: dict = {"replicated_clusters": (
            self.replicas.n_replicated if self.replicas is not None else 0)}
        if self.global_cache is not None:
            out["global_cache"] = self.global_cache.report()
        if self.fusion is not None:
            out["dedup"] = self.fusion.report()
        return out
