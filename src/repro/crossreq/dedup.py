"""In-flight query dedup/fusion across a wavefront (paper §4.4's skewness
observation applied to the *query* stream).

At production concurrency, N near-identical retrieval stages from different
users routinely sit in the same wavefront.  Without coordination each one
charges its own segment scans.  The fusion pass clusters pending retrieval
sub-stages by query similarity and fuses lookalikes into one executing
group:

* the first request of a group (in SLO-slack order) is the **leader** — its
  sub-stages dispatch normally and carry ``fanout = 1 + n_subscribers`` so
  backends can account the charge once per fused group;
* **subscribers** are parked (never assembled); when the leader's stage
  completes, its merged top-k rows fan out to every subscriber and their
  stages complete at the same instant.

Two matching tiers:

* **exact** — identical query bytes + (k, nprobe): byte-hash fast path.
  The subscriber receives the leader's answer for *the same query*; under
  result-preserving settings (lossless early termination, cache answers
  off) that is bit-identical to executing the subscriber independently —
  verified in ``bench_crossreq`` and ``tests/test_crossreq.py``.  Under
  the default heuristic early termination, leader and independent
  execution are both approximations of the same reference search (their
  searched prefixes may differ), so the fused answer is one of those
  approximations, not a bitwise replay of the other;
* **near** — cosine similarity >= ``threshold`` within the same (k, nprobe)
  bucket: the subscriber is answered *from the leader's result* with the
  same tolerance semantics as an O1 cache answer (returned distances are to
  the leader's query; the error is bounded by the leader-subscriber query
  distance via the triangle inequality).  The subscriber's LocalCache
  records the leader's query vector with those distances, keeping the next
  round's ball bound sound.

A leader stays matchable while its stage is in flight, so duplicates
arriving a few cycles late still fuse instead of re-scanning.  Fusion runs
in the hedra sub-stage assembly path only — the coarse async/sequential
baselines model systems without cross-request coordination.

Matching is keyed on **stage-typed signatures** (core/stages.py FusionSig):
each registered StageSpec describes its own equivalence class — exact key
bytes, a parameter bucket, and an optional unit vector for near matching —
so rerank/rewrite/compress stages dedup through the identical machinery as
retrieval, and stage kinds never collide (the kind prefixes the key and
bucket).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.stages import FusionSig


@dataclasses.dataclass
class FusionStats:
    exact_subscribed: int = 0
    near_subscribed: int = 0
    leaders_registered: int = 0
    groups_fused: int = 0  # leader completions that had >= 1 subscriber
    fanout_total: int = 0


@dataclasses.dataclass
class _Leader:
    rid: int
    req: object
    key: bytes
    bucket: tuple  # ("<kind>", *stage params), e.g. ("retrieval", k, nprobe)
    unit_vec: Optional[np.ndarray]


def _retrieval_sig(req) -> FusionSig:
    """Default signature for a legacy retrieval stage (callers that pass no
    explicit sig — direct FusionPass use outside the scheduler)."""
    from repro.core import stages

    return stages.spec("retrieval").fusion_signature(None, req)


class FusionPass:
    """Clusters pending stage work by signature similarity and tracks
    leader -> subscriber groups while the leader's stage is in flight."""

    def __init__(self, threshold: float):
        if not 0.0 < threshold <= 1.0:
            raise ValueError("dedup threshold must be in (0, 1]")
        self.threshold = float(threshold)
        self.stats = FusionStats()
        self._leaders: dict[int, _Leader] = {}  # rid -> leader record
        self._by_key: dict[bytes, int] = {}  # exact stage key -> leader rid
        # bucket -> {rid: unit query vec}; near matches only compare within
        # a bucket so fused answers keep the subscriber's stage parameters
        self._buckets: dict[tuple, dict[int, np.ndarray]] = {}
        self._subs: dict[int, list[tuple[object, str]]] = {}

    @property
    def n_inflight_leaders(self) -> int:
        return len(self._leaders)

    # ---------------------------------------------------------------- matching
    def try_subscribe(self, req, sig: Optional[FusionSig] = None, *,
                      allow_near: bool) -> Optional[str]:
        """Attach ``req``'s fresh stage to an in-flight leader with the same
        signature.  Returns 'exact' / 'near', or None when no leader
        matches."""
        if sig is None:
            sig = _retrieval_sig(req)
        lead = self._by_key.get(sig.key)
        if lead is not None and lead != req.request_id:
            self._subs[lead].append((req, "exact"))
            self.stats.exact_subscribed += 1
            return "exact"
        if not allow_near or self.threshold >= 1.0 or sig.unit_vec is None:
            return None
        bucket = self._buckets.get(sig.bucket)
        if not bucket:
            return None
        q = np.asarray(sig.unit_vec, np.float64)
        rids = [r for r in bucket if r != req.request_id]
        if not rids:
            return None
        mat = np.stack([bucket[r] for r in rids])
        cos = mat @ q
        j = int(np.argmax(cos))
        if float(cos[j]) < self.threshold:
            return None
        self._subs[rids[j]].append((req, "near"))
        self.stats.near_subscribed += 1
        return "near"

    def register_leader(self, req, sig: Optional[FusionSig] = None) -> None:
        """Make ``req`` the executing leader for its signature; later
        lookalikes subscribe until the stage completes."""
        rid = req.request_id
        if rid in self._leaders:
            return
        if sig is None:
            sig = _retrieval_sig(req)
        self._leaders[rid] = _Leader(rid, req, sig.key, sig.bucket,
                                     sig.unit_vec)
        self._by_key.setdefault(sig.key, rid)
        if sig.unit_vec is not None:
            self._buckets.setdefault(sig.bucket, {})[rid] = sig.unit_vec
        self._subs.setdefault(rid, [])
        self.stats.leaders_registered += 1

    def fanout(self, rid: int) -> int:
        """1 + current subscriber count (1 when ``rid`` is not a leader)."""
        return 1 + len(self._subs.get(rid, ()))

    # -------------------------------------------------------------- completion
    def complete_leader(self, rid: int) -> list[tuple[object, str]]:
        """Leader's stage finished: drop the group and hand back the
        subscribers for fan-out.  No-op (empty list) for non-leaders."""
        lead = self._leaders.pop(rid, None)
        if lead is None:
            return []
        if self._by_key.get(lead.key) == rid:
            del self._by_key[lead.key]
        bucket = self._buckets.get(lead.bucket)
        if bucket is not None:
            bucket.pop(rid, None)
            if not bucket:
                del self._buckets[lead.bucket]
        subs = self._subs.pop(rid, [])
        if subs:
            self.stats.groups_fused += 1
            self.stats.fanout_total += len(subs)
        return subs

    # ------------------------------------------------------------------ stats
    def report(self) -> dict:
        s = self.stats
        return {
            "exact_subscribed": s.exact_subscribed,
            "near_subscribed": s.near_subscribed,
            "leaders_registered": s.leaders_registered,
            "groups_fused": s.groups_fused,
            "fanout_total": s.fanout_total,
            "inflight_leaders": self.n_inflight_leaders,
        }
