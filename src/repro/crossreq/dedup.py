"""In-flight query dedup/fusion across a wavefront (paper §4.4's skewness
observation applied to the *query* stream).

At production concurrency, N near-identical retrieval stages from different
users routinely sit in the same wavefront.  Without coordination each one
charges its own segment scans.  The fusion pass clusters pending retrieval
sub-stages by query similarity and fuses lookalikes into one executing
group:

* the first request of a group (in SLO-slack order) is the **leader** — its
  sub-stages dispatch normally and carry ``fanout = 1 + n_subscribers`` so
  backends can account the charge once per fused group;
* **subscribers** are parked (never assembled); when the leader's stage
  completes, its merged top-k rows fan out to every subscriber and their
  stages complete at the same instant.

Two matching tiers:

* **exact** — identical query bytes + (k, nprobe): byte-hash fast path.
  The subscriber receives the leader's answer for *the same query*; under
  result-preserving settings (lossless early termination, cache answers
  off) that is bit-identical to executing the subscriber independently —
  verified in ``bench_crossreq`` and ``tests/test_crossreq.py``.  Under
  the default heuristic early termination, leader and independent
  execution are both approximations of the same reference search (their
  searched prefixes may differ), so the fused answer is one of those
  approximations, not a bitwise replay of the other;
* **near** — cosine similarity >= ``threshold`` within the same (k, nprobe)
  bucket: the subscriber is answered *from the leader's result* with the
  same tolerance semantics as an O1 cache answer (returned distances are to
  the leader's query; the error is bounded by the leader-subscriber query
  distance via the triangle inequality).  The subscriber's LocalCache
  records the leader's query vector with those distances, keeping the next
  round's ball bound sound.

A leader stays matchable while its stage is in flight, so duplicates
arriving a few cycles late still fuse instead of re-scanning.  Fusion runs
in the hedra sub-stage assembly path only — the coarse async/sequential
baselines model systems without cross-request coordination.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class FusionStats:
    exact_subscribed: int = 0
    near_subscribed: int = 0
    leaders_registered: int = 0
    groups_fused: int = 0  # leader completions that had >= 1 subscriber
    fanout_total: int = 0


@dataclasses.dataclass
class _Leader:
    rid: int
    req: object
    key: bytes
    bucket: tuple[int, int]  # (k, nprobe)
    unit_vec: np.ndarray


class FusionPass:
    """Clusters pending retrieval sub-stages by query similarity and tracks
    leader -> subscriber groups while the leader's stage is in flight."""

    def __init__(self, threshold: float):
        if not 0.0 < threshold <= 1.0:
            raise ValueError("dedup threshold must be in (0, 1]")
        self.threshold = float(threshold)
        self.stats = FusionStats()
        self._leaders: dict[int, _Leader] = {}  # rid -> leader record
        self._by_key: dict[bytes, int] = {}  # exact query key -> leader rid
        # (k, nprobe) -> {rid: unit query vec}; near matches only compare
        # within a bucket so fused answers keep the subscriber's k/nprobe
        self._buckets: dict[tuple[int, int], dict[int, np.ndarray]] = {}
        self._subs: dict[int, list[tuple[object, str]]] = {}

    @property
    def n_inflight_leaders(self) -> int:
        return len(self._leaders)

    @staticmethod
    def _key(req) -> bytes:
        r = req.ret
        return (np.asarray(r.query_vec, np.float32).tobytes()
                + np.array([r.k, r.nprobe], np.int64).tobytes())

    # ---------------------------------------------------------------- matching
    def try_subscribe(self, req, *, allow_near: bool) -> Optional[str]:
        """Attach ``req``'s fresh retrieval stage to an in-flight leader.
        Returns 'exact' / 'near', or None when no leader matches."""
        key = self._key(req)
        lead = self._by_key.get(key)
        if lead is not None and lead != req.request_id:
            self._subs[lead].append((req, "exact"))
            self.stats.exact_subscribed += 1
            return "exact"
        if not allow_near or self.threshold >= 1.0:
            return None
        bucket = self._buckets.get((req.ret.k, req.ret.nprobe))
        if not bucket:
            return None
        q = np.asarray(req.ret.query_vec, np.float64)
        q = q / max(float(np.linalg.norm(q)), 1e-12)
        rids = [r for r in bucket if r != req.request_id]
        if not rids:
            return None
        mat = np.stack([bucket[r] for r in rids])
        cos = mat @ q
        j = int(np.argmax(cos))
        if float(cos[j]) < self.threshold:
            return None
        self._subs[rids[j]].append((req, "near"))
        self.stats.near_subscribed += 1
        return "near"

    def register_leader(self, req) -> None:
        """Make ``req`` the executing leader for its query; later lookalikes
        subscribe until the stage completes."""
        rid = req.request_id
        if rid in self._leaders:
            return
        key = self._key(req)
        q = np.asarray(req.ret.query_vec, np.float64)
        unit = q / max(float(np.linalg.norm(q)), 1e-12)
        bucket = (req.ret.k, req.ret.nprobe)
        self._leaders[rid] = _Leader(rid, req, key, bucket, unit)
        self._by_key.setdefault(key, rid)
        self._buckets.setdefault(bucket, {})[rid] = unit
        self._subs.setdefault(rid, [])
        self.stats.leaders_registered += 1

    def fanout(self, rid: int) -> int:
        """1 + current subscriber count (1 when ``rid`` is not a leader)."""
        return 1 + len(self._subs.get(rid, ()))

    # -------------------------------------------------------------- completion
    def complete_leader(self, rid: int) -> list[tuple[object, str]]:
        """Leader's stage finished: drop the group and hand back the
        subscribers for fan-out.  No-op (empty list) for non-leaders."""
        lead = self._leaders.pop(rid, None)
        if lead is None:
            return []
        if self._by_key.get(lead.key) == rid:
            del self._by_key[lead.key]
        bucket = self._buckets.get(lead.bucket)
        if bucket is not None:
            bucket.pop(rid, None)
            if not bucket:
                del self._buckets[lead.bucket]
        subs = self._subs.pop(rid, [])
        if subs:
            self.stats.groups_fused += 1
            self.stats.fanout_total += len(subs)
        return subs

    # ------------------------------------------------------------------ stats
    def report(self) -> dict:
        s = self.stats
        return {
            "exact_subscribed": s.exact_subscribed,
            "near_subscribed": s.near_subscribed,
            "leaders_registered": s.leaders_registered,
            "groups_fused": s.groups_fused,
            "fanout_total": s.fanout_total,
            "inflight_leaders": self.n_inflight_leaders,
        }
