"""Global semantic cache shared across requests (paper §4.3, lifted to §4.4's
inter-request scale).

``LocalCache`` exploits *intra*-request similarity: v' of one request is
answered or accelerated from the same request's previous v.  At production
concurrency the same locality holds *across* requests — near-identical
queries arrive from different users — so the GlobalCache keeps a bounded,
eviction-managed pool of completed searches
``(query_vec, top-k', home clusters H_v, probed clusters C_v)`` that any
request can consult at sub-stage assembly:

* **exact hit** (same query bytes, same nprobe): the entry's top-k is the
  answer — the conclusive-answer fast path;
* **near hit** within the O1 ball bound: answered through the existing
  ``answer_from_cache`` triangle-bound check (entries duck-type
  ``LocalCache``, so the per-request machinery applies unchanged);
* **seed hit**: on an inconclusive near miss the nearest entry's H_v/C_v
  seed O2/O3 cluster reordering, so a cold request inherits a hot request's
  search history and terminates earlier.

Eviction is LRU + popularity-weighted: the victim maximises
``age / (1 + hits)``, so briefly-idle hot entries outlive one-shot cold ones.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.similarity import answer_from_cache, doc_clusters
from repro.retrieval.ivf import TopK


def merge_unique(a: TopK, b: TopK, k: int) -> TopK:
    """Merge two top-k lists *of the same query* into one width-``k`` list
    with distinct doc ids (``TopK.merge`` alone would duplicate the shared
    seed prefix when accumulating wide rows across sub-stages)."""
    av, bv = a.ids >= 0, b.ids >= 0
    d = np.concatenate([a.dists[av], b.dists[bv]])
    i = np.concatenate([a.ids[av], b.ids[bv]])
    order = np.argsort(d, kind="stable")
    d, i = d[order], i[order]
    _, first = np.unique(i, return_index=True)
    keep = np.sort(first)[:k]
    out = TopK.empty(k)
    out.dists[: keep.size] = d[keep]
    out.ids[: keep.size] = i[keep]
    return out


@dataclasses.dataclass
class GlobalCacheEntry:
    """One completed search; field names duck-type ``LocalCache`` so
    ``answer_from_cache`` / ``reorder_clusters`` consume entries directly."""

    query_vec: np.ndarray
    dists: np.ndarray
    ids: np.ndarray
    home_clusters: set
    probed_clusters: set
    nprobe: int
    key: bytes
    hits: int = 0
    last_used: int = 0

    @property
    def empty(self) -> bool:
        return False


@dataclasses.dataclass
class GlobalCacheStats:
    lookups: int = 0
    exact_hits: int = 0
    near_answers: int = 0
    seed_hits: int = 0
    inserts: int = 0
    refreshes: int = 0
    evictions: int = 0


class GlobalCache:
    """Bounded cross-request semantic cache (see module docstring)."""

    def __init__(
        self,
        capacity: int,
        *,
        exact_eps: float = 1e-6,
        answer_delta_frac: float = 0.15,
        seed_delta_frac: float = 0.6,
    ):
        if capacity <= 0:
            raise ValueError("GlobalCache capacity must be positive")
        self.capacity = int(capacity)
        self.exact_eps = float(exact_eps)
        self.answer_delta_frac = float(answer_delta_frac)
        self.seed_delta_frac = float(seed_delta_frac)
        self.stats = GlobalCacheStats()
        self._entries: list[Optional[GlobalCacheEntry]] = [None] * self.capacity
        self._by_key: dict[bytes, int] = {}  # query-bytes key -> slot
        self._vecs: Optional[np.ndarray] = None  # (capacity, d) stacked
        self._valid = np.zeros(self.capacity, bool)
        self._tick = 0

    def __len__(self) -> int:
        return int(self._valid.sum())

    @staticmethod
    def _key(query_vec: np.ndarray, nprobe: int) -> bytes:
        return (np.asarray(query_vec, np.float32).tobytes()
                + np.array([nprobe], np.int64).tobytes())

    def _touch(self, slot: int) -> None:
        ent = self._entries[slot]
        ent.hits += 1
        ent.last_used = self._tick

    # ------------------------------------------------------------------ reads
    def nearest(self, query_vec: np.ndarray) -> Optional[tuple[GlobalCacheEntry, float]]:
        """Nearest entry by L2; returns (entry, distance) or None."""
        if self._vecs is None or not self._valid.any():
            return None
        q = np.asarray(query_vec, np.float32)
        idx = np.flatnonzero(self._valid)
        d = ((self._vecs[idx] - q[None, :]) ** 2).sum(axis=1)
        j = int(np.argmin(d))
        return self._entries[int(idx[j])], float(np.sqrt(max(d[j], 0.0)))

    def consult(
        self, query_vec: np.ndarray, k: int, nprobe: int, *,
        allow_answer: bool = True, allow_seed: bool = True,
    ) -> tuple[Optional[tuple[np.ndarray, np.ndarray]],
               Optional[GlobalCacheEntry]]:
        """One lookup, both outcomes: ``(answer, seed_entry)``.

        The conclusive-answer check (exact-key fast path, then the O1 ball
        bound against the nearest entry) and the H_v/C_v seed fall-back
        share a single O(capacity x d) nearest scan.  At most one of the
        two results is non-None.
        """
        if not allow_answer and not allow_seed:
            return None, None  # nothing can hit: skip the scan entirely
        self._tick += 1
        self.stats.lookups += 1
        q = np.asarray(query_vec, np.float32)
        if allow_answer:
            slot = self._by_key.get(self._key(q, nprobe))
            if slot is not None:
                ent = self._entries[slot]
                valid = ent.ids >= 0
                if int(valid.sum()) >= k:
                    self._touch(slot)
                    self.stats.exact_hits += 1
                    return ((ent.dists[valid][:k].copy(),
                             ent.ids[valid][:k].copy()), None)
        near = self.nearest(q)
        if near is None:
            return None, None
        ent, dvv = near
        if allow_answer:
            if dvv <= self.exact_eps and ent.nprobe == nprobe:
                valid = ent.ids >= 0
                if int(valid.sum()) >= k:
                    self._touch(self._by_key[ent.key])
                    self.stats.exact_hits += 1
                    return ((ent.dists[valid][:k].copy(),
                             ent.ids[valid][:k].copy()), None)
            # a shallower search's entry is not the true top-k' for this
            # request's probe depth; the ball bound would overstate recall
            if ent.nprobe >= nprobe:
                hit = answer_from_cache(
                    ent, q, k,
                    delta=self.answer_delta_frac * float(np.linalg.norm(q)))
                if hit is not None:
                    self._touch(self._by_key[ent.key])
                    self.stats.near_answers += 1
                    return (hit[0].copy(), hit[1].copy()), None
        if allow_seed and dvv <= self.seed_delta_frac * float(np.linalg.norm(q)):
            self._touch(self._by_key[ent.key])
            self.stats.seed_hits += 1
            return None, ent
        return None, None

    def answer(self, query_vec: np.ndarray, k: int, nprobe: int
               ) -> Optional[tuple[np.ndarray, np.ndarray]]:
        """Conclusive-answer check only; None -> fall through to search."""
        return self.consult(query_vec, k, nprobe, allow_seed=False)[0]

    def seed(self, query_vec: np.ndarray) -> Optional[GlobalCacheEntry]:
        """Nearest entry within the seed ball — its H_v/C_v feed O2/O3
        reordering for a request with no local history of its own."""
        return self.consult(query_vec, 1, 0, allow_answer=False)[1]

    # ----------------------------------------------------------------- writes
    def insert(self, query_vec: np.ndarray, topk: TopK, index,
               probed: list[int], nprobe: int) -> None:
        """Publish a completed search.  Same-key inserts refresh in place;
        otherwise the LRU/popularity victim is evicted."""
        self._tick += 1
        q = np.asarray(query_vec, np.float32)
        if self._vecs is None:
            self._vecs = np.zeros((self.capacity, q.shape[0]), np.float32)
        key = self._key(q, nprobe)
        valid_ids = topk.ids[topk.ids >= 0]
        home = set(int(c) for c in doc_clusters(index, valid_ids))
        slot = self._by_key.get(key)
        hits_keep = 0
        if slot is None:
            free = np.flatnonzero(~self._valid)
            if free.size:
                slot = int(free[0])
            else:
                slot = self._evict()
            self.stats.inserts += 1
        else:
            # refresh: keep popularity, replace payload
            self.stats.refreshes += 1
            hits_keep = self._entries[slot].hits
        ent = GlobalCacheEntry(
            query_vec=q.copy(),
            dists=topk.dists.copy(),
            ids=topk.ids.copy(),
            home_clusters=home,
            probed_clusters=set(int(c) for c in probed),
            nprobe=int(nprobe),
            key=key,
            hits=hits_keep,
            last_used=self._tick,
        )
        self._entries[slot] = ent
        self._by_key[key] = slot
        self._vecs[slot] = q
        self._valid[slot] = True

    def _evict(self) -> int:
        """Victim = max age / (1 + hits): plain LRU tempered by popularity."""
        best_slot, best_score = 0, -1.0
        for slot in np.flatnonzero(self._valid):
            ent = self._entries[int(slot)]
            score = (self._tick - ent.last_used) / (1.0 + ent.hits)
            if score > best_score:
                best_slot, best_score = int(slot), score
        victim = self._entries[best_slot]
        del self._by_key[victim.key]
        self._entries[best_slot] = None
        self._valid[best_slot] = False
        self.stats.evictions += 1
        return best_slot

    # ------------------------------------------------------------------ stats
    def report(self) -> dict:
        s = self.stats
        return {
            "size": len(self),
            "capacity": self.capacity,
            "lookups": s.lookups,
            "exact_hits": s.exact_hits,
            "near_answers": s.near_answers,
            "seed_hits": s.seed_hits,
            "inserts": s.inserts,
            "refreshes": s.refreshes,
            "evictions": s.evictions,
        }
