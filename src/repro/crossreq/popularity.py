"""Shared cluster-popularity tracking + popularity-aware replication.

The per-worker EMA histogram in ``serving/dispatch.py`` only sees the probes
*one worker* served, so at N workers each worker's picture of cluster
hotness is a 1/N sample and the affinity policy serialises every hot cluster
on whichever worker saw it first.  :class:`PopularityTracker` is the shared
source of truth that supersedes it: one globally decayed cluster-probe
histogram, recorded at dispatch time by the dispatcher and consulted by

* the dispatcher's replica-aware routing (via :class:`ReplicaMap`);
* the hot-cluster device cache's refresh ranking
  (``HotClusterCache(shared_tracker=...)``), so residency decisions see the
  whole pool's traffic instead of execution-order artifacts.

:class:`ReplicaMap` turns the histogram into *replica sets*: clusters above
the hotness cut become resident on (or routable to) ``replication_factor``
distinct workers, so concurrent sub-stages probing a hot cluster spread
across its replica holders instead of piling onto a single owner.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from repro.retrieval.hotcache import AccessTracker


class PopularityTracker(AccessTracker):
    """Global decayed cluster-probe histogram (one per serving pool).

    Recording happens at *dispatch* (`RetrievalDispatcher.note_dispatch`);
    decay ticks once per scheduler assembly cycle, owned by the scheduler —
    consumers (cache refresh, replica map) must never tick it themselves.
    """

    def __init__(self, n_clusters: int, decay: float = 0.98):
        super().__init__(n_clusters, decay=decay)

    def hot_clusters(self, n_hot: int) -> np.ndarray:
        """Top ``n_hot`` clusters by decayed probe count, hottest first,
        trimmed to those actually observed (freq > 0)."""
        top = self.top(max(int(n_hot), 0))
        return top[self.freq[top] > 0.0]


class ReplicaMap:
    """cid -> tuple of replica-holder worker ids, for hot clusters only.

    Refreshed either from the shared tracker (pure placement replication:
    rank-spread assignment) or from the device cache's actual replicated
    residency when a hybrid engine with ``replication > 1`` is attached.
    Clusters with fewer than two holders are *not* mapped — single-owner
    routing stays with the configured dispatch policy.
    """

    def __init__(self, num_workers: int, factor: int, *,
                 hot_fraction: float = 0.1):
        self.num_workers = max(1, int(num_workers))
        self.factor = max(1, int(factor))
        self.hot_fraction = float(hot_fraction)
        self._owners: dict[int, tuple[int, ...]] = {}

    @property
    def n_replicated(self) -> int:
        return len(self._owners)

    def owners(self, cid: int) -> Optional[tuple[int, ...]]:
        return self._owners.get(int(cid))

    def owners_for(self, clusters: Iterable[int]) -> set[int]:
        """Union of replica holders over the sub-stage's hot clusters."""
        out: set[int] = set()
        for c in clusters:
            o = self._owners.get(int(c))
            if o:
                out.update(o)
        return out

    def covering_holders(self, clusters: Iterable[int]) -> set[int]:
        """Workers holding a replica of *every* cluster in the part — the
        failover candidates that can serve an orphaned shard part whole.
        Empty whenever any cluster is unreplicated (the dead owner was its
        only copy)."""
        common: Optional[set[int]] = None
        for c in clusters:
            o = self._owners.get(int(c))
            cover = set(o) if o else set()
            common = cover if common is None else (common & cover)
            if not common:
                return set()
        return common or set()

    # ---------------------------------------------------------------- refresh
    def refresh_from_tracker(self, tracker: PopularityTracker) -> None:
        """Rank-spread assignment: the i-th hottest cluster is owned by
        workers ``{(i + j) % num_workers}`` — deterministic, and adjacent
        hot clusters land on disjoint primaries."""
        if self.factor < 2 or self.num_workers < 2:
            self._owners = {}
            return
        n_hot = max(1, int(self.hot_fraction * tracker.freq.shape[0]))
        rf = min(self.factor, self.num_workers)
        self._owners = {
            int(cid): tuple(sorted((rank + j) % self.num_workers
                                   for j in range(rf)))
            for rank, cid in enumerate(tracker.hot_clusters(n_hot))
        }

    def refresh_from_cache(self, cache) -> None:
        """Mirror the device cache's replicated residency: a cluster with
        visible copies on several workers' slabs is routable to any of them.
        Owner derivation and transit visibility live in the cache's
        ``replica_owners`` accessor — this is a pure mirror."""
        owners: dict[int, tuple[int, ...]] = {}
        for cid in cache.replica_slots():
            held = tuple(cache.replica_owners(cid))
            if len(held) > 1:
                owners[int(cid)] = held
        self._owners = owners
