import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, record memory analysis, cost analysis, and collective
schedule — the inputs to EXPERIMENTS.md §Dry-run / §Roofline.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first init, and only the dry-run wants 512 host devices.

Usage (one cell per process; scripts/run_dryrun_all.py fans out):

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen3-1.7b --shape train_4k --mesh both \
        --out results/dryrun/qwen3-1.7b.train_4k.json

Cost methodology: XLA's cost_analysis counts while-loop bodies once, so the
scanned full-depth module under-reports FLOPs by ~n_layers.  Each cell is
therefore compiled twice more in *unrolled depth-1 / depth-2* variants per
unique segment type; per-layer slopes are extrapolated to full depth:

    cost_full = cost(depth-1 base) + sum_seg (repeat_seg - 1) * slope(type(seg))

The full scanned compile still provides memory analysis (exact: stacked
params + caches are real buffers) and proves the sharding compiles.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo as hloa
from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config, shape_applicable
from repro.distributed.sharding import (
    batch_spec,
    decode_state_shardings,
    dp_axes,
    dp_size,
    param_shardings,
    to_named,
)
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh


def _shardings_for(cfg, shape, mesh, ispec, layout: str = "tp"):
    """(in_shardings, out_shardings, donate) matching make_step_fn's signature."""
    psh = param_shardings(cfg, mesh, ispec["params"], layout)
    if shape.kind == "train":
        osh = {
            "mu": psh, "nu": psh,
            "step": NamedSharding(mesh, P()),
        }
        bsh = to_named(mesh, batch_spec(cfg, mesh, shape, layout))
        return (psh, osh, bsh), (NamedSharding(mesh, P()), psh, osh), (0, 1)
    if shape.kind == "prefill":
        bsh = to_named(mesh, batch_spec(cfg, mesh, shape, layout))
        bsh = {k: v for k, v in bsh.items() if k in ispec["batch"]}
        st_shape = jax.eval_shape(
            S.make_step_fn(cfg, shape), ispec["params"], ispec["batch"]
        )[1]
        ssh = decode_state_shardings(cfg, mesh, shape.global_batch, st_shape, layout)
        tok_sh = NamedSharding(mesh, P(
            dp_axes(mesh, layout)
            if shape.global_batch % dp_size(mesh, layout) == 0 else None))
        return (psh, bsh), (tok_sh, ssh), ()
    # decode
    ssh = decode_state_shardings(cfg, mesh, shape.global_batch, ispec["state"], layout)
    bax = dp_axes(mesh, layout) if shape.global_batch % dp_size(mesh, layout) == 0 else None
    tok_sh = NamedSharding(mesh, P(bax))
    return (psh, tok_sh, ssh), (tok_sh, ssh), (2,)


def _lower_compile(cfg, shape, mesh, microbatch: int = 0, layout: str = "tp"):
    ispec = S.input_specs(cfg, shape)
    step = S.make_step_fn(cfg, shape, microbatch=microbatch)
    in_sh, out_sh, donate = _shardings_for(cfg, shape, mesh, ispec, layout)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    if shape.kind == "train":
        args = (ispec["params"], ispec["opt_state"], ispec["batch"])
    elif shape.kind == "prefill":
        args = (ispec["params"], ispec["batch"])
    else:
        args = (ispec["params"], ispec["tokens"], ispec["state"])
    from repro.distributed.act_sharding import use_mesh

    with mesh, use_mesh(mesh, layout):
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             microbatch: int = 0, skip_cost: bool = False,
             overrides: dict | None = None, layout: str = "tp") -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "applicable": ok, "reason": reason,
        "microbatch": microbatch, "overrides": overrides or {}, "layout": layout,
    }
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    rec["chips"] = chips

    # ---- full scanned compile: memory + sharding proof -------------------
    t0 = time.time()
    lowered, compiled = _lower_compile(cfg, shape, mesh, microbatch=microbatch,
                                       layout=layout)
    rec["compile_s"] = time.time() - t0
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes_est": ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
    }
    scan_costs = hloa.extract_costs(compiled)
    rec["scan_level_costs"] = {
        "flops_per_device": scan_costs.flops_per_device,
        "bytes_per_device": scan_costs.bytes_per_device,
        "collective_bytes": scan_costs.collectives.total_bytes,
        "collective_counts": scan_costs.collectives.count_by_op,
    }
    del lowered, compiled

    if mesh_kind == "multi" or skip_cost:
        return rec  # multi-pod pass only proves the pod axis shards

    # ---- depth-extrapolated exact costs -----------------------------------
    # cost variants run microbatch=0: gradient accumulation is arithmetic-
    # identical (tests/test_training.py::test_microbatch_equivalence), so
    # FLOPs/bytes/collective totals match while compiles stay small
    microbatch = 0
    base_cfg = S.depth_variant(cfg, None, shape)
    _, c_base = _lower_compile(base_cfg, shape, mesh, microbatch=microbatch,
                               layout=layout)
    costs = hloa.extract_costs(c_base)
    base = costs
    del c_base
    rec["cost_variants"] = {"base_layers": base_cfg.n_layers + base_cfg.n_encoder_layers}
    for t in S.unique_segment_types(cfg):
        bumped = S.depth_variant(cfg, t, shape)
        _, c_b = _lower_compile(bumped, shape, mesh, microbatch=microbatch,
                                layout=layout)
        slope = hloa.extract_costs(c_b).scaled_sub(base)
        # negative slopes are compile noise (fusion differences between the
        # 1- and 2-layer variants); per-layer cost cannot be negative
        slope = hloa.CompiledCosts(
            max(slope.flops_per_device, 0.0),
            max(slope.bytes_per_device, 0.0),
            hloa.CollectiveStats(
                {k: max(v, 0) for k, v in slope.collectives.bytes_by_op.items()},
                {k: max(v, 0) for k, v in slope.collectives.count_by_op.items()},
                max(slope.collectives.f32_bytes, 0.0),
            ),
        )
        del c_b
        n_extra = S.layer_multiplier(cfg, t) - S.layer_multiplier(base_cfg, t)
        costs = costs.plus_scaled(slope, n_extra)
        rec["cost_variants"][str(t)] = {
            "slope_flops": slope.flops_per_device,
            "slope_bytes": slope.bytes_per_device,
            "slope_coll_bytes": slope.collectives.total_bytes,
            "extra_layers": n_extra,
        }

    from repro.analysis.memory_model import analytic_hbm_bytes

    rec["roofline"] = hloa.roofline_terms(costs, chips)
    tp = mesh.shape.get("model", 1) if layout in ("tp", "serve_tp") else 1
    mem = analytic_hbm_bytes(cfg, shape, chips, tp=tp)
    rec["roofline"]["analytic_hbm_bytes"] = mem
    rec["roofline"]["t_memory_s"] = mem["total"] / hloa.HBM_BW
    rec["roofline"]["t_memory_xla_upper_s"] = costs.bytes_per_device / hloa.HBM_BW
    # recompute dominant with the analytic memory term
    terms = {"compute": rec["roofline"]["t_compute_s"],
             "memory": rec["roofline"]["t_memory_s"],
             "collective": rec["roofline"]["t_collective_s"]}
    rec["roofline"]["dominant"] = max(terms, key=terms.get)
    rec["model"] = hloa.model_flops(cfg, shape, chips)
    mfpd = rec["model"]["model_flops_per_device"]
    rec["roofline"]["useful_flops_ratio"] = (
        mfpd / costs.flops_per_device if costs.flops_per_device else 0.0
    )
    rec["roofline"]["roofline_frac_of_dominant"] = None  # filled by report
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", required=True, choices=list(SHAPES_BY_NAME))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--layout", default="tp", choices=["tp", "serve_tp", "dp_only"])
    ap.add_argument("--overrides", type=str, default="",
                    help="JSON dict of ModelConfig overrides (perf experiments)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    overrides = json.loads(args.overrides) if args.overrides else None
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out = []
    for mk in meshes:
        try:
            rec = run_cell(args.arch, args.shape, mk, microbatch=args.microbatch,
                           skip_cost=args.skip_cost, overrides=overrides,
                           layout=args.layout)
        except Exception as e:  # noqa: BLE001
            rec = {"arch": args.arch, "shape": args.shape, "mesh": mk,
                   "error": repr(e), "traceback": traceback.format_exc()}
        out.append(rec)
        print(json.dumps({k: v for k, v in rec.items() if k != "traceback"},
                         indent=2, default=str))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2, default=str)


if __name__ == "__main__":
    main()
