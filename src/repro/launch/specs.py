"""ShapeDtypeStruct stand-ins + step functions for every (arch x shape) cell.

Nothing here allocates: params come from ``jax.eval_shape(init_params)``,
decode states from ``jax.eval_shape(init_decode_state)``, batches are pure
ShapeDtypeStructs.  The dry-run lowers/compiles against these.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, Segment, ShapeConfig
from repro.models import lm
from repro.training.optimizer import init_opt_state
from repro.training.train_step import make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------


def params_spec(cfg: ModelConfig):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def opt_spec(cfg: ModelConfig):
    # init_opt_state only reads .shape/.dtype, so it composes with eval_shape
    return jax.eval_shape(lambda: init_opt_state(params_spec(cfg)))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }
    if cfg.n_prefix_embeds:
        out["prefix_embeds"] = sds((B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return out


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = batch_specs(cfg, shape)
    out = {"tokens": b["tokens"]}
    for k in ("prefix_embeds", "enc_embeds"):
        if k in b:
            out[k] = b[k]
    return out


def decode_state_spec(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: lm.init_decode_state(cfg, B, S, filled=S - 1)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """The full stand-in set for one cell (what the dry-run lowers against)."""
    if shape.kind == "train":
        return {
            "params": params_spec(cfg),
            "opt_state": opt_spec(cfg),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "prefill":
        return {
            "params": params_spec(cfg),
            "batch": prefill_input_specs(cfg, shape),
        }
    # decode
    return {
        "params": params_spec(cfg),
        "tokens": sds((shape.global_batch,), jnp.int32),
        "state": decode_state_spec(cfg, shape),
    }


# ---------------------------------------------------------------------------
# Step functions (what gets jitted per shape kind)
# ---------------------------------------------------------------------------


def make_step_fn(cfg: ModelConfig, shape: ShapeConfig, microbatch: int = 0) -> Callable:
    if shape.kind == "train":
        ts = make_train_step(cfg, microbatch=microbatch)

        def train_step(params, opt_state, batch):
            loss, params, opt_state, stats = ts(params, opt_state, batch)
            return loss, params, opt_state

        return train_step

    if shape.kind == "prefill":
        cache_len = shape.seq_len + cfg.n_prefix_embeds  # prefix shares cache

        def prefill_step(params, batch):
            logits, state = lm.prefill(
                params, cfg, batch["tokens"], max_len=cache_len,
                prefix_embeds=batch.get("prefix_embeds"),
                enc_embeds=batch.get("enc_embeds"),
            )
            return jnp.argmax(logits, -1).astype(jnp.int32), state

        return prefill_step

    def serve_step(params, tokens, state):
        logits, state = lm.decode_step(params, cfg, tokens, state)
        return jnp.argmax(logits, -1).astype(jnp.int32), state

    return serve_step


# ---------------------------------------------------------------------------
# Depth variants for cost extrapolation (see launch/dryrun.py)
# ---------------------------------------------------------------------------


def _seg_type(seg: Segment) -> tuple:
    return (seg.mixer, seg.ffn, seg.cross_attn)


def unique_segment_types(cfg: ModelConfig) -> list[tuple]:
    seen, out = set(), []
    for seg in tuple(cfg.segments) + tuple(cfg.encoder_segments):
        t = _seg_type(seg)
        if t not in seen:
            seen.add(t)
            out.append(t)
    return out


def depth_variant(cfg: ModelConfig, bump: tuple | None, shape: ShapeConfig) -> ModelConfig:
    """All segments at repeat=1 (bumped type at repeat=2), scans unrolled,
    loss un-chunked — the configuration whose HLO FLOPs are exact."""

    def rep(seg: Segment) -> Segment:
        r = 2 if (bump is not None and _seg_type(seg) == bump) else 1
        return dataclasses.replace(seg, repeat=r)

    segs = tuple(rep(s) for s in cfg.segments)
    enc = tuple(rep(s) for s in cfg.encoder_segments)
    return dataclasses.replace(
        cfg,
        segments=segs,
        n_layers=sum(s.repeat for s in segs),
        encoder_segments=enc,
        n_encoder_layers=sum(s.repeat for s in enc),
        scan_layers=False,
        unroll_scans=True,
        loss_chunk=shape.seq_len,
    )


def layer_multiplier(cfg: ModelConfig, t: tuple) -> int:
    """How many layers of segment-type t the full model has."""
    n = 0
    for seg in tuple(cfg.segments) + tuple(cfg.encoder_segments):
        if _seg_type(seg) == t:
            n += seg.repeat
    return n
