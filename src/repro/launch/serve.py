"""Production serving launcher: sharded decode over a mesh + HedraRAG
scheduler.  On this container it runs reduced configs on the host mesh; the
production path is exercised compile-only via launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.backends import RealBackend
from repro.models import lm
from repro.retrieval import (
    CorpusConfig,
    HybridRetrievalEngine,
    IVFIndex,
    SyntheticEmbedder,
    make_corpus,
)
from repro.server import Server
from repro.serving.engine import GenerationEngine
from repro import workflows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(ARCH_IDS))
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--workflow", default="one-shot",
                    choices=list(workflows.WORKFLOWS))
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--ret-workers", type=int, default=1,
                    help="size of the retrieval worker pool")
    ap.add_argument("--dispatch", default="affinity",
                    choices=["affinity", "least_loaded", "round_robin"],
                    help="retrieval sub-stage placement policy")
    ap.add_argument("--index-sharding", action="store_true",
                    help="distributed IVF retrieval: each worker owns a "
                         "contiguous cluster-range shard; sub-stages "
                         "scatter-gather across the pool")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="inject a seeded random FaultPlan (crashes/stalls/"
                         "transients) and serve through the recovery path")
    ap.add_argument("--fault-crash-frac", type=float, default=0.25,
                    help="fraction of the pool crashed by the fault plan")
    ap.add_argument("--fault-transient-prob", type=float, default=0.05,
                    help="per-dispatch transient failure probability")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="record spans and write a Chrome trace-event / "
                         "Perfetto JSON timeline here (implies tracing=True)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="sample the labeled metrics registry and write the "
                         "JSON snapshot (with an embedded Prometheus text "
                         "exposition) here (implies telemetry=True)")
    args = ap.parse_args()

    docs, _, topics = make_corpus(CorpusConfig(n_docs=8000, dim=48, n_topics=64))
    index = IVFIndex.build(docs, n_clusters=32, iters=4)
    embedder = SyntheticEmbedder(topics)
    hybrid = HybridRetrievalEngine(index, cache_capacity=8, kernel_impl="ref")

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = GenerationEngine(cfg, params, max_batch=8, max_len=160, eos_id=0)
    backend = RealBackend(engine, index, embedder, hybrid=hybrid)

    pending = [f"query {i}" for i in range(args.n_requests)]
    orig = backend.gen_duration

    def gen_duration(n_prefill_tokens, batch, n_steps):
        while engine.can_admit() and pending:
            p = pending.pop(0)
            toks = (np.frombuffer(p.encode(), np.uint8).astype(np.int32)
                    % (cfg.vocab_size - 2)) + 1
            engine.add_sequence(toks, max_new=args.max_new)
        return orig(n_prefill_tokens, batch, n_steps)

    backend.gen_duration = gen_duration
    fault_plan = None
    if args.fault_seed is not None:
        from repro.serving.faults import FaultPlan

        horizon = args.n_requests * 20_000.0 + 400_000.0
        fault_plan = FaultPlan.random(
            args.fault_seed, args.ret_workers, horizon,
            crash_frac=args.fault_crash_frac,
            transient_prob=args.fault_transient_prob)
        print(f"fault plan: {fault_plan.describe()}")
    server = Server(index, embedder, mode="hedra", backend=backend, nprobe=8,
                    num_ret_workers=args.ret_workers,
                    dispatch_policy=args.dispatch,
                    index_sharding=args.index_sharding,
                    fault_plan=fault_plan,
                    tracing=args.trace_out is not None,
                    telemetry=args.metrics_out is not None)
    for i in range(args.n_requests):
        server.add_request(f"query {i}", workflows.build(args.workflow),
                           arrival_us=i * 20_000.0)
    t0 = time.perf_counter()
    m = server.run()
    print(f"served {m.finished} requests in {time.perf_counter()-t0:.2f}s wall")
    for k, v in m.summary().items():
        print(f"  {k:24s} {v}")
    if args.trace_out:
        server.export_trace(args.trace_out)
        print(f"trace written to {args.trace_out} "
              "(open in https://ui.perfetto.dev or chrome://tracing)")
    if args.metrics_out:
        server.metrics_snapshot(args.metrics_out)
        print(f"metrics snapshot written to {args.metrics_out}")


if __name__ == "__main__":
    main()
