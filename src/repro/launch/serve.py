"""Production serving launcher: sharded decode over a mesh + HedraRAG
scheduler.  On this container it runs reduced configs on the host mesh; the
production path is exercised compile-only via launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.backends import RealBackend
from repro.models import lm
from repro.retrieval import (
    CorpusConfig,
    HybridRetrievalEngine,
    IVFIndex,
    SyntheticEmbedder,
    make_corpus,
)
from repro.server import Server
from repro.serving.engine import GenerationEngine
from repro import workflows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(ARCH_IDS))
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--workflow", default="one-shot",
                    choices=list(workflows.WORKFLOWS))
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--ret-workers", type=int, default=1,
                    help="size of the retrieval worker pool")
    ap.add_argument("--dispatch", default="affinity",
                    choices=["affinity", "least_loaded", "round_robin"],
                    help="retrieval sub-stage placement policy")
    ap.add_argument("--index-sharding", action="store_true",
                    help="distributed IVF retrieval: each worker owns a "
                         "contiguous cluster-range shard; sub-stages "
                         "scatter-gather across the pool")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="inject a seeded random FaultPlan (crashes/stalls/"
                         "transients) and serve through the recovery path")
    ap.add_argument("--fault-crash-frac", type=float, default=0.25,
                    help="fraction of the pool crashed by the fault plan")
    ap.add_argument("--fault-transient-prob", type=float, default=0.05,
                    help="per-dispatch transient failure probability")
    ap.add_argument("--wallclock", action="store_true",
                    help="serve through the threaded wall-clock ingress "
                         "(serving/ingress.py) instead of the batch path; "
                         "arrivals are real producer-thread timestamps")
    ap.add_argument("--speedup", type=float, default=200.0,
                    help="wall->virtual clock compression for --wallclock "
                         "(1 wall ms = speedup virtual ms)")
    ap.add_argument("--closed-loop", type=int, default=0, metavar="CLIENTS",
                    help="with --wallclock: closed-loop load generation with "
                         "this many client threads (submit, wait, think, "
                         "repeat) instead of an open-loop stream")
    ap.add_argument("--replay-check", action="store_true",
                    help="with --wallclock: record the measured backend "
                         "charges on a DurationTape alongside the arrival "
                         "trace, replay both on a fresh server stack over "
                         "the pure virtual clock, and assert bit-identical "
                         "per-request event fingerprints (the determinism "
                         "oracle, extended to the measured RealBackend)")
    ap.add_argument("--arrivals-out", metavar="PATH", default=None,
                    help="with --wallclock: write the recorded "
                         "arrival/heartbeat trace JSON here")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="record spans and write a Chrome trace-event / "
                         "Perfetto JSON timeline here (implies tracing=True)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="sample the labeled metrics registry and write the "
                         "JSON snapshot (with an embedded Prometheus text "
                         "exposition) here (implies telemetry=True)")
    args = ap.parse_args()

    docs, _, topics = make_corpus(CorpusConfig(n_docs=8000, dim=48, n_topics=64))
    cfg = get_config(args.arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    fault_plan = None
    if args.fault_seed is not None:
        from repro.serving.faults import FaultPlan

        horizon = args.n_requests * 20_000.0 + 400_000.0
        fault_plan = FaultPlan.random(
            args.fault_seed, args.ret_workers, horizon,
            crash_frac=args.fault_crash_frac,
            transient_prob=args.fault_transient_prob)
        print(f"fault plan: {fault_plan.describe()}")

    def build_server() -> Server:
        # rebuilt from scratch for each serving pass (the replay oracle
        # needs a fresh, bit-identical stack: engine KV state and the
        # hybrid cache are mutated by a run)
        index = IVFIndex.build(docs, n_clusters=32, iters=4)
        embedder = SyntheticEmbedder(topics)
        hybrid = HybridRetrievalEngine(index, cache_capacity=8,
                                       kernel_impl="ref")
        engine = GenerationEngine(cfg, params, max_batch=8, max_len=160,
                                  eos_id=0)
        backend = RealBackend(engine, index, embedder, hybrid=hybrid)
        pending = [f"query {i}" for i in range(args.n_requests)]
        orig = backend.gen_duration

        def gen_duration(n_prefill_tokens, batch, n_steps):
            while engine.can_admit() and pending:
                p = pending.pop(0)
                toks = (np.frombuffer(p.encode(), np.uint8).astype(np.int32)
                        % (cfg.vocab_size - 2)) + 1
                engine.add_sequence(toks, max_new=args.max_new)
            return orig(n_prefill_tokens, batch, n_steps)

        backend.gen_duration = gen_duration
        return Server(index, embedder, mode="hedra", backend=backend,
                      nprobe=8,
                      num_ret_workers=args.ret_workers,
                      dispatch_policy=args.dispatch,
                      index_sharding=args.index_sharding,
                      fault_plan=fault_plan,
                      external_heartbeats=args.wallclock,
                      fault_tolerance=args.wallclock,
                      tracing=args.trace_out is not None,
                      telemetry=args.metrics_out is not None)

    server = build_server()
    t0 = time.perf_counter()
    if args.wallclock:
        from repro.serving import ingress
        from repro.serving.workload import ClosedLoopSpec, MixSpec

        tape = None
        if args.replay_check:
            # RealBackend charges *measured* durations (the sanctioned
            # wall-clock boundary in core/backends.py), so the arrival
            # trace alone cannot reproduce its timeline — record the
            # charges too and replay them verbatim into the replica
            tape = ingress.DurationTape()
            ingress.tape_backend(server.backend, tape, mode="record")
        if args.closed_loop > 0:
            spec = ClosedLoopSpec(
                name=args.workflow,
                weights={args.workflow: 1.0},
                num_clients=args.closed_loop,
                requests_per_client=max(
                    1, args.n_requests // args.closed_loop))
            m, trace = server.serve_wallclock(closed_loop=spec,
                                              speedup=args.speedup)
        else:
            mix = MixSpec(args.workflow, weights={args.workflow: 1.0})
            stream = mix.sample(args.n_requests, rate_per_s=50.0)
            m, trace = server.serve_wallclock(stream, speedup=args.speedup)
        print(f"ingress trace: {len(trace.rows)} rows")
        if args.arrivals_out:
            trace.save(args.arrivals_out)
            print(f"arrival trace written to {args.arrivals_out}")
        if args.replay_check:
            replica = build_server()
            ingress.tape_backend(replica.backend, tape, mode="replay")
            ingress.replay_trace(replica, trace)
            if replica.fingerprints() != server.fingerprints():
                raise SystemExit("replay-check FAILED: virtual-clock replay "
                                 "diverged from the wall-clock run")
            print(f"replay-check ok: virtual-clock replay is bit-identical "
                  f"({len(tape.rows)} taped backend charges, "
                  f"{tape.remaining()} unconsumed)")
    else:
        for i in range(args.n_requests):
            server.add_request(f"query {i}", workflows.build(args.workflow),
                               arrival_us=i * 20_000.0)
        m = server.run()
    print(f"served {m.finished} requests in {time.perf_counter()-t0:.2f}s wall")
    for k, v in m.summary().items():
        print(f"  {k:24s} {v}")
    if args.trace_out:
        server.export_trace(args.trace_out)
        print(f"trace written to {args.trace_out} "
              "(open in https://ui.perfetto.dev or chrome://tracing)")
    if args.metrics_out:
        server.metrics_snapshot(args.metrics_out)
        print(f"metrics snapshot written to {args.metrics_out}")


if __name__ == "__main__":
    main()
