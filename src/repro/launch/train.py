"""Production training launcher: mesh + sharded train_step + elastic loop.

On real TPU hardware this runs under `python -m repro.launch.train --arch X`;
on this CPU container it runs with the host mesh (1 device) for any reduced
config, or use launch/dryrun.py for the 512-device compile-only path.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config
from repro.configs.base import ShapeConfig
from repro.distributed.act_sharding import use_mesh
from repro.distributed.elastic import ElasticConfig, ElasticRunner
from repro.distributed.sharding import batch_spec, param_shardings, to_named
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.training.data import SyntheticTokenStream
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES_BY_NAME))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    if args.reduced:
        cfg = cfg.reduced()
        shape = ShapeConfig(shape.name, 128, 8, shape.kind)

    mesh = (make_production_mesh() if args.production_mesh else make_host_mesh())
    ecfg = ElasticConfig(ckpt_dir=args.ckpt_dir, save_every=args.save_every)

    def build_step(mesh):
        step = make_train_step(cfg, OptConfig(total_steps=args.steps),
                               microbatch=args.microbatch)
        pshape = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
        psh = param_shardings(cfg, mesh, pshape)
        osh = {"mu": psh, "nu": psh, "step": NamedSharding(mesh, P())}
        bsh = to_named(mesh, batch_spec(cfg, mesh, shape))
        return jax.jit(step, in_shardings=(psh, osh, bsh),
                       out_shardings=(NamedSharding(mesh, P()), psh, osh, None),
                       donate_argnums=(0, 1))

    def init_fn(mesh):
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": init_opt_state(params)}

    runner = ElasticRunner(ecfg, lambda: mesh, build_step)
    mesh, step_fn, state, start = runner.resume_or_init(init_fn, lambda m, l: None)
    ds = SyntheticTokenStream(cfg, shape)
    params, opt = state["params"], state["opt"]

    dts = []
    with mesh, use_mesh(mesh):
        for step in range(start, args.steps):
            batch = {k: np.asarray(v) for k, v in ds.batch_at(step).items()}
            t0 = time.time()
            loss, params, opt, stats = step_fn(params, opt, batch)
            loss = float(loss)
            dt = time.time() - t0
            dts.append(dt)
            if runner.observe_step_time(dt, float(np.median(dts))):
                print("straggler streak detected -> re-mesh would trigger here")
            runner.maybe_save(step, {"params": params, "opt": opt})
            if step % 10 == 0:
                print(f"step {step} loss {loss:.4f} dt {dt*1e3:.0f}ms")
    print("training loop done")


if __name__ == "__main__":
    main()
