"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis is pure
    data parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """All pure-DP axes of a mesh (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
