"""The paper's five evaluation workflows as RAGraphs (§6.1), plus the
stage-registry workflows built from the polymorphic stage kinds.

Paper five:

  one-shot   retrieve -> generate
  multistep  decompose -> [retrieve -> answer] x subquestions (conditional loop)
  irg        [generate -> retrieve] x N iterative retrieval-generation
  hyde       hypothesis-generate -> retrieve(with hypothesis) -> answer
  recomp     retrieve -> compress(as generation) -> answer

Registry workflows (core/stages.py kinds as first-class graph stages):

  rerank      retrieve wide -> cross-encoder rerank -> generate
  multiquery  rewrite (N query variants, k-way merged) -> generate
  hybrid      dense+lexical retrieval (rrf fusion) -> generate
  compress    retrieve wide -> extractive compress -> generate
  pipeline    rewrite -> rerank -> compress -> generate (all four host kinds)

The conditional loops terminate through per-request state counters, which is
how the paper's Listing 1 lambda edges resolve at runtime.  ``max_rounds``
caps iteration; the workload profile decides the actual per-request rounds
(written into state at admission by the Server).
"""
from __future__ import annotations

from repro.core.ragraph import END, START, RAGraph


def one_shot(topk: int = 5) -> RAGraph:
    g = RAGraph("one-shot")
    g.add_retrieval(0, query="input", output="docs", topk=topk)
    g.add_generation(1, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, END)
    return g


def hyde(topk: int = 5) -> RAGraph:
    g = RAGraph("hyde")
    g.add_generation(0, prompt="Generate a hypothesis for {input}.",
                     output="hypopara", max_tokens=128)
    g.add_retrieval(1, query="hypopara", output="docs", topk=topk)
    g.add_generation(2, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, END)
    return g


def recomp(topk: int = 8) -> RAGraph:
    g = RAGraph("recomp")
    g.add_retrieval(0, query="input", output="docs", topk=topk)
    g.add_generation(1, prompt="Compress {docs} for {input}.",
                     output="summary", max_tokens=96)
    g.add_generation(2, prompt="Answer {input} using {summary}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, END)
    return g


def multistep(topk: int = 2) -> RAGraph:
    """Decompose into subquestions, answer each with its own retrieval."""
    g = RAGraph("multistep")
    g.add_generation(0, prompt="Decompose {input} into subquestions.",
                     output="subquestion", max_tokens=96)
    g.add_retrieval(1, query="subquestion", output="docs", topk=topk)
    g.add_generation(2, prompt="Answer {subquestion} using {docs}.",
                     output="subanswer")

    def loop(state: dict):
        state["_round"] = state.get("_round", 0) + 1
        return 1 if state["_round"] < state.get("_target_rounds", 2) else END

    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, loop)
    return g


def irg(topk: int = 5) -> RAGraph:
    """Iterative retrieval-generation synergy (IRG / ITER-RETGEN)."""
    g = RAGraph("irg")
    g.add_generation(0, prompt="Draft an answer for {input}.",
                     output="draft", max_tokens=128)
    g.add_retrieval(1, query="draft", output="docs", topk=topk)
    g.add_generation(2, prompt="Refine {draft} for {input} using {docs}.",
                     output="draft")

    def loop(state: dict):
        state["_round"] = state.get("_round", 0) + 1
        return 1 if state["_round"] < state.get("_target_rounds", 2) else END

    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, loop)
    return g


def rerank(topk: int = 24, keep: int = 5) -> RAGraph:
    """Retrieve a wide candidate set, cross-encoder rerank, answer."""
    g = RAGraph("rerank")
    g.add_retrieval(0, query="input", output="cands", topk=topk)
    g.add_rerank(1, docs="cands", output="docs", keep=keep)
    g.add_generation(2, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, END)
    return g


def multiquery(n_queries: int = 3, topk: int = 5) -> RAGraph:
    """Multi-query expansion: N variant searches, k-way merged."""
    g = RAGraph("multiquery")
    g.add_rewrite(0, query="input", output="docs", n_queries=n_queries,
                  topk=topk)
    g.add_generation(1, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, END)
    return g


def hybrid(topk: int = 8, lexical_weight: float = 0.5) -> RAGraph:
    """Dense+lexical hybrid retrieval with reciprocal-rank fusion."""
    g = RAGraph("hybrid")
    g.add_retrieval(0, query="input", output="docs", topk=topk,
                    lexical_weight=lexical_weight)
    g.add_generation(1, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, END)
    return g


def compress(topk: int = 16, ratio: float = 0.5) -> RAGraph:
    """Retrieve wide, extractively compress the context, answer."""
    g = RAGraph("compress")
    g.add_retrieval(0, query="input", output="cands", topk=topk)
    g.add_compress(1, docs="cands", output="docs", ratio=ratio)
    g.add_generation(2, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, END)
    return g


def pipeline(n_queries: int = 3, topk: int = 12, keep: int = 8,
             ratio: float = 0.5) -> RAGraph:
    """Every host stage kind in one chain: rewrite -> rerank -> compress ->
    generate (the stress workflow for the heterogeneous mix)."""
    g = RAGraph("pipeline")
    g.add_rewrite(0, query="input", output="cands", n_queries=n_queries,
                  topk=topk)
    g.add_rerank(1, docs="cands", output="picked", keep=keep)
    g.add_compress(2, docs="picked", output="docs", ratio=ratio)
    g.add_generation(3, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, 3)
    g.add_edge(3, END)
    return g


WORKFLOWS = {
    "one-shot": one_shot,
    "hyde": hyde,
    "recomp": recomp,
    "multistep": multistep,
    "irg": irg,
    "rerank": rerank,
    "multiquery": multiquery,
    "hybrid": hybrid,
    "compress": compress,
    "pipeline": pipeline,
}


def build(name: str, **kw) -> RAGraph:
    g = WORKFLOWS[name](**kw)
    g.validate()
    return g
