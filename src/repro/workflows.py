"""The paper's five evaluation workflows as RAGraphs (§6.1).

  one-shot   retrieve -> generate
  multistep  decompose -> [retrieve -> answer] x subquestions (conditional loop)
  irg        [generate -> retrieve] x N iterative retrieval-generation
  hyde       hypothesis-generate -> retrieve(with hypothesis) -> answer
  recomp     retrieve -> compress -> answer (post-retrieval stage)

The conditional loops terminate through per-request state counters, which is
how the paper's Listing 1 lambda edges resolve at runtime.  ``max_rounds``
caps iteration; the workload profile decides the actual per-request rounds
(written into state at admission by the Server).
"""
from __future__ import annotations

from repro.core.ragraph import END, START, RAGraph


def one_shot(topk: int = 5) -> RAGraph:
    g = RAGraph("one-shot")
    g.add_retrieval(0, query="input", output="docs", topk=topk)
    g.add_generation(1, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, END)
    return g


def hyde(topk: int = 5) -> RAGraph:
    g = RAGraph("hyde")
    g.add_generation(0, prompt="Generate a hypothesis for {input}.",
                     output="hypopara", max_tokens=128)
    g.add_retrieval(1, query="hypopara", output="docs", topk=topk)
    g.add_generation(2, prompt="Answer {input} using {docs}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, END)
    return g


def recomp(topk: int = 8) -> RAGraph:
    g = RAGraph("recomp")
    g.add_retrieval(0, query="input", output="docs", topk=topk)
    g.add_generation(1, prompt="Compress {docs} for {input}.",
                     output="summary", max_tokens=96)
    g.add_generation(2, prompt="Answer {input} using {summary}.", output="answer")
    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, END)
    return g


def multistep(topk: int = 2) -> RAGraph:
    """Decompose into subquestions, answer each with its own retrieval."""
    g = RAGraph("multistep")
    g.add_generation(0, prompt="Decompose {input} into subquestions.",
                     output="subquestion", max_tokens=96)
    g.add_retrieval(1, query="subquestion", output="docs", topk=topk)
    g.add_generation(2, prompt="Answer {subquestion} using {docs}.",
                     output="subanswer")

    def loop(state: dict):
        state["_round"] = state.get("_round", 0) + 1
        return 1 if state["_round"] < state.get("_target_rounds", 2) else END

    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, loop)
    return g


def irg(topk: int = 5) -> RAGraph:
    """Iterative retrieval-generation synergy (IRG / ITER-RETGEN)."""
    g = RAGraph("irg")
    g.add_generation(0, prompt="Draft an answer for {input}.",
                     output="draft", max_tokens=128)
    g.add_retrieval(1, query="draft", output="docs", topk=topk)
    g.add_generation(2, prompt="Refine {draft} for {input} using {docs}.",
                     output="draft")

    def loop(state: dict):
        state["_round"] = state.get("_round", 0) + 1
        return 1 if state["_round"] < state.get("_target_rounds", 2) else END

    g.add_edge(START, 0)
    g.add_edge(0, 1)
    g.add_edge(1, 2)
    g.add_edge(2, loop)
    return g


WORKFLOWS = {
    "one-shot": one_shot,
    "hyde": hyde,
    "recomp": recomp,
    "multistep": multistep,
    "irg": irg,
}


def build(name: str, **kw) -> RAGraph:
    g = WORKFLOWS[name](**kw)
    g.validate()
    return g
