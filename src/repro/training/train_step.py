"""The jitted training step (loss -> grads -> AdamW), microbatch-capable."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.training.optimizer import OptConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[OptConfig] = None,
                    microbatch: int = 0):
    """Returns train_step(params, opt_state, batch) -> (loss, params, opt).

    ``microbatch`` > 0 splits the batch into that many sequential gradient
    accumulation slices (scan) — activation memory drops by the same factor
    while keeping arithmetic identical.
    """
    opt_cfg = opt_cfg or OptConfig()

    def loss_fn(params, batch):
        return lm.train_loss(params, cfg, batch)

    def grads_of(params, batch):
        if not microbatch or microbatch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def slice_batch(b, i):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // microbatch), x.shape[0] // microbatch, 0
                ),
                b,
            )

        def body(carry, i):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, slice_batch(batch, i))
            acc_g = jax.tree.map(jnp.add, acc_g, g)
            return (acc_loss + l, acc_g), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        init = (jnp.zeros((), jnp.float32), zero_g)
        if cfg.unroll_scans:
            # cost-analysis variants: while-loop bodies are counted once by
            # XLA, so the accumulation loop must be unrolled (see dryrun.py)
            carry = init
            for i in range(microbatch):
                carry, _ = body(carry, jnp.int32(i))
            tot, g = carry
        else:
            (tot, g), _ = jax.lax.scan(body, init, jnp.arange(microbatch))
        inv = 1.0 / microbatch
        return tot * inv, jax.tree.map(lambda x: x * inv, g)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        params, opt_state, stats = adamw_update(opt_cfg, params, grads, opt_state)
        return loss, params, opt_state, stats

    return train_step
