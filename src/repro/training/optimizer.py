"""AdamW with global-norm clipping and warmup-cosine schedule (pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, f32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: OptConfig, step) -> jax.Array:
    step = step.astype(f32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(f32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: OptConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(f32)
    bc2 = 1.0 - b2 ** step.astype(f32)

    def upd(p, g, mu, nu):
        g = g.astype(f32) * scale
        mu2 = b1 * mu + (1 - b1) * g
        nu2 = b2 * nu + (1 - b2) * g * g
        mhat = mu2 / bc1
        nhat = nu2 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(f32)
        return (p.astype(f32) - lr * delta).astype(p.dtype), mu2, nu2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    new = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [x[0] for x in new])
    new_mu = jax.tree.unflatten(tdef, [x[1] for x in new])
    new_nu = jax.tree.unflatten(tdef, [x[2] for x in new])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
