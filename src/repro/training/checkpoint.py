"""Sharding-aware checkpoint/restore (fault tolerance substrate).

Format: <dir>/step_<N>/
    manifest.msgpack   — tree structure, shapes, dtypes, step, extra metadata
    shard_<i>.npz      — array payloads (chunked ~512 MB per file)

Writes are atomic (tmp dir + rename), so a crash mid-save never corrupts the
latest checkpoint; ``latest_step`` scans completed saves only.  Restore
accepts a target sharding tree and ``device_put``s each leaf accordingly, so
a checkpoint written on one mesh restores onto another (elastic re-mesh).

Multi-host: each process saves only the addressable shards of its leaves
(``process_index`` infix) and restore re-assembles via
``jax.make_array_from_single_device_arrays`` — the single-process path below
is the degenerate case of the same layout.
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Optional

import jax
import msgpack
import numpy as np

_CHUNK_BYTES = 512 << 20


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves]
    return keys, [leaf for _, leaf in leaves], treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    keys, leaves, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    shard_idx, shard_bytes, shard_payload = 0, 0, {}

    def flush():
        nonlocal shard_idx, shard_bytes, shard_payload
        if shard_payload:
            np.savez(os.path.join(tmp, f"shard_{shard_idx}.npz"), **shard_payload)
            shard_idx += 1
            shard_bytes, shard_payload = 0, {}

    for i, (k, leaf) in enumerate(zip(keys, leaves)):
        arr = np.asarray(leaf)
        name = f"a{i}"
        manifest["leaves"].append(
            {"key": k, "shard": shard_idx, "name": name,
             "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
        shard_payload[name] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _CHUNK_BYTES:
            flush()
    flush()
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.msgpack")):
                out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       like: Any = None, shardings: Any = None):
    """Returns (step, tree, extra).  ``like`` provides the treedef; without
    it a nested dict keyed by path is returned.  ``shardings`` (same treedef)
    places each leaf on its target devices."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    shards: dict[int, Any] = {}

    def load(entry):
        si = entry["shard"]
        if si not in shards:
            shards[si] = np.load(os.path.join(d, f"shard_{si}.npz"))
        return shards[si][entry["name"]]

    by_key = {e["key"]: load(e) for e in manifest["leaves"]}
    if like is None:
        return step, by_key, manifest["extra"]
    keys, leaves, treedef = _flatten(like)
    vals = [by_key[k] for k in keys]
    if shardings is not None:
        shard_leaves = jax.tree.leaves(shardings)
        vals = [jax.device_put(v, s) for v, s in zip(vals, shard_leaves)]
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    return step, tree, manifest["extra"]
