"""Deterministic synthetic token pipeline (train substrate).

A seeded, stateless-per-step stream: batch(step) is a pure function of
(seed, step), so restarts resume exactly from the checkpointed step — the
data-side half of fault tolerance.  Host sharding: each process materialises
only its slice (process_index-strided) and forms the global array with
``jax.make_array_from_process_local_data`` when running multi-host.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.3  # token distribution skew (realistic unigram stats)


class SyntheticTokenStream:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig | None = None):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg if data_cfg is not None else DataConfig()

    def batch_at(self, step: int, local_batch: int | None = None,
                 batch_offset: int = 0) -> dict:
        B = local_batch or self.shape.global_batch
        S = self.shape.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.data_cfg.seed, step, batch_offset])
        )
        # zipf-ish tokens clipped to vocab
        toks = rng.zipf(self.data_cfg.zipf_a, size=(B, S + 1)).astype(np.int64)
        toks = np.minimum(toks - 1, self.cfg.vocab_size - 1).astype(np.int32)
        batch = {
            "tokens": toks[:, :S],
            "labels": toks[:, 1 : S + 1],
        }
        if self.cfg.n_prefix_embeds:
            batch["prefix_embeds"] = rng.standard_normal(
                (B, self.cfg.n_prefix_embeds, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        if self.cfg.is_encoder_decoder:
            batch["enc_embeds"] = rng.standard_normal(
                (B, self.cfg.encoder_seq, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        return batch
