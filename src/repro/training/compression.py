"""Gradient compression for the cross-pod (DCN) reduction axis.

At 2+ pods the gradient all-reduce crosses DCN (~25 GB/s), an order of
magnitude slower than ICI.  The hierarchical scheme here:

  1. intra-pod reduction runs in bf16 over ICI (XLA default — cheap);
  2. the *inter-pod* hop quantizes to int8 blocks (per-block absmax scale),
     exchanges int8 + f32 scales via all_gather over the ``pod`` axis, and
     sums after dequantisation — 2× DCN byte reduction vs a bf16 all-reduce
     at pod count 2 (all-gather transfers n·(1 byte) vs all-reduce's
     ~2·(2 bytes) per element; favourable while n_pods ≤ 4);
  3. quantisation error is fed back into the next step's gradient (error
     feedback), which restores convergence to the uncompressed trajectory up
     to higher-order terms (Karimireddy et al., 2019).

Used by wrapping the gradient tree between loss.backward and the optimizer:
    comp = PodGradCompressor(block=256)
    grads, ef_state = comp.compress_reduce(grads, ef_state, axis="pod")
On a single-axis mesh (no "pod") it degrades to a no-op psum.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

f32 = jnp.float32


def _quantize_blocks(x: jax.Array, block: int) -> tuple[jax.Array, jax.Array, int]:
    flat = x.astype(f32).reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequantize_blocks(q: jax.Array, scale: jax.Array, pad: int, shape) -> jax.Array:
    flat = (q.astype(f32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum_leaf(x: jax.Array, axis: str, block: int = 256) -> jax.Array:
    """int8 all-gather-sum over ``axis`` (call inside shard_map)."""
    q, scale, pad = _quantize_blocks(x, block)
    q_all = jax.lax.all_gather(q, axis)        # (n, blocks, block) int8
    s_all = jax.lax.all_gather(scale, axis)    # (n, blocks) f32
    deq = q_all.astype(f32) * s_all[..., None]
    total = deq.sum(axis=0).reshape(-1)
    if pad:
        total = total[:-pad]
    return total.reshape(x.shape).astype(x.dtype)


def quantization_residual(x: jax.Array, block: int = 256) -> jax.Array:
    """x - dequant(quant(x)): the error-feedback term."""
    q, scale, pad = _quantize_blocks(x, block)
    return (x.astype(f32) - _dequantize_blocks(q, scale, pad, x.shape)).astype(x.dtype)


def block_saliency(x, block: int = 256):
    """Per-row information-density proxy reusing the per-block absmax scale
    rule of ``_quantize_blocks``: mean over each row's blocks of the absmax
    scale a quantizer would assign.  Rows whose feature blocks carry larger
    dynamic range compress worse — i.e. hold more information — which is
    what the extractive-compression serving stage (core/stages.py
    CompressSpec) ranks candidates by.  Pure numpy on purpose: it runs on
    the serving host path, not under jit."""
    import numpy as np

    v = np.asarray(x, np.float32)
    v = v.reshape(1, -1) if v.ndim == 1 else v
    n, d = v.shape
    pad = (-d) % block
    if pad:
        v = np.concatenate([v, np.zeros((n, pad), np.float32)], axis=1)
    blocks = v.reshape(n, -1, block)
    scale = np.maximum(np.abs(blocks).max(axis=2), 1e-12) / 127.0
    return scale.mean(axis=1)


class ErrorFeedback:
    """Residual accumulator: grads_in + residual -> compress -> new residual."""

    @staticmethod
    def init(grads) -> Any:
        return jax.tree.map(lambda g: jnp.zeros_like(g, f32), grads)

    @staticmethod
    def apply(grads, ef_state, block: int = 256):
        """Returns (grads_to_send, new_ef_state) — pure, jit-safe."""
        def one(g, e):
            corrected = g.astype(f32) + e
            resid = quantization_residual(corrected, block)
            return (corrected - resid).astype(g.dtype), resid

        pairs = jax.tree.map(one, grads, ef_state)
        send = jax.tree.map(lambda p: p[0], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        ef = jax.tree.map(lambda p: p[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
        return send, ef


def dcn_bytes_saved(n_params: int, n_pods: int = 2) -> dict:
    """Napkin report: bf16 all-reduce vs int8 all-gather over the pod axis."""
    ar = 2 * 2 * n_params * (n_pods - 1) / n_pods  # ring AR, bf16
    ag = (1 + 4 / 256) * n_params * (n_pods - 1)   # int8 + scales, AG
    return {"bf16_allreduce_bytes": ar, "int8_allgather_bytes": ag,
            "saving": ar / ag}
