"""IVF (inverted-file) vector index with step-wise, cluster-granular search.

Two execution paths mirror the paper's hybrid engine:

* **host path** — numpy/BLAS search over the flat cluster-sorted store
  (stands in for multi-threaded Faiss on the CPU of a TPU host);
* **device path** — clusters packed into fixed 128-aligned tiles
  (``cluster_tensor``) consumed by the fused distance+top-k Pallas kernel
  (``repro.kernels.ivf_scan``) or its jnp reference.

Beyond plain search, the index exposes the primitives HedraRAG's scheduler
needs (paper §4.2/§4.3/§5):

* ``probe_order``           — nprobe nearest centroids per query;
* ``search_cluster_batch``  — variable-length (query x cluster) work items;
* ``TopK.merge``            — running-result merge across sub-stages;
* triangle-inequality lower bounds (centroid distance - cluster radius) for
  lossless early termination under similarity-aware cluster reordering.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Running top-k
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TopK:
    """Running top-k (smallest L2^2 distances) for one query."""

    k: int
    dists: np.ndarray  # (k,) float32, +inf padded
    ids: np.ndarray  # (k,) int64, -1 padded

    @classmethod
    def empty(cls, k: int) -> "TopK":
        return cls(k, np.full((k,), np.inf, np.float32), np.full((k,), -1, np.int64))

    def merge(self, dists: np.ndarray, ids: np.ndarray) -> "TopK":
        d = np.concatenate([self.dists, dists.astype(np.float32)])
        i = np.concatenate([self.ids, ids.astype(np.int64)])
        if d.shape[0] > self.k:
            sel = np.argpartition(d, self.k - 1)[: self.k]
            sel = sel[np.argsort(d[sel], kind="stable")]
        else:
            sel = np.argsort(d, kind="stable")
        return TopK(self.k, d[sel], i[sel])

    @property
    def kth(self) -> float:
        return float(self.dists[-1])

    def valid(self) -> np.ndarray:
        return self.ids >= 0


# ---------------------------------------------------------------------------
# Index
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IVFIndex:
    centroids: np.ndarray  # (K, d) f32
    flat: np.ndarray  # (N, d) f32, sorted by cluster
    flat_norms: np.ndarray  # (N,) precomputed ||v||^2
    ids: np.ndarray  # (N,) original doc id per row
    offsets: np.ndarray  # (K+1,) int64 cluster row ranges
    radii: np.ndarray  # (K,) max member distance to centroid (for pruning)
    _row_of_doc: Optional[np.ndarray] = None  # lazy doc-id -> flat-row inverse

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        n_clusters: int,
        *,
        seed: int = 0,
        iters: int = 10,
    ) -> "IVFIndex":
        import jax

        from repro.retrieval.kmeans import kmeans

        v = np.asarray(vectors, np.float32)
        cent, asn = kmeans(
            jax.random.PRNGKey(seed), v, n_clusters, iters=iters
        )
        cent = np.asarray(cent, np.float32)
        asn = np.asarray(asn)
        order = np.argsort(asn, kind="stable")
        flat = v[order]
        ids = order.astype(np.int64)
        counts = np.bincount(asn, minlength=n_clusters)
        offsets = np.zeros(n_clusters + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        # cluster radii (for triangle-inequality early termination)
        diffs = flat - cent[asn[order]]
        member_d = np.linalg.norm(diffs, axis=1)
        radii = np.zeros(n_clusters, np.float32)
        np.maximum.at(radii, asn[order], member_d.astype(np.float32))
        return cls(
            centroids=cent,
            flat=flat,
            flat_norms=(flat**2).sum(-1).astype(np.float32),
            ids=ids,
            offsets=offsets,
            radii=radii,
        )

    # ------------------------------------------------------------- properties
    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    def cluster_size(self, cid: int) -> int:
        return int(self.offsets[cid + 1] - self.offsets[cid])

    def cluster_sizes(self) -> np.ndarray:
        return (self.offsets[1:] - self.offsets[:-1]).astype(np.int64)

    def _doc_rows(self, doc_ids) -> np.ndarray:
        if self._row_of_doc is None:
            inv = np.empty(self.ids.shape[0], np.int64)
            inv[self.ids] = np.arange(self.ids.shape[0])
            object.__setattr__(self, "_row_of_doc", inv)
        return self._row_of_doc[np.asarray(doc_ids, np.int64)]

    def doc_cluster(self, doc_ids: np.ndarray) -> np.ndarray:
        """Map original doc ids -> owning cluster ids."""
        rows = self._doc_rows(doc_ids)
        return (np.searchsorted(self.offsets, rows, side="right") - 1).astype(np.int64)

    def doc_vectors(self, doc_ids) -> np.ndarray:
        """Gather stored vectors by original doc id (rerank/compress stage
        scoring operates on retrieved candidates, not cluster layout)."""
        return self.flat[self._doc_rows(doc_ids)]

    # ----------------------------------------------------------------- search
    def centroid_dists(self, q: np.ndarray) -> np.ndarray:
        """q: (d,) or (Q, d) -> squared L2 to each centroid (Q, K)."""
        q2 = np.atleast_2d(q).astype(np.float32)
        c = self.centroids
        return (
            (q2**2).sum(-1, keepdims=True)
            - 2.0 * q2 @ c.T
            + (c**2).sum(-1)[None, :]
        )

    def probe_order(self, q: np.ndarray, nprobe: int) -> np.ndarray:
        """nprobe nearest cluster ids, ascending centroid distance. (Q, nprobe)."""
        d = self.centroid_dists(q)
        npb = min(nprobe, self.n_clusters)
        part = np.argpartition(d, npb - 1, axis=1)[:, :npb]
        row = np.take_along_axis(d, part, axis=1)
        srt = np.argsort(row, axis=1, kind="stable")
        return np.take_along_axis(part, srt, axis=1)

    def cluster_lower_bound(self, q: np.ndarray, cids: np.ndarray) -> np.ndarray:
        """Lossless lower bound on squared distance to any member of cids."""
        cd = np.sqrt(np.maximum(self.centroid_dists(q)[0][cids], 0.0))
        lb = np.maximum(cd - self.radii[cids], 0.0)
        return lb**2

    def search_cluster(
        self, q: np.ndarray, cid: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """Exhaustive scan of one cluster.  q: (Q, d).  -> (dists, ids) (Q, m)."""
        lo, hi = int(self.offsets[cid]), int(self.offsets[cid + 1])
        block = self.flat[lo:hi]
        q2 = np.atleast_2d(q).astype(np.float32)
        d = (
            (q2**2).sum(-1, keepdims=True)
            - 2.0 * q2 @ block.T
            + self.flat_norms[lo:hi][None, :]
        )
        return d, np.broadcast_to(self.ids[lo:hi][None, :], d.shape)

    def search_cluster_batch(
        self, work: Sequence[tuple[np.ndarray, int, TopK]]
    ) -> list[TopK]:
        """Variable-length (query, cluster, running-topk) work items (§5).

        Groups items by cluster so each cluster block is streamed once and
        shared across all queries probing it — the cross-request batching the
        paper's extended-Faiss interface provides.
        """
        by_cluster: dict[int, list[int]] = {}
        for i, (_, cid, _) in enumerate(work):
            by_cluster.setdefault(cid, []).append(i)
        out: list[Optional[TopK]] = [None] * len(work)
        for cid, idxs in by_cluster.items():
            qs = np.stack([work[i][0] for i in idxs])
            d, ids = self.search_cluster(qs, cid)
            for row, i in enumerate(idxs):
                tk = work[i][2]
                out[i] = tk.merge(d[row], ids[row])
        return out  # type: ignore[return-value]

    # ------------------------------------------------- plan (SoA) execution
    def scan_segments(self, plan, seg_indices, out) -> None:
        """Scan the given plan segments on the host path.

        Per segment the cluster block is GEMM-scanned once against the
        stacked queries of every item probing it (minimal FLOPs — no padded
        columns).  The per-item top-k reduction is then done in *size
        buckets*: segments of similar cluster size share one padded
        candidate matrix and a single argpartition/sort, so the number of
        numpy reductions scales with the size spread, not the segment count.

        ``out`` is the plan's item-level :class:`BatchTopK` scoreboard; each
        item belongs to exactly one segment, so its row is written once
        (the rows passed here must still be in their empty state).
        """
        k = out.k
        segs = np.asarray(seg_indices, np.int64)
        if segs.size == 0:
            return
        # offsets / bucket layout vectorized over the selected segments;
        # query rows are gathered per bucket (only the selected segments'
        # items, not the whole plan)
        los = self.offsets[plan.seg_cluster[segs]]
        his = self.offsets[plan.seg_cluster[segs] + 1]
        ms = (his - los).astype(np.int64)
        sa = plan.seg_bounds[segs]
        se = plan.seg_bounds[segs + 1]
        nqs = (se - sa).astype(np.int64)
        keep = (ms > 0) & (nqs > 0)
        if not keep.all():
            los, his, ms, sa, se, nqs = (
                a[keep] for a in (los, his, ms, sa, se, nqs))
        if ms.size == 0:
            return
        # bucket key: geometric size class (1.35x steps) — finer than pow2
        # so the padded candidate width stays close to the true cluster
        # size (the top-k partition cost is linear in padded width)
        keys = np.ceil(np.log(ms) / np.log(1.35)).astype(np.int64)
        for key in np.unique(keys):
            pick = np.flatnonzero(keys == key)
            width = max(int(ms[pick].max()), k)  # tight, not the pow2 key
            n = int(nqs[pick].sum())
            cand = np.full((n, width), np.inf, np.float32)
            lo_row = np.repeat(los[pick], nqs[pick])
            m_row = np.repeat(ms[pick], nqs[pick])
            # item rows of the bucket = concat of the picked seg_order runs
            nq_pick = nqs[pick]
            flat_pos = (np.repeat(sa[pick], nq_pick) + np.arange(n)
                        - np.repeat(np.cumsum(nq_pick) - nq_pick, nq_pick))
            rows_all = plan.seg_order[flat_pos]
            q_bucket = plan.queries[rows_all]
            qn_bucket = plan.q_norms[rows_all]
            at = 0
            for i in pick:
                lo, hi, m, nr = los[i], his[i], int(ms[i]), int(nqs[i])
                # ||q||^2 - 2 q.x + ||x||^2, GEMM-ed straight into the
                # bucket matrix (bit-identical to search_cluster)
                d = cand[at: at + nr, :m]
                np.matmul(q_bucket[at: at + nr], self.flat[lo:hi].T, out=d)
                d *= -2.0
                d += qn_bucket[at: at + nr, None]
                d += self.flat_norms[lo:hi][None, :]
                at += nr
            if width > k:
                sel = np.argpartition(cand, k - 1, axis=1)[:, :k]
                cand = np.take_along_axis(cand, sel, axis=1)
            else:
                sel = np.broadcast_to(np.arange(width), cand.shape)
            order = np.argsort(cand, axis=1, kind="stable")
            sel = np.take_along_axis(sel, order, axis=1)
            # doc ids straight from the flat store (pad columns -> -1),
            # instead of materialising a full (n, width) id matrix
            valid = sel < m_row[:, None]
            flat_rows = np.minimum(lo_row[:, None] + sel, self.ids.shape[0] - 1)
            out.dists[rows_all] = np.take_along_axis(cand, order, axis=1)
            out.ids[rows_all] = np.where(valid, self.ids[flat_rows], -1)

    def search_plan(self, plan, out=None):
        """Execute a whole :class:`~repro.retrieval.plan.RetrievalPlan` on
        the host path.  Returns the item-level ``BatchTopK`` scoreboard
        (callers fold it per group via ``plan.finalize``)."""
        from repro.retrieval.plan import BatchTopK

        if out is None:
            out = BatchTopK.empty(plan.n_items, plan.k)
        self.scan_segments(plan, np.arange(plan.n_segments), out)
        return out

    def search(
        self, q: np.ndarray, nprobe: int, k: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """Full reference search.  q: (Q, d) -> (dists (Q, k), ids (Q, k))."""
        q2 = np.atleast_2d(q)
        probes = self.probe_order(q2, nprobe)
        D = np.zeros((q2.shape[0], k), np.float32)
        I = np.zeros((q2.shape[0], k), np.int64)
        for r in range(q2.shape[0]):
            tk = TopK.empty(k)
            for cid in probes[r]:
                d, ids = self.search_cluster(q2[r : r + 1], int(cid))
                tk = tk.merge(d[0], ids[0])
            D[r], I[r] = tk.dists, tk.ids
        return D, I

    # ----------------------------------------------- device (tile) packing
    def cluster_tensor(
        self, cids: Sequence[int], pad_to: int = 128
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Pack clusters into fixed tiles for the TPU path.

        Returns (slab (n, L, d) f32 zero-padded, valid (n,) int32,
        slab_ids (n, L) int64 with -1 padding), where L = max size rounded up
        to ``pad_to`` (MXU lane alignment).
        """
        sizes = [self.cluster_size(int(c)) for c in cids]
        L = max(pad_to, -(-max(sizes + [1]) // pad_to) * pad_to)
        n = len(cids)
        slab = np.zeros((n, L, self.dim), np.float32)
        slab_ids = np.full((n, L), -1, np.int64)
        valid = np.zeros((n,), np.int32)
        for j, cid in enumerate(cids):
            lo, hi = int(self.offsets[cid]), int(self.offsets[cid + 1])
            m = hi - lo
            slab[j, :m] = self.flat[lo:hi]
            slab_ids[j, :m] = self.ids[lo:hi]
            valid[j] = m
        return slab, valid, slab_ids


# ---------------------------------------------------------------------------
# Cost model (used by the discrete-event executor; calibrated at runtime)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterCostModel:
    """t(cluster) = fixed + per_vector * size (+ per_query amortised).

    ``calibrate`` measures real host search times and fits the linear model —
    the same measured distribution drives Fig. 6(b)-style variation.
    """

    fixed_us: float = 20.0
    per_vector_us: float = 0.05
    per_query_us: float = 2.0

    def cost_us(self, size: int, n_queries: int = 1) -> float:
        return self.fixed_us + self.per_vector_us * size + self.per_query_us * n_queries

    def cost_vec_us(self, sizes: np.ndarray, n_queries: np.ndarray) -> np.ndarray:
        """Per-cluster cost over a segment table: element-wise
        ``fixed + per_vector * size + per_query * n_queries``."""
        sizes = np.asarray(sizes, np.float64)
        nq = np.asarray(n_queries, np.float64)
        return self.fixed_us + self.per_vector_us * sizes + self.per_query_us * nq

    def batch_cost_us(self, sizes: np.ndarray, n_queries: int = 1) -> float:
        """Vectorized sum of cost_us over many clusters (one query each)."""
        sizes = np.asarray(sizes, np.float64)
        return float(
            sizes.size * (self.fixed_us + self.per_query_us * n_queries)
            + self.per_vector_us * sizes.sum()
        )

    @classmethod
    def calibrate(cls, index: IVFIndex, n_samples: int = 32, seed: int = 0) -> "ClusterCostModel":
        import time

        rng = np.random.default_rng(seed)
        sizes, times = [], []
        cids = rng.choice(index.n_clusters, size=min(n_samples, index.n_clusters), replace=False)
        q = rng.standard_normal((1, index.dim)).astype(np.float32)
        for cid in cids:
            t0 = time.perf_counter()
            index.search_cluster(q, int(cid))
            dt = (time.perf_counter() - t0) * 1e6
            sizes.append(index.cluster_size(int(cid)))
            times.append(dt)
        sizes_a = np.array(sizes, np.float64)
        times_a = np.array(times, np.float64)
        if len(sizes) >= 2 and sizes_a.std() > 0:
            slope, intercept = np.polyfit(sizes_a, times_a, 1)
            slope = max(slope, 1e-4)
            intercept = max(intercept, 1.0)
        else:
            slope, intercept = 0.05, 20.0
        return cls(fixed_us=float(intercept), per_vector_us=float(slope))
