"""Distributed IVF search: clusters sharded across chips (shard_map).

The pod-scale layout for the retrieval side of HedraRAG: the hot-cluster
slab is sharded over the ``data`` axis (each chip owns C/dp cluster tiles),
queries are replicated, every chip computes a *local* fused distance+top-k
over its tiles, and the (Q, k) candidate sets are all-gathered and k-way
merged — the classic distributed-ANN reduction, expressed with jax.lax
collectives inside shard_map.  Per-chip work is exactly the single-chip
fused scan (the Pallas kernel's jnp oracle), so this composes with
kernels/ivf_scan on real TPUs.

Wire cost per query: dp * k * 12 bytes (dist + id) — negligible next to the
O(C * L * d / dp) local scans, which is why cluster sharding scales linearly
until the merge latency floor (~2 * link latency).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

f32 = jnp.float32


def _local_scan_topk(q: jax.Array, slab: jax.Array, valid: jax.Array,
                     base_row: jax.Array, k: int):
    """Scan all local cluster tiles for all queries.

    q: (Q, d); slab: (Cl, L, d); valid: (Cl,); base_row: () global row offset
    of this shard's first tile.  Returns (dists (Q, k), rows (Q, k)) where
    rows are *global* (tile, row) flat indices.
    """
    Q, d = q.shape
    Cl, L, _ = slab.shape
    flat = slab.reshape(Cl * L, d)
    d2 = (
        (q.astype(f32) ** 2).sum(-1, keepdims=True)
        - 2.0 * q.astype(f32) @ flat.astype(f32).T
        + (flat.astype(f32) ** 2).sum(-1)[None, :]
    )  # (Q, Cl*L)
    col = jnp.arange(Cl * L)
    mask = (col % L)[None, :] < valid[col // L][None, :]
    d2 = jnp.where(mask, d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx + base_row * L


def make_sharded_search(mesh: Mesh, k: int, axis: str = "data"):
    """Build a jitted sharded search fn for a cluster slab sharded on
    ``axis``.  Signature: f(queries (Q, d), slab (C, L, d), valid (C,)) ->
    (dists (Q, k), global_rows (Q, k)), fully replicated outputs."""
    n_shards = mesh.shape[axis]

    def local(q, slab, valid):
        shard = jax.lax.axis_index(axis)
        Cl = slab.shape[0]
        base = shard.astype(jnp.int32) * Cl
        d_loc, r_loc = _local_scan_topk(q, slab, valid, base, k)
        # all-gather the (Q, k) candidates and merge: k-way reduction
        d_all = jax.lax.all_gather(d_loc, axis, axis=1)  # (Q, dp, k)
        r_all = jax.lax.all_gather(r_loc, axis, axis=1)
        Q = q.shape[0]
        d_flat = d_all.reshape(Q, n_shards * k)
        r_flat = r_all.reshape(Q, n_shards * k)
        neg, sel = jax.lax.top_k(-d_flat, k)
        return -neg, jnp.take_along_axis(r_flat, sel, axis=1)

    inner = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(axis, None, None), P(axis)),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(inner)


def reference_search(q, slab, valid, k):
    """Single-device oracle over the full slab (for tests)."""
    return _local_scan_topk(q, slab, valid, jnp.int32(0), k)
