"""Distributed IVF search: clusters sharded across chips (shard_map).

The pod-scale layout for the retrieval side of HedraRAG: the hot-cluster
slab is sharded over the ``data`` axis (each chip owns C/dp cluster tiles),
queries are replicated, every chip computes a *local* fused distance+top-k
over its tiles, and the (Q, k) candidate sets are all-gathered and k-way
merged — the classic distributed-ANN reduction, expressed with jax.lax
collectives inside shard_map.  Per-chip work is exactly the single-chip
fused scan (the Pallas kernel's jnp oracle), so this composes with
kernels/ivf_scan on real TPUs.

Wire cost per query: dp * k * 12 bytes (dist + id) — negligible next to the
O(C * L * d / dp) local scans, which is why cluster sharding scales linearly
until the merge latency floor (~2 * link latency).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

f32 = jnp.float32


# ---------------------------------------------------------------------------
# Cluster -> shard ownership (the serving-path side of distribution)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardMap:
    """Cluster-ownership table for shard-mode serving.

    Each retrieval worker owns one shard of the IVF cluster table; in the
    canonical layout (``build``) shards are *contiguous cluster ranges*
    balanced by vector mass, mirroring how ``make_sharded_search`` splits
    the device slab over the mesh ``data`` axis (chip ``i`` owns tile range
    ``[bounds[i], bounds[i+1])``).  ``from_owner`` accepts an arbitrary
    cluster->shard assignment (property tests, externally planned layouts).

    The scheduler uses ``split`` to scatter a sub-stage's probe list into
    per-shard partial scans and the dispatcher uses ``owner``/``bounds`` for
    placement; hot clusters may additionally be served by crossreq replica
    holders (see ``RetrievalDispatcher.pick_shard_worker``).
    """

    owner: np.ndarray  # (n_clusters,) i64 owning shard per cluster
    bounds: Optional[np.ndarray] = None  # (n_shards+1,) for contiguous maps
    n_shards: int = 0

    def __post_init__(self):
        self.owner = np.asarray(self.owner, np.int64)
        if self.n_shards <= 0:
            self.n_shards = int(self.owner.max()) + 1 if self.owner.size else 1

    @property
    def n_clusters(self) -> int:
        return int(self.owner.shape[0])

    @classmethod
    def build(cls, cluster_sizes: Sequence[int], n_shards: int) -> "ShardMap":
        """Contiguous cluster-range shards balanced by vector mass: shard
        boundaries are placed on the size prefix sum so each worker scans
        ~1/N of the corpus, not 1/N of the (skew-sized) clusters."""
        sizes = np.asarray(cluster_sizes, np.float64)
        n_shards = max(1, int(n_shards))
        n_clusters = sizes.shape[0]
        if n_shards >= n_clusters:
            owner = np.arange(n_clusters, dtype=np.int64)
            bounds = np.arange(n_clusters + 1, dtype=np.int64)
            return cls(owner=owner, bounds=bounds, n_shards=max(n_clusters, 1))
        prefix = np.cumsum(sizes)
        total = prefix[-1] if prefix.size else 0.0
        cuts = [0]
        for j in range(1, n_shards):
            c = int(np.searchsorted(prefix, j * total / n_shards,
                                    side="right"))
            cuts.append(min(max(c, cuts[-1] + 1), n_clusters - (n_shards - j)))
        cuts.append(n_clusters)
        bounds = np.asarray(cuts, np.int64)
        owner = np.zeros(n_clusters, np.int64)
        for s in range(n_shards):
            owner[bounds[s]: bounds[s + 1]] = s
        return cls(owner=owner, bounds=bounds, n_shards=n_shards)

    @classmethod
    def from_owner(cls, owner: Sequence[int],
                   n_shards: Optional[int] = None) -> "ShardMap":
        """Arbitrary (not necessarily contiguous) cluster->shard assignment."""
        arr = np.asarray(owner, np.int64)
        return cls(owner=arr,
                   n_shards=int(n_shards) if n_shards else 0)

    def owner_of(self, clusters: Iterable[int]) -> np.ndarray:
        return self.owner[np.asarray(list(clusters), np.int64)]

    def split(self, clusters: Sequence[int]) -> list[tuple[int, list[int]]]:
        """Scatter a probe list by owning shard: ``[(shard, [cid, ...]),
        ...]`` ascending by shard id, order of clusters preserved within
        each part.  Empty shards are omitted."""
        cl = list(clusters)
        if not cl:
            return []
        own = self.owner[np.asarray(cl, np.int64)]
        parts: dict[int, list[int]] = {}
        for cid, o in zip(cl, own):
            parts.setdefault(int(o), []).append(int(cid))
        return sorted(parts.items())

    def shard_sizes(self, cluster_sizes: Sequence[int]) -> np.ndarray:
        """Vector mass per shard (diagnostics / balance reporting)."""
        sizes = np.asarray(cluster_sizes, np.float64)
        return np.bincount(self.owner, weights=sizes,
                           minlength=self.n_shards)


def _local_scan_topk(q: jax.Array, slab: jax.Array, valid: jax.Array,
                     base_row: jax.Array, k: int):
    """Scan all local cluster tiles for all queries.

    q: (Q, d); slab: (Cl, L, d); valid: (Cl,); base_row: () global row offset
    of this shard's first tile.  Returns (dists (Q, k), rows (Q, k)) where
    rows are *global* (tile, row) flat indices.
    """
    Q, d = q.shape
    Cl, L, _ = slab.shape
    flat = slab.reshape(Cl * L, d)
    d2 = (
        (q.astype(f32) ** 2).sum(-1, keepdims=True)
        - 2.0 * q.astype(f32) @ flat.astype(f32).T
        + (flat.astype(f32) ** 2).sum(-1)[None, :]
    )  # (Q, Cl*L)
    col = jnp.arange(Cl * L)
    mask = (col % L)[None, :] < valid[col // L][None, :]
    d2 = jnp.where(mask, d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx + base_row * L


def make_sharded_search(mesh: Mesh, k: int, axis: str = "data"):
    """Build a jitted sharded search fn for a cluster slab sharded on
    ``axis``.  Signature: f(queries (Q, d), slab (C, L, d), valid (C,)) ->
    (dists (Q, k), global_rows (Q, k)), fully replicated outputs."""
    n_shards = mesh.shape[axis]

    def local(q, slab, valid):
        shard = jax.lax.axis_index(axis)
        Cl = slab.shape[0]
        base = shard.astype(jnp.int32) * Cl
        d_loc, r_loc = _local_scan_topk(q, slab, valid, base, k)
        # all-gather the (Q, k) candidates and merge: k-way reduction
        d_all = jax.lax.all_gather(d_loc, axis, axis=1)  # (Q, dp, k)
        r_all = jax.lax.all_gather(r_loc, axis, axis=1)
        Q = q.shape[0]
        d_flat = d_all.reshape(Q, n_shards * k)
        r_flat = r_all.reshape(Q, n_shards * k)
        neg, sel = jax.lax.top_k(-d_flat, k)
        return -neg, jnp.take_along_axis(r_flat, sel, axis=1)

    inner = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(axis, None, None), P(axis)),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(inner)


def reference_search(q, slab, valid, k):
    """Single-device oracle over the full slab (for tests)."""
    return _local_scan_topk(q, slab, valid, jnp.int32(0), k)


def scatter_gather_search(
    index, q: np.ndarray, nprobe: int, k: int, shard_map: ShardMap,
    shards=None,
) -> tuple[np.ndarray, np.ndarray]:
    """Whole-index IVF search through the serving scatter-gather path.

    The probe list of each query is split by owning shard
    (``ShardMap.split``), each part is scanned as an independent partial
    plan (what a shard worker executes), the partial item rows are scattered
    back into one gather scoreboard in original probe order, and the gather
    plan's ``finalize`` performs the k-way merge.  Bit-identical to
    ``plan_search``/``IVFIndex.search`` — the serving-path analogue of
    ``make_sharded_search``'s all-gather + top-k reduction, on the host.
    Returns ``(dists (Q, k), ids (Q, k))``.

    ``shards`` restricts the scan to a subset of surviving shard ids (the
    degraded-mode oracle after worker crashes): probes owned by missing
    shards are dropped before planning, so the result is the partial top-k a
    degraded-complete request observes — and, for the surviving shards, the
    parity guarantee versus the whole-index fold over that reduced probe
    list is unchanged.
    """
    from repro.retrieval.plan import (
        BatchTopK, PlanBuilder, gather_scatter_rows, make_gather_plan,
    )

    q2 = np.atleast_2d(np.asarray(q, np.float32))
    probes = index.probe_order(q2, nprobe)
    Q = q2.shape[0]
    clusters = [[int(c) for c in probes[r]] for r in range(Q)]
    if shards is not None:
        alive = {int(s) for s in shards}
        clusters = [[c for c in cl if int(shard_map.owner[c]) in alive]
                    for cl in clusters]
    owners = [shard_map.owner_of(cl) for cl in clusters]
    gathers = [make_gather_plan(q2[r], clusters[r], k=k) for r in range(Q)]
    boards = [BatchTopK.empty(len(clusters[r]), gathers[r].k)
              for r in range(Q)]
    # one partial plan per shard, spanning *all* queries probing it — a
    # cluster belongs to exactly one shard, so each cluster block is scanned
    # against exactly the query set the whole-index plan would batch it with
    # (same segment table, same GEMM shapes, bit-identical item rows)
    for shard in range(shard_map.n_shards):
        pb = PlanBuilder()
        members = []  # (query, positions into its board)
        for r in range(Q):
            pos = np.flatnonzero(owners[r] == shard)
            if pos.size == 0:
                continue
            pb.add(q2[r], [clusters[r][int(p)] for p in pos], k=k)
            members.append((r, pos))
        if pb.empty:
            continue
        partial = pb.build()
        rows = index.search_plan(partial)
        for g, (r, pos) in enumerate(members):
            gather_scatter_rows(boards[r], pos, rows,
                                int(partial.group_start[g]),
                                int(partial.group_start[g + 1]))
    D = np.zeros((Q, k), np.float32)
    I = np.zeros((Q, k), np.int64)
    for r in range(Q):
        res = gathers[r].finalize(boards[r])
        D[r], I[r] = res.dists[0, :k], res.ids[0, :k]
    return D, I
