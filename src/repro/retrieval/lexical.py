"""Deterministic lexical (term-overlap) scoring channel + rank fusion.

The dense IVF path models the semantic retrieval channel; hybrid workflows
additionally score candidates lexically and fold the two orders together
(``RetrievalNode.lexical_weight > 0``).  Real deployments run BM25 here; the
repro needs the same *shape* — a second, query-text-keyed ranking signal
that is deterministic across runs and backends — without a token corpus, so
the scorer synthesises one:

* every doc owns a seeded Zipf-skewed term multiset (low term ids are
  common, high ids rare), derived lazily from the doc id alone;
* a query's terms derive from a stable hash of its text with the same skew;
* score = idf-weighted overlap, idf rising with term rarity.

Identical (text, doc) pairs therefore score identically everywhere, which is
what the serving fingerprints and cross-backend parity tests need.

``rrf_fuse`` is standard weighted reciprocal-rank fusion over the dense
order and the lexical reorder; ``weight=0`` is the identity (pure dense).
"""
from __future__ import annotations

import hashlib

import numpy as np

_SKEW = 2.5  # u**_SKEW biases draws toward low (common) term ids


class LexicalScorer:
    """Synthetic-but-deterministic lexical channel over integer doc ids."""

    def __init__(self, vocab_size: int = 4096, doc_terms: int = 24,
                 query_terms: int = 8, seed: int = 101):
        self.vocab_size = int(vocab_size)
        self.doc_terms = int(doc_terms)
        self.query_terms = int(query_terms)
        self.seed = int(seed)
        self._doc_cache: dict[int, np.ndarray] = {}

    # ----------------------------------------------------------- term sets
    def _skewed(self, u: np.ndarray) -> np.ndarray:
        t = (self.vocab_size * np.asarray(u, np.float64) ** _SKEW)
        return np.minimum(t.astype(np.int64), self.vocab_size - 1)

    def doc_term_set(self, doc_id: int) -> np.ndarray:
        terms = self._doc_cache.get(doc_id)
        if terms is None:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, 1, int(doc_id)]))
            terms = np.unique(self._skewed(rng.random(self.doc_terms)))
            self._doc_cache[doc_id] = terms
        return terms

    def query_term_set(self, text: str) -> np.ndarray:
        h = hashlib.sha256(str(text).encode("utf-8")).digest()
        u = np.frombuffer(h[: 4 * self.query_terms], np.uint32).astype(
            np.float64) / float(2**32)
        return np.unique(self._skewed(u[: self.query_terms]))

    def idf(self, terms: np.ndarray) -> np.ndarray:
        # low ids are drawn often (the skew above), so rarity — and idf —
        # rises with the term id
        return np.log1p((np.asarray(terms, np.float64) + 1.0)
                        / float(self.vocab_size))

    # -------------------------------------------------------------- scoring
    def scores(self, text: str, doc_ids) -> dict:
        """idf-weighted term overlap for each candidate doc."""
        q = self.query_term_set(text)
        qidf = self.idf(q)
        out = {}
        for d in doc_ids:
            d = int(d)
            hit = np.isin(q, self.doc_term_set(d), assume_unique=True)
            out[d] = float(qidf[hit].sum())
        return out


def rrf_fuse(dense_ids, lex_scores: dict, weight: float,
             c: float = 60.0) -> list:
    """Weighted reciprocal-rank fusion: fold the dense order (rank = list
    position) with the lexical reorder of the same candidate set.  Returns
    the identical id set, reordered; ties break on doc id so the fold is
    deterministic."""
    dense_ids = [int(d) for d in dense_ids]
    lex_order = sorted(dense_ids,
                       key=lambda d: (-lex_scores.get(d, 0.0), d))
    lex_rank = {d: i for i, d in enumerate(lex_order)}
    w = float(weight)
    fused = {
        d: (1.0 - w) / (c + i) + w / (c + lex_rank[d])
        for i, d in enumerate(dense_ids)
    }
    return sorted(dense_ids, key=lambda d: (-fused[d], d))
