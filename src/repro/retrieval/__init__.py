from repro.retrieval.hotcache import AccessTracker, HotClusterCache, plan_memory_split
from repro.retrieval.hybrid import HybridRetrievalEngine, engine_from_memory_budget
from repro.retrieval.ivf import ClusterCostModel, IVFIndex, TopK
from repro.retrieval.plan import (
    BatchTopK,
    PlanBuilder,
    RetrievalPlan,
    plan_from_work,
    plan_search,
)
from repro.retrieval.synthetic import (
    CorpusConfig,
    DuplicateTrafficEmbedder,
    SyntheticEmbedder,
    make_corpus,
)

__all__ = [
    "IVFIndex",
    "TopK",
    "BatchTopK",
    "PlanBuilder",
    "RetrievalPlan",
    "plan_from_work",
    "plan_search",
    "ClusterCostModel",
    "HotClusterCache",
    "AccessTracker",
    "plan_memory_split",
    "HybridRetrievalEngine",
    "engine_from_memory_budget",
    "CorpusConfig",
    "make_corpus",
    "SyntheticEmbedder",
    "DuplicateTrafficEmbedder",
]
