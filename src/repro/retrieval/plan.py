"""Structure-of-arrays retrieval plans: the vectorized sub-stage executor.

A :class:`RetrievalPlan` is one retrieval-worker job flattened into arrays.
It replaces the per-item ``list[(query, cluster, TopK)]`` protocol (one
Python ``TopK`` object and two ``merge()`` allocations per work item) with a
layout the whole sub-stage path can operate on at numpy speed:

* **items** — one row per (query, cluster) probe.  ``queries`` is the stacked
  ``(n_items, d)`` matrix, ``cluster_ids`` the probed cluster per row.
* **segment table** — items sorted by cluster (``seg_order``) and segmented
  into unique-cluster runs (``seg_cluster``, ``seg_bounds``).  Each cluster
  block is GEMM-scanned exactly once per sub-stage and the result rows are
  shared by every query probing that cluster, on either the host or the
  device path.
* **scoreboard** — a :class:`BatchTopK`: ``(n_items, k)`` dists/ids arrays.
  Merging a batch of candidate rows is a single ``np.argpartition`` over the
  concatenated candidate axis — no per-item allocation.
* **groups** — consecutive items belonging to one logical search (a request
  sub-stage, a speculative warmup, one query of a batched full search).
  ``finalize()`` folds the per-item rows back into one running top-k per
  group with the same sequential-merge semantics (and therefore the same
  per-cluster improvement streaks) as the scalar ``TopK.merge`` chain, but
  vectorized across all groups.

The plan carries everything completion needs (seeds, early-termination
streak state, opaque ``meta`` tags mapping groups back to (request, node)),
so the scheduler consumes results with one vectorized scatter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

from repro.retrieval.ivf import TopK

_EPS = 1e-12  # improvement threshold shared with the scalar streak logic

# finalize(): above this (n_groups * W * g_max) element count the dense 3-D
# streak-recovery tensor would dominate memory (coarse whole-stage jobs with
# large nprobe), so the equivalent sequential per-step merge is used instead
_STREAK_TENSOR_MAX = 2_000_000


# ---------------------------------------------------------------------------
# Batched running top-k (SoA scoreboard)
# ---------------------------------------------------------------------------


class BatchTopK:
    """Running top-k for ``n`` items as two ``(n, k)`` arrays.

    Rows are kept ascending by distance, ``+inf``/``-1`` padded — row ``i``
    is bit-compatible with ``TopK(k, dists[i], ids[i])``.  ``merge_rows``
    folds a ``(m, c)`` candidate block into ``m`` rows at once.
    """

    __slots__ = ("k", "dists", "ids")

    def __init__(self, k: int, dists: np.ndarray, ids: np.ndarray):
        self.k = int(k)
        self.dists = dists
        self.ids = ids

    @classmethod
    def empty(cls, n: int, k: int) -> "BatchTopK":
        return cls(
            k,
            np.full((n, k), np.inf, np.float32),
            np.full((n, k), -1, np.int64),
        )

    @property
    def n(self) -> int:
        return self.dists.shape[0]

    def merge_rows(
        self, rows: np.ndarray, cand_d: np.ndarray, cand_i: np.ndarray
    ) -> None:
        """Merge candidates ``cand_d/cand_i`` (m, c) into rows ``rows`` (m,).

        One argpartition over the concatenated ``k + c`` candidate axis for
        all m rows, then one stable sort — the exact batched analogue of
        ``TopK.merge`` (current entries concatenated first, so tie behaviour
        matches the scalar path).
        """
        if cand_d.size == 0 or rows.size == 0:
            return
        k = self.k
        d = np.concatenate(
            [self.dists[rows], np.asarray(cand_d, np.float32)], axis=1)
        i = np.concatenate(
            [self.ids[rows], np.asarray(cand_i, np.int64)], axis=1)
        if d.shape[1] > k:
            sel = np.argpartition(d, k - 1, axis=1)[:, :k]
            d = np.take_along_axis(d, sel, axis=1)
            i = np.take_along_axis(i, sel, axis=1)
        order = np.argsort(d, axis=1, kind="stable")
        self.dists[rows] = np.take_along_axis(d, order, axis=1)
        self.ids[rows] = np.take_along_axis(i, order, axis=1)

    def row(self, i: int, k: Optional[int] = None) -> TopK:
        """Materialise one row as a scalar ``TopK`` (trimmed to ``k``)."""
        kk = self.k if k is None else int(k)
        return TopK(kk, self.dists[i, :kk].copy(), self.ids[i, :kk].copy())


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlanResult:
    """Per-group outcome of ``RetrievalPlan.finalize``."""

    dists: np.ndarray  # (n_groups, k) merged running top-k, ascending
    ids: np.ndarray  # (n_groups, k)
    last_kth: np.ndarray  # (n_groups,) kth distance at the last improvement
    no_improve: np.ndarray  # (n_groups,) trailing no-improvement streak

    def group_topk(self, g: int, k: int) -> TopK:
        return TopK(int(k), self.dists[g, :k].copy(), self.ids[g, :k].copy())


@dataclasses.dataclass
class RetrievalPlan:
    """One flattened retrieval job (see module docstring for the layout)."""

    queries: np.ndarray  # (n_items, d) f32 — one row per (query, cluster)
    q_norms: np.ndarray  # (n_items,) f32 — ||q||^2 cached at build time
    cluster_ids: np.ndarray  # (n_items,) i64
    k: int  # scoreboard width = max group k
    item_group: np.ndarray  # (n_items,) i64 — owning group per item
    group_start: np.ndarray  # (n_groups + 1,) i64 — items of g: [s[g], s[g+1])
    group_k: np.ndarray  # (n_groups,) i64 — requested k per group
    # (n_groups,) i64 — logical searches served per group: a crossreq-fused
    # group executes once but answers `fanout` subscriber requests; backends
    # charge the group once and account the avoided duplicate work
    group_fanout: np.ndarray
    group_meta: list  # opaque per-group tags (request/node/spec binding)
    seed_dists: np.ndarray  # (n_groups, k) f32 — running top-k at assembly
    seed_ids: np.ndarray  # (n_groups, k) i64
    group_last_kth: np.ndarray  # (n_groups,) f64 — streak state at assembly
    group_no_improve: np.ndarray  # (n_groups,) i64
    # segment table: items grouped by probed cluster
    seg_order: np.ndarray  # (n_items,) permutation, cluster-sorted
    seg_cluster: np.ndarray  # (n_seg,) unique cluster ids, ascending
    seg_bounds: np.ndarray  # (n_seg + 1,) ranges into seg_order

    @property
    def n_items(self) -> int:
        return int(self.cluster_ids.shape[0])

    @property
    def n_groups(self) -> int:
        return int(self.group_k.shape[0])

    @property
    def n_segments(self) -> int:
        return int(self.seg_cluster.shape[0])

    def seg_counts(self) -> np.ndarray:
        """Queries probing each segment's cluster (for vectorized charging)."""
        return self.seg_bounds[1:] - self.seg_bounds[:-1]

    def segment_rows(self, s: int) -> np.ndarray:
        """Item rows probing segment ``s``'s cluster."""
        return self.seg_order[self.seg_bounds[s]: self.seg_bounds[s + 1]]

    # ------------------------------------------------------------- completion
    def finalize(self, results: BatchTopK) -> PlanResult:
        """Fold per-item rows into per-group running top-k + streaks.

        One stable sort over each group's ``[seed | item 0 | item 1 | ...]``
        candidate axis replaces the sequential per-cluster merge chain; the
        per-cluster kth sequence the early-termination streak needs is
        recovered with a vectorized prefix count over the sorted step labels.
        The outcome (including tie order — truncation to top-k commutes with
        the stable concatenation order) matches the scalar
        ``TopK.merge``-per-cluster chain exactly.
        """
        k = self.k
        n_g = self.n_groups
        sizes = self.group_start[1:] - self.group_start[:-1]
        g_max = int(sizes.max()) if sizes.size else 0
        ref = self.group_last_kth.astype(np.float64).copy()
        noimp = self.group_no_improve.astype(np.int64).copy()
        if g_max == 0 or results.n == 0:
            return PlanResult(
                self.seed_dists.copy(), self.seed_ids.copy(), ref, noimp)
        if n_g * (g_max + 1) * k * g_max > _STREAK_TENSOR_MAX:
            return self._finalize_sequential(results, sizes, g_max, ref, noimp)
        # candidate matrix: seed in columns [0, k), item j in
        # [(j+1)k, (j+2)k) — one fancy scatter for all items
        W = (g_max + 1) * k
        cd = np.full((n_g, W), np.inf, np.float32)
        ci = np.full((n_g, W), -1, np.int64)
        cd[:, :k] = self.seed_dists
        ci[:, :k] = self.seed_ids
        slot = np.arange(self.n_items) - self.group_start[self.item_group] + 1
        cols = slot[:, None] * k + np.arange(k)[None, :]
        cd[self.item_group[:, None], cols] = results.dists
        ci[self.item_group[:, None], cols] = results.ids
        order = np.argsort(cd, axis=1, kind="stable")
        ds = np.take_along_axis(cd, order, axis=1)
        is_ = np.take_along_axis(ci, order, axis=1)
        # kth after each step j = group_k-th smallest among candidates with
        # step label <= j (label: seed -1, item j -> j)
        lab = order // k - 1  # (n_g, W) sorted step labels
        mask = lab[:, :, None] <= np.arange(g_max)[None, None, :]
        cum = mask.cumsum(axis=1, dtype=np.int32)
        hit = cum == self.group_k[:, None, None].astype(np.int32)
        pos = hit.argmax(axis=1)  # (n_g, g_max) first index reaching k_g
        kth_seq = ds[np.arange(n_g)[:, None], pos].astype(np.float64)
        for j in range(g_max):
            act = sizes > j
            imp = act & (kth_seq[:, j] < ref - _EPS)
            ref[imp] = kth_seq[imp, j]
            noimp[imp] = 0
            noimp[act & ~imp] += 1
        return PlanResult(
            np.ascontiguousarray(ds[:, :k]),
            np.ascontiguousarray(is_[:, :k]),
            ref, noimp)

    def _finalize_sequential(self, results, sizes, g_max, ref, noimp):
        """Equivalent fold without the dense streak tensor: one vectorized
        merge per item *position* (every group advances in lock-step), so
        memory stays O(n_groups * k) however many clusters a group holds."""
        run = BatchTopK(self.k, self.seed_dists.copy(), self.seed_ids.copy())
        kth_col = self.group_k - 1
        for j in range(g_max):
            act = np.flatnonzero(sizes > j)
            items = self.group_start[act] + j
            run.merge_rows(act, results.dists[items], results.ids[items])
            kth = run.dists[act, kth_col[act]].astype(np.float64)
            imp = kth < ref[act] - _EPS
            ref[act[imp]] = kth[imp]
            noimp[act[imp]] = 0
            noimp[act[~imp]] += 1
        return PlanResult(run.dists, run.ids, ref, noimp)


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


class PlanBuilder:
    """Accumulates (query, clusters) groups and emits a ``RetrievalPlan``."""

    def __init__(self):
        self._queries: list[np.ndarray] = []  # one (d,) vector per group
        self._clusters: list[np.ndarray] = []  # clusters probed per group
        self._k: list[int] = []
        self._meta: list[Any] = []
        self._seeds: list[Optional[TopK]] = []
        self._last_kth: list[float] = []
        self._no_improve: list[int] = []
        self._fanout: list[int] = []
        self._out_k: list[int] = []

    def __len__(self) -> int:
        return len(self._queries)

    @property
    def empty(self) -> bool:
        return not self._queries

    def add(
        self,
        query: np.ndarray,
        clusters: Sequence[int],
        *,
        k: int,
        meta: Any = None,
        seed: Optional[TopK] = None,
        last_kth: float = np.inf,
        no_improve: int = 0,
        fanout: int = 1,
        out_k: Optional[int] = None,
    ) -> int:
        """Add one group: ``query`` probing ``clusters`` with running ``seed``.

        ``out_k`` widens the *scoreboard* (``plan.k``) beyond the group's
        requested ``k`` without touching ``group_k`` — the k-th-distance
        streaks and the returned ``group_topk(g, k)`` stay identical, but
        ``finalize`` rows carry ``out_k`` candidates (used by the crossreq
        global cache to publish top-k' entries at no extra scan cost).
        """
        gid = len(self._queries)
        self._queries.append(np.asarray(query, np.float32))
        self._clusters.append(np.asarray(clusters, np.int64))
        self._k.append(int(k))
        self._meta.append(meta)
        self._seeds.append(seed)
        self._last_kth.append(float(last_kth))
        self._no_improve.append(int(no_improve))
        self._fanout.append(max(1, int(fanout)))
        self._out_k.append(int(out_k) if out_k is not None else int(k))
        return gid

    def build(self) -> RetrievalPlan:
        if not self._queries:
            raise ValueError("empty plan")
        n_groups = len(self._queries)
        counts = np.array([c.shape[0] for c in self._clusters], np.int64)
        group_start = np.zeros(n_groups + 1, np.int64)
        np.cumsum(counts, out=group_start[1:])
        cluster_ids = (
            np.concatenate(self._clusters)
            if counts.sum() else np.zeros(0, np.int64)
        )
        item_group = np.repeat(np.arange(n_groups, dtype=np.int64), counts)
        group_q = np.stack(self._queries).astype(np.float32, copy=False)
        queries = group_q[item_group]
        q_norms = (group_q**2).sum(-1)[item_group]
        group_k = np.array(self._k, np.int64)
        k = int(max(group_k.max(), max(self._out_k)))
        seed_d = np.full((n_groups, k), np.inf, np.float32)
        seed_i = np.full((n_groups, k), -1, np.int64)
        for g, tk in enumerate(self._seeds):
            if tk is not None:
                kk = min(tk.k, k)
                seed_d[g, :kk] = tk.dists[:kk]
                seed_i[g, :kk] = tk.ids[:kk]
        order = np.argsort(cluster_ids, kind="stable")
        sorted_c = cluster_ids[order]
        if sorted_c.size:
            uniq, first = np.unique(sorted_c, return_index=True)
            seg_bounds = np.append(first, sorted_c.size).astype(np.int64)
        else:
            uniq = np.zeros(0, np.int64)
            seg_bounds = np.zeros(1, np.int64)
        return RetrievalPlan(
            queries=queries,
            q_norms=q_norms,
            cluster_ids=cluster_ids,
            k=k,
            item_group=item_group,
            group_start=group_start,
            group_k=group_k,
            group_fanout=np.array(self._fanout, np.int64),
            group_meta=list(self._meta),
            seed_dists=seed_d,
            seed_ids=seed_i,
            group_last_kth=np.array(self._last_kth, np.float64),
            group_no_improve=np.array(self._no_improve, np.int64),
            seg_order=order.astype(np.int64),
            seg_cluster=uniq.astype(np.int64),
            seg_bounds=seg_bounds,
        )


# ---------------------------------------------------------------------------
# Scatter-gather (shard-mode serving): partial-scan reassembly
# ---------------------------------------------------------------------------


def make_gather_plan(
    query: np.ndarray,
    clusters: Sequence[int],
    *,
    k: int,
    seed: Optional[TopK] = None,
    last_kth: float = np.inf,
    no_improve: int = 0,
    out_k: Optional[int] = None,
) -> RetrievalPlan:
    """One-group replay plan for a scatter-gather merge.

    Shard-mode serving splits a sub-stage's probe list into per-shard
    partial scans; each partial returns *item-level* rows (one per
    (query, cluster) probe, exactly what the whole-index path would have
    computed for those items).  The gather step scatters those rows back
    into a single scoreboard ordered like the original probe list and folds
    it with this plan's ``finalize`` — the same seed/streak fold the
    whole-index path runs, so the k-way merged result (top-k, ``no_improve``
    streak, ``last_kth``) is bit-identical to a single worker scanning the
    whole probe list.
    """
    b = PlanBuilder()
    b.add(query, clusters, k=int(k), seed=seed, last_kth=last_kth,
          no_improve=no_improve, out_k=out_k)
    return b.build()


def gather_scatter_rows(
    scoreboard: BatchTopK,
    positions: np.ndarray,
    results: BatchTopK,
    start: int,
    stop: int,
) -> None:
    """Copy one partial scan's item rows ``results[start:stop]`` into the
    gather ``scoreboard`` at ``positions`` (the items' indices in the
    original probe-list order).  Rows are ascending +inf/-1 padded, so
    trimming a wider executing plan's rows to the gather width keeps
    exactly the top candidates the narrower whole-index row would hold."""
    gk = scoreboard.k
    scoreboard.dists[positions] = results.dists[start:stop, :gk]
    scoreboard.ids[positions] = results.ids[start:stop, :gk]


# ---------------------------------------------------------------------------
# Convenience: plan-based full search (reference-equivalent)
# ---------------------------------------------------------------------------


def plan_search(
    index, q: np.ndarray, nprobe: int, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Full IVF search through the plan executor.

    Semantically identical to ``IVFIndex.search`` (same probe order, same
    merge semantics); one group per query, one item per probed cluster.
    Returns ``(dists (Q, k), ids (Q, k))``.
    """
    q2 = np.atleast_2d(np.asarray(q, np.float32))
    probes = index.probe_order(q2, nprobe)
    b = PlanBuilder()
    for r in range(q2.shape[0]):
        b.add(q2[r], probes[r], k=k)
    plan = b.build()
    out = index.search_plan(plan)
    res = plan.finalize(out)
    return res.dists[:, :k].copy(), res.ids[:, :k].copy()


def plan_from_work(
    work: Sequence[tuple[np.ndarray, int, TopK]]
) -> RetrievalPlan:
    """Adapt the legacy per-item work-list protocol to a plan: one group per
    (query, cluster, running-topk) item, seeded with the running top-k."""
    b = PlanBuilder()
    for q, cid, tk in work:
        b.add(q, [int(cid)], k=tk.k, seed=tk)
    return b.build()
