"""Hybrid host/device retrieval engine (paper §4.4, Fig. 11).

Per sub-stage the engine receives a :class:`~repro.retrieval.plan.RetrievalPlan`
— a structure-of-arrays batch of (query, cluster) probes spanning requests.
The plan's segment table is partitioned at *cluster* granularity: segments
whose cluster is resident in the device hot cache are packed into QB-aligned
query-groups and scanned by the fused Pallas kernel (jnp oracle off-TPU);
the rest run on the host GEMM path.  Both paths merge into the plan's shared
``BatchTopK`` scoreboard, and the caller treats their runtimes as overlapped
(they execute on different resources in the real system).

Device-slab maintenance is incremental: cluster swaps stage tiles into the
pinned host slab and mark the slot dirty; the jnp mirror is then *delta
updated* with one batched index-update per sub-stage instead of re-uploading
the whole slab (``stats()['uploads']`` reports full vs delta traffic).
Clusters larger than the tile length are refused residency (they would be
silently truncated on the device) and stay on the host path.

The legacy per-item ``search_substage`` API is kept as a thin adapter over
the plan executor.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.retrieval.hotcache import HotClusterCache, capacity_from_bytes
from repro.retrieval.ivf import IVFIndex, TopK
from repro.retrieval.plan import BatchTopK, RetrievalPlan, plan_from_work

QB = 8  # queries per device work group (sublane-aligned)


@dataclasses.dataclass
class SubstageTiming:
    host_us: float = 0.0
    device_us: float = 0.0
    n_host_items: int = 0
    n_device_items: int = 0

    @property
    def overlapped_us(self) -> float:
        return max(self.host_us, self.device_us)


class HybridRetrievalEngine:
    def __init__(
        self,
        index: IVFIndex,
        *,
        cache_capacity: int = 0,
        tile_len: int = 0,
        update_interval: int = 50,
        transit_substages: int = 2,
        kernel_impl: str = "auto",
        topk_default: int = 10,
        replication: int = 1,
        num_owners: int = 1,
        shared_tracker=None,
    ):
        import jax.numpy as jnp

        self.index = index
        self.kernel_impl = kernel_impl
        self.topk_default = topk_default
        sizes = index.cluster_sizes()
        self.tile_len = tile_len or max(128, int(-(-sizes.max() // 128) * 128))
        self._jnp = jnp
        self.cache_capacity = cache_capacity
        if cache_capacity:
            self._slab = np.zeros(
                (cache_capacity, self.tile_len, index.dim), np.float32
            )
            self._slab_ids = np.full((cache_capacity, self.tile_len), -1, np.int64)
            self._slab_valid = np.zeros((cache_capacity,), np.int32)
            self._slot_cid = np.full((cache_capacity,), -1, np.int64)
        self.cache = HotClusterCache(
            index.n_clusters,
            cache_capacity,
            update_interval=update_interval,
            transit_substages=transit_substages,
            loader=self._load_cluster if cache_capacity else None,
            replication=replication,
            num_owners=num_owners,
            shared_tracker=shared_tracker,
        )
        self._device_slab = None  # lazily mirrored jnp copy
        self._dirty_slots: set[int] = set()  # staged but not yet delta-uploaded
        self._qbuf = np.zeros((0, QB, index.dim), np.float32)  # persistent
        self.upload_stats = {"full": 0, "delta": 0, "delta_slots": 0}

    # ----------------------------------------------------------- shard mode
    def enable_sharding(self, shard_owner, num_owners: int) -> None:
        """Partition the device slab by cluster ownership: worker ``w`` owns
        slots ``s`` with ``s % num_owners == w`` and only its shard's
        clusters (plus crossreq hot-cluster replicas) are staged there, so
        each worker's resident set shrinks ~``num_owners`` x versus the
        pool-global slab.  Must run before any cluster is staged."""
        self.cache.set_shard_owner(shard_owner, num_owners)

    @property
    def sharded(self) -> bool:
        return self.cache.shard_owner is not None

    # ------------------------------------------------------------- cache load
    def _load_cluster(self, cid: int, slot: int) -> bool:
        """Stage cluster ``cid`` into slab ``slot``; refuse oversized ones.

        A cluster larger than ``tile_len`` cannot be represented on the
        device without truncation (which would silently change top-k vs the
        host path), so residency is refused and the cache keeps it host-side.
        """
        lo, hi = int(self.index.offsets[cid]), int(self.index.offsets[cid + 1])
        m = hi - lo
        if m > self.tile_len:
            return False
        self._slab[slot, :] = 0.0
        self._slab[slot, :m] = self.index.flat[lo:hi]
        self._slab_ids[slot, :] = -1
        self._slab_ids[slot, :m] = self.index.ids[lo:hi]
        self._slab_valid[slot] = m
        self._slot_cid[slot] = cid
        self._dirty_slots.add(int(slot))  # delta-upload on next device use
        return True

    def _device_arrays(self):
        """jnp mirror of the slab, maintained by per-slot delta uploads."""
        jnp = self._jnp
        if self._device_slab is None:
            self._device_slab = (
                jnp.asarray(self._slab),
                jnp.asarray(self._slab_valid),
            )
            self.upload_stats["full"] += 1
            self._dirty_slots.clear()
        elif self._dirty_slots:
            slots = np.fromiter(sorted(self._dirty_slots), np.int64)
            ds, dv = self._device_slab
            ds = ds.at[slots].set(jnp.asarray(self._slab[slots]))
            dv = dv.at[slots].set(jnp.asarray(self._slab_valid[slots]))
            self._device_slab = (ds, dv)
            self.upload_stats["delta"] += 1
            self.upload_stats["delta_slots"] += int(slots.size)
            self._dirty_slots.clear()
        return self._device_slab

    # ---------------------------------------------------------------- search
    def search_plan(
        self,
        plan: RetrievalPlan,
        *,
        resident: Optional[np.ndarray] = None,
        timing: Optional[SubstageTiming] = None,
        owner: Optional[int] = None,
    ) -> BatchTopK:
        """Execute one plan: device path for resident-cluster segments, host
        path for the rest, both merging into the item scoreboard.

        ``resident`` is the residency snapshot (bool per cluster) taken when
        the sub-stage was *dispatched*; passing it keeps the executed
        partition consistent with the charged one even if swaps landed in
        between.  Segments whose snapshot said device but whose cluster has
        since been swapped out fall back to the host path (counted in
        ``cache.stats.stale_fallbacks``).

        ``owner`` (shard mode) restricts the device path to the executing
        worker's slot partition: slot resolution goes through
        ``cache.slot_on_owner`` so a cluster resident only on *another*
        worker's slab takes this worker's host path.
        """
        out = BatchTopK.empty(plan.n_items, plan.k)
        # records accesses; hit/miss stats and live residency are both
        # owner-filtered in shard mode, matching the executed partition
        cur = self.cache.lookup_batch(plan.cluster_ids, owner=owner)
        if resident is None:
            # per-segment residency from the per-item lookup (items of a
            # segment share one cluster, so its first is representative)
            seg_dev = cur[plan.seg_order[plan.seg_bounds[:-1]]]
        else:
            seg_dev = resident[plan.seg_cluster]
        host_segs: list[int] = []
        dev_segs: list[int] = []
        dev_slots: dict[int, int] = {}
        for s in range(plan.n_segments):
            if not seg_dev[s]:
                host_segs.append(s)
                continue
            cid = int(plan.seg_cluster[s])
            if owner is None:
                slot = self.cache._resident.get(cid)
            else:
                slot = self.cache.slot_on_owner(cid, owner)
            if slot is None or self._slot_cid[slot] != cid:
                # swapped out between dispatch and execution
                self.cache.stats.stale_fallbacks += int(
                    plan.segment_rows(s).size)
                host_segs.append(s)
            else:
                dev_segs.append(s)
                dev_slots[s] = int(slot)

        if dev_segs:
            t0 = time.perf_counter()
            n_dev = self._device_scan(plan, dev_segs, out, dev_slots)
            if timing is not None:
                timing.device_us = (time.perf_counter() - t0) * 1e6
                timing.n_device_items = n_dev
        if host_segs:
            t0 = time.perf_counter()
            self.index.scan_segments(plan, np.asarray(host_segs, np.int64), out)
            if timing is not None:
                timing.host_us = (time.perf_counter() - t0) * 1e6
                timing.n_host_items = int(
                    sum(plan.segment_rows(s).size for s in host_segs))

        self.cache.end_substage()
        return out

    def search_substage(
        self, work: Sequence[tuple[np.ndarray, int, TopK]]
    ) -> tuple[list[TopK], SubstageTiming]:
        """Legacy per-item API: adapt the work list to a plan and execute."""
        timing = SubstageTiming()
        if not work:
            self.cache.end_substage()  # empty sub-stages still tick the clock
            return [], timing
        plan = plan_from_work(work)
        res = plan.finalize(self.search_plan(plan, timing=timing))
        return (
            [res.group_topk(g, int(plan.group_k[g]))
             for g in range(plan.n_groups)],
            timing,
        )

    # ------------------------------------------------------------ device path
    def _query_groups(self, n: int) -> np.ndarray:
        """Persistent pre-packed query-group buffer (grown geometrically)."""
        if self._qbuf.shape[0] < n:
            cap = max(n, 2 * self._qbuf.shape[0], 8)
            self._qbuf = np.zeros((cap, QB, self.index.dim), np.float32)
        return self._qbuf

    def _device_scan(self, plan: RetrievalPlan, dev_segs, out: BatchTopK,
                     dev_slots: Optional[dict] = None) -> int:
        """Pack resident segments into (G, QB, d) groups + fused scan, then
        one vectorized scatter-merge of all member rows.  ``dev_slots``
        (shard mode) carries the per-segment slot resolved on the executing
        worker's partition; without it the primary slot is used."""
        from repro.kernels.ivf_scan import ivf_scan

        jnp = self._jnp
        slab, valid = self._device_arrays()
        k = min(plan.k, self.tile_len)
        g_slots: list[int] = []
        g_rows: list[np.ndarray] = []
        for s in dev_segs:
            if dev_slots is not None and s in dev_slots:
                slot = dev_slots[s]
            else:
                slot = int(self.cache.slot_of(int(plan.seg_cluster[s])))
            rows = plan.segment_rows(s)
            for ofs in range(0, rows.size, QB):
                g_slots.append(slot)
                g_rows.append(rows[ofs: ofs + QB])
        G = len(g_slots)
        qbuf = self._query_groups(G)
        qbuf[:G] = 0.0
        for g, rows in enumerate(g_rows):
            qbuf[g, : rows.size] = plan.queries[rows]
        slots_arr = np.asarray(g_slots, np.int32)
        dists, idx = ivf_scan(
            jnp.asarray(qbuf[:G]), jnp.asarray(slots_arr), slab, valid, k,
            impl=self.kernel_impl)
        dists = np.asarray(dists)  # (G, QB, k)
        idx = np.asarray(idx)
        # local row -> doc id for all groups at once
        sid = self._slab_ids[slots_arr]  # (G, L)
        ids = np.take_along_axis(
            sid, np.maximum(idx, 0).reshape(G, -1), axis=1).reshape(idx.shape)
        ids = np.where(idx >= 0, ids, -1)
        # one scatter-merge over the real (non-padded) member rows
        counts = [r.size for r in g_rows]
        rows_flat = np.concatenate(g_rows)
        sel_g = np.repeat(np.arange(G), counts)
        sel_r = np.concatenate([np.arange(c) for c in counts])
        out.merge_rows(rows_flat, dists[sel_g, sel_r], ids[sel_g, sel_r])
        return int(rows_flat.size)

    # ---------------------------------------------------------------- stats
    def resident_mask(self, owner: Optional[int] = None) -> np.ndarray:
        """Residency snapshot for dispatch-time charging (bool per cluster);
        ``owner`` restricts it to one worker's slot partition (shard mode)."""
        return self.cache.resident_mask(owner)

    def replica_owners(self, cid: int) -> list[int]:
        """Workers holding a staged replica of ``cid`` (crossreq routing)."""
        return self.cache.replica_owners(cid)

    def stats(self) -> dict:
        per_owner = (self.cache.per_owner_resident()
                     if self.cache.num_owners > 1 else {})
        return {
            "sharded": self.sharded,
            "per_owner_resident": per_owner,
            "hit_rate": self.cache.stats.hit_rate,
            "hits": self.cache.stats.hits,
            "misses": self.cache.stats.misses,
            "swaps": self.cache.stats.swaps,
            "oversized_rejects": self.cache.stats.oversized_rejects,
            "stale_fallbacks": self.cache.stats.stale_fallbacks,
            "replica_loads": self.cache.stats.replica_loads,
            "replicated_clusters": len(self.cache.replicated_ids),
            "uploads": dict(self.upload_stats),
            "skew": self.cache.tracker.skewness_report(),
        }


def engine_from_memory_budget(
    index: IVFIndex,
    cache_bytes: int,
    **kw,
) -> HybridRetrievalEngine:
    sizes = index.cluster_sizes()
    tile_len = max(128, int(-(-sizes.max() // 128) * 128))
    cap = capacity_from_bytes(cache_bytes, tile_len, index.dim)
    cap = min(cap, index.n_clusters)
    return HybridRetrievalEngine(index, cache_capacity=cap, tile_len=tile_len, **kw)
