"""Hybrid host/device retrieval engine (paper §4.4, Fig. 11).

Per sub-stage the engine receives a batch of (query, cluster, running-topk)
work items spanning requests.  Items whose cluster is resident in the device
hot cache are packed into query-groups and scanned by the fused Pallas kernel
(jnp oracle off-TPU); the rest run on the host path.  Both paths share the
``TopK`` merge, and the caller treats their runtimes as overlapped (they
execute on different resources in the real system).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.retrieval.hotcache import HotClusterCache, capacity_from_bytes
from repro.retrieval.ivf import IVFIndex, TopK

QB = 8  # queries per device work group (sublane-aligned)


@dataclasses.dataclass
class SubstageTiming:
    host_us: float = 0.0
    device_us: float = 0.0
    n_host_items: int = 0
    n_device_items: int = 0

    @property
    def overlapped_us(self) -> float:
        return max(self.host_us, self.device_us)


class HybridRetrievalEngine:
    def __init__(
        self,
        index: IVFIndex,
        *,
        cache_capacity: int = 0,
        tile_len: int = 0,
        update_interval: int = 50,
        transit_substages: int = 2,
        kernel_impl: str = "auto",
        topk_default: int = 10,
    ):
        import jax.numpy as jnp

        self.index = index
        self.kernel_impl = kernel_impl
        self.topk_default = topk_default
        sizes = index.cluster_sizes()
        self.tile_len = tile_len or max(128, int(-(-sizes.max() // 128) * 128))
        self._jnp = jnp
        self.cache_capacity = cache_capacity
        if cache_capacity:
            self._slab = np.zeros(
                (cache_capacity, self.tile_len, index.dim), np.float32
            )
            self._slab_ids = np.full((cache_capacity, self.tile_len), -1, np.int64)
            self._slab_valid = np.zeros((cache_capacity,), np.int32)
        self.cache = HotClusterCache(
            index.n_clusters,
            cache_capacity,
            update_interval=update_interval,
            transit_substages=transit_substages,
            loader=self._load_cluster if cache_capacity else None,
        )
        self._device_slab = None  # lazily mirrored jnp copy

    # ------------------------------------------------------------- cache load
    def _load_cluster(self, cid: int, slot: int) -> None:
        lo, hi = int(self.index.offsets[cid]), int(self.index.offsets[cid + 1])
        m = min(hi - lo, self.tile_len)
        self._slab[slot, :] = 0.0
        self._slab[slot, :m] = self.index.flat[lo : lo + m]
        self._slab_ids[slot, :] = -1
        self._slab_ids[slot, :m] = self.index.ids[lo : lo + m]
        self._slab_valid[slot] = m
        self._device_slab = None  # invalidate device mirror

    def _device_arrays(self):
        if self._device_slab is None:
            self._device_slab = (
                self._jnp.asarray(self._slab),
                self._jnp.asarray(self._slab_valid),
            )
        return self._device_slab

    # ---------------------------------------------------------------- search
    def search_substage(
        self, work: Sequence[tuple[np.ndarray, int, TopK]]
    ) -> tuple[list[TopK], SubstageTiming]:
        """Execute one sub-stage worth of (query, cluster, topk) items."""
        timing = SubstageTiming()
        out: list[Optional[TopK]] = [None] * len(work)
        host_items: list[int] = []
        dev_items: list[int] = []
        for i, (_, cid, _) in enumerate(work):
            (dev_items if self.cache.lookup(int(cid)) else host_items).append(i)

        if dev_items:
            t0 = time.perf_counter()
            self._device_search([work[i] for i in dev_items], [out, dev_items])
            timing.device_us = (time.perf_counter() - t0) * 1e6
            timing.n_device_items = len(dev_items)

        if host_items:
            t0 = time.perf_counter()
            res = self.index.search_cluster_batch([work[i] for i in host_items])
            for i, r in zip(host_items, res):
                out[i] = r
            timing.host_us = (time.perf_counter() - t0) * 1e6
            timing.n_host_items = len(host_items)

        self.cache.end_substage()
        return out, timing  # type: ignore[return-value]

    def _device_search(self, items, sink) -> None:
        """Pack resident-cluster items into (G, QB, d) groups + fused scan."""
        from repro.kernels.ivf_scan import ivf_scan

        out, idx_map = sink
        jnp = self._jnp
        slab, valid = self._device_arrays()
        k = max(it[2].k for it in items)

        # group by cluster slot, then chunk into QB-sized query groups
        by_slot: dict[int, list[int]] = {}
        for pos, (_, cid, _) in enumerate(items):
            by_slot.setdefault(self.cache.slot_of(int(cid)), []).append(pos)
        groups, gq, member = [], [], []
        for slot, positions in by_slot.items():
            for ofs in range(0, len(positions), QB):
                chunk = positions[ofs : ofs + QB]
                qs = np.zeros((QB, self.index.dim), np.float32)
                for r, p in enumerate(chunk):
                    qs[r] = items[p][0]
                groups.append(slot)
                gq.append(qs)
                member.append(chunk)
        q_groups = jnp.asarray(np.stack(gq))
        g_slot = jnp.asarray(np.array(groups, np.int32))
        dists, idx = ivf_scan(q_groups, g_slot, slab, valid, k, impl=self.kernel_impl)
        dists = np.asarray(dists)
        idx = np.asarray(idx)
        for g, chunk in enumerate(member):
            slot = groups[g]
            for r, p in enumerate(chunk):
                local = idx[g, r]
                ids = np.where(local >= 0, self._slab_ids[slot][np.maximum(local, 0)], -1)
                keep = ids >= 0
                tk = items[p][2]
                out[idx_map[p]] = tk.merge(dists[g, r][keep], ids[keep])

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "hit_rate": self.cache.stats.hit_rate,
            "hits": self.cache.stats.hits,
            "misses": self.cache.stats.misses,
            "swaps": self.cache.stats.swaps,
            "skew": self.cache.tracker.skewness_report(),
        }


def engine_from_memory_budget(
    index: IVFIndex,
    cache_bytes: int,
    **kw,
) -> HybridRetrievalEngine:
    sizes = index.cluster_sizes()
    tile_len = max(128, int(-(-sizes.max() // 128) * 128))
    cap = capacity_from_bytes(cache_bytes, tile_len, index.dim)
    cap = min(cap, index.n_clusters)
    return HybridRetrievalEngine(index, cache_capacity=cap, tile_len=tile_len, **kw)
