"""Partial device index cache with asynchronous updates (paper §4.4, C6).

The accelerator cannot hold the whole IVF index next to LM weights and KV
cache, but cluster access is heavily skewed (paper Fig. 8: top 20% of
clusters -> ~69% of compute).  We therefore cache the top-``gc`` hottest
clusters in device memory:

* access frequencies are tracked with an exponential moving average so the
  hot set adapts as workloads shift;
* the cached set is refreshed every ``update_interval`` sub-stages (50 in the
  paper) — *not* on demand — to avoid host<->device link contention;
* swaps are asynchronous: a cluster being loaded is "in transit" for
  ``transit_substages`` sub-stages, during which searches for it fall back to
  the host path (exactly the paper's rule);
* Eq. (2) picks the KV-cache size (and therefore the cache budget) by
  balancing generation vs retrieval throughput.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Access tracking
# ---------------------------------------------------------------------------


class AccessTracker:
    """Per-cluster EMA access frequency."""

    def __init__(self, n_clusters: int, decay: float = 0.98):
        self.freq = np.zeros(n_clusters, np.float64)
        self.decay = decay
        self.total_accesses = 0

    def record(self, cluster_ids: np.ndarray | list[int]) -> None:
        ids = np.asarray(cluster_ids, np.int64)
        np.add.at(self.freq, ids, 1.0)
        self.total_accesses += int(ids.size)

    def tick(self) -> None:
        self.freq *= self.decay

    def top(self, n: int) -> np.ndarray:
        n = min(n, self.freq.size)
        part = np.argpartition(-self.freq, n - 1)[:n]
        return part[np.argsort(-self.freq[part], kind="stable")]

    def skewness_report(self, fractions=(0.05, 0.1, 0.2, 0.5)) -> dict:
        """Fraction of accesses covered by the top-x%% clusters (Fig. 8)."""
        srt = np.sort(self.freq)[::-1]
        tot = max(srt.sum(), 1e-9)
        cum = np.cumsum(srt) / tot
        return {
            f"top_{int(f*100)}pct": float(cum[max(int(len(srt) * f) - 1, 0)])
            for f in fractions
        }


# ---------------------------------------------------------------------------
# The cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    transit_blocked: int = 0
    swaps: int = 0
    updates: int = 0
    # clusters refused residency because they exceed the device tile length
    # (they would be silently truncated on the device path)
    oversized_rejects: int = 0
    # items whose snapshot said device but whose cluster was swapped out
    # between dispatch and execution (host fallback, counted for honesty)
    stale_fallbacks: int = 0
    # extra (non-primary) replica copies staged for hot clusters when
    # popularity-aware replication is enabled
    replica_loads: int = 0

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


class HotClusterCache:
    """Device-resident cache of the hottest IVF clusters.

    ``loader(cid, slot)`` is called when a cluster becomes resident; in the
    real engine it stages the cluster tile into the cache slab (the device
    mirror is delta-updated lazily).  A loader may *refuse* a cluster by
    returning ``False`` — e.g. one larger than the device tile, which would
    be silently truncated — in which case the slot is released and the
    cluster stays on the host path (counted in ``stats.oversized_rejects``).
    Loads become *visible* only ``transit_substages`` sub-stages later.

    Cross-request extensions (``repro.crossreq``): ``replication > 1``
    stages the hottest clusters into multiple slots on distinct owner
    workers (slot *s* belongs to worker ``s % num_owners``) so the
    dispatcher can route hot-cluster sub-stages to any replica holder; a
    ``shared_tracker`` (the pool-global :class:`PopularityTracker`)
    supersedes the cache's own access EMA as the refresh ranking source.
    Both default off, leaving behaviour identical to the single-owner cache.
    """

    def __init__(
        self,
        n_clusters: int,
        capacity: int,
        *,
        update_interval: int = 50,
        transit_substages: int = 2,
        decay: float = 0.98,
        loader: Optional[Callable[[int, int], None]] = None,
        replication: int = 1,
        num_owners: int = 1,
        shared_tracker=None,
    ):
        self.tracker = AccessTracker(n_clusters, decay=decay)
        self.capacity = int(capacity)
        self.update_interval = update_interval
        self.transit_substages = transit_substages
        self.loader = loader
        self.replication = max(1, int(replication))
        self.num_owners = max(1, int(num_owners))
        # ticked by its owner (the scheduler), never by this cache
        self.shared_tracker = shared_tracker
        self.stats = CacheStats()
        # shard-mode ownership (set via set_shard_owner before any staging):
        # cluster ``c``'s primary copy may only be staged into a slot owned
        # by worker ``shard_owner[c]`` — each worker's slab partition holds
        # only its own shard (plus replicated hot clusters), which is what
        # cuts per-worker residency ~N x under index sharding
        self.shard_owner: Optional[np.ndarray] = None
        self._resident: dict[int, int] = {}  # cid -> primary slot
        self._replica_slots: dict[int, list[int]] = {}  # cid -> all slots
        self._transit: dict[int, int] = {}  # cid -> substages remaining
        # per-slot transit for *extra* replica copies: the primary stays
        # visible while a new replica pays the same staging latency
        self._slot_transit: dict[int, int] = {}
        self._refused: set[int] = set()  # loader-refused (e.g. oversized)
        self._free_slots = list(range(self.capacity))
        self._substage = 0

    def set_shard_owner(self, owner: np.ndarray, num_owners: int) -> None:
        """Enable shard-mode slot ownership: primary copies are constrained
        to their owning worker's slot partition.  Must be configured before
        any cluster is staged — re-partitioning a populated slab would
        silently orphan resident tiles."""
        if self._resident:
            raise RuntimeError(
                "set_shard_owner must be called before any cluster is staged")
        self.shard_owner = np.asarray(owner, np.int64)
        self.num_owners = max(1, int(num_owners))

    # ------------------------------------------------------------------ query
    def is_resident(self, cid: int) -> bool:
        return cid in self._resident and cid not in self._transit

    def slot_of(self, cid: int) -> int:
        return self._resident[cid]

    def lookup(self, cid: int) -> bool:
        """Record an access and return device-residency (False -> host path)."""
        self.tracker.record([cid])
        if cid in self._transit:
            self.stats.transit_blocked += 1
            self.stats.misses += 1
            return False
        if cid in self._resident:
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        return False

    def lookup_batch(self, cids: np.ndarray,
                     owner: Optional[int] = None) -> np.ndarray:
        """Vectorized ``lookup``: record all accesses at once and return a
        per-item residency bool (False -> host path).  Equivalent to calling
        ``lookup`` per item, without the Python loop over the tracker.
        ``owner`` (shard mode) counts hits against the executing worker's
        slot partition — a cluster resident only on another worker's slab
        is a miss for this worker, matching the executed partition."""
        ids = np.asarray(cids, np.int64)
        self.tracker.record(ids)
        if not self._resident and not self._transit:
            self.stats.misses += int(ids.size)
            return np.zeros(ids.shape, bool)
        mask = self.resident_mask(owner)
        res = mask[ids]
        transit = np.isin(ids, np.fromiter(self._transit, np.int64))
        self.stats.transit_blocked += int(transit.sum())
        self.stats.hits += int(res.sum())
        self.stats.misses += int(ids.size - res.sum())
        return res

    def resident_mask(self, owner: Optional[int] = None) -> np.ndarray:
        """Snapshot of device residency as a bool array over all clusters.
        Taken at sub-stage *assembly* time by the backends so that the
        charged duration and the executed host/device partition agree even
        when swaps land in between (see SimBackend.search_charged).

        With ``owner`` given (shard mode), a cluster counts as resident only
        when a *visible* staged copy lives on that worker's slot partition —
        workers see their own shard (plus replicated hot clusters), not the
        pool-global slab."""
        mask = np.zeros(self.tracker.freq.shape[0], bool)
        if owner is None:
            for cid in self._resident:
                if cid not in self._transit:
                    mask[cid] = True
            return mask
        for cid in self._replica_slots:
            if any(s % self.num_owners == owner
                   for s in self._visible_slots(cid)):
                mask[cid] = True
        return mask

    def slot_on_owner(self, cid: int, owner: int) -> Optional[int]:
        """Visible staged slot of ``cid`` on worker ``owner``'s partition,
        or None — shard-mode slot resolution for the device scan path."""
        for s in self._visible_slots(cid):
            if s % self.num_owners == owner:
                return s
        return None

    def per_owner_resident(self) -> dict[int, int]:
        """Visible staged copies per owner worker — the per-worker device
        residency figure the shard-mode benchmarks/tests report."""
        out = {w: 0 for w in range(self.num_owners)}
        for cid in self._replica_slots:
            for s in self._visible_slots(cid):
                out[s % self.num_owners] += 1
        return out

    @property
    def resident_ids(self) -> list[int]:
        return [c for c in self._resident if c not in self._transit]

    def _visible_slots(self, cid: int) -> list[int]:
        """Visible staged slots of ``cid`` (primary first): none while the
        primary load is in transit, and individual replica copies in slot
        transit are excluded.  The single home of the visibility rule —
        every residency/routing accessor builds on it."""
        if cid in self._transit:
            return []
        return [s for s in self._replica_slots.get(cid, ())
                if s not in self._slot_transit]

    def replica_slots(self) -> dict[int, list[int]]:
        """cid -> *visible* staged slots (primary first).  Clusters whose
        primary load is still in transit, and individual replica copies in
        slot transit, are excluded — visibility semantics live in
        ``_visible_slots``, not in the callers."""
        return {
            cid: self._visible_slots(cid)
            for cid in self._replica_slots
            if cid not in self._transit
        }

    def replica_owners(self, cid: int) -> list[int]:
        """Distinct owner workers holding a *visible* copy of ``cid``."""
        return sorted({s % self.num_owners
                       for s in self._visible_slots(cid)})

    @property
    def replicated_ids(self) -> list[int]:
        """Visible clusters staged on two or more distinct owners."""
        return [c for c in self._replica_slots
                if len(self.replica_owners(c)) > 1]

    # ------------------------------------------------------------------- tick
    def end_substage(self) -> None:
        """Advance one sub-stage: progress transits, maybe refresh hot set."""
        self._substage += 1
        done = []
        for cid in list(self._transit):
            self._transit[cid] -= 1
            if self._transit[cid] <= 0:
                done.append(cid)
        for cid in done:
            del self._transit[cid]
        for slot in list(self._slot_transit):
            self._slot_transit[slot] -= 1
            if self._slot_transit[slot] <= 0:
                del self._slot_transit[slot]
        self.tracker.tick()
        if self.capacity and self._substage % self.update_interval == 0:
            self._refresh()

    def _want_copies(self, ranked: list[int]) -> dict[int, int]:
        """Desired copies per cluster under the capacity budget: with
        replication the hottest ``capacity // (2*replication)`` clusters get
        ``replication`` copies (on distinct owners), the rest one.  Copies
        beyond the number of distinct owners add no routable holder, so the
        factor is clamped to ``num_owners``."""
        r = max(1, min(self.replication, self.num_owners))
        if r == 1:
            return {c: 1 for c in ranked[: self.capacity]}
        n_hot = max(1, self.capacity // (2 * r))
        want: dict[int, int] = {}
        budget = self.capacity
        for i, c in enumerate(ranked):
            n = min(r if i < n_hot else 1, budget)
            if n <= 0:
                break
            want[c] = n
            budget -= n
        return want

    def _take_slot(self, used_owners: set[int],
                   require_distinct: bool = False) -> Optional[int]:
        """Pop a free slot on an owner not yet holding a copy of the
        cluster (so replicas spread across workers).  With
        ``require_distinct`` (extra replica copies), returns None instead
        of falling back to a same-owner slot — such a copy would pin
        capacity without adding a routable holder."""
        if self.num_owners > 1 and used_owners:
            for i in range(len(self._free_slots) - 1, -1, -1):
                if self._free_slots[i] % self.num_owners not in used_owners:
                    return self._free_slots.pop(i)
            if require_distinct:
                return None
        return self._free_slots.pop()

    def _take_owner_slot(self, owner: int) -> Optional[int]:
        """Pop a free slot on exactly ``owner``'s partition (shard-mode
        primary staging), or None when that worker's slots are full."""
        for i in range(len(self._free_slots) - 1, -1, -1):
            if self._free_slots[i] % self.num_owners == owner:
                return self._free_slots.pop(i)
        return None

    def _refresh(self) -> None:
        self.stats.updates += 1
        # refused clusters (e.g. oversized for the device tile) are excluded
        # from candidacy so they are rejected at most once and the slot they
        # would pin goes to the next-hottest loadable cluster instead;
        # ranking comes from the pool-shared tracker when one is attached
        src = self.shared_tracker if self.shared_tracker is not None else self.tracker
        ranked = [int(c) for c in
                  src.top(self.capacity + len(self._refused))
                  if int(c) not in self._refused]
        want = self._want_copies(ranked)
        have = set(self._resident)
        evict = list(have - set(want))
        # evict first to free slots; eviction is instantaneous (drop only)
        for cid in evict:
            for slot in self._replica_slots.pop(cid, [self._resident[cid]]):
                self._free_slots.append(slot)
                self._slot_transit.pop(slot, None)
            self._resident.pop(cid)
            self._transit.pop(cid, None)
        # trim excess copies of clusters that cooled below the hot cut
        for cid, slots in self._replica_slots.items():
            keep = want.get(cid, 1)
            while len(slots) > keep:
                slot = slots.pop()
                self._free_slots.append(slot)
                self._slot_transit.pop(slot, None)
        # stage missing copies, hottest first (dict preserves ranked order)
        for cid, copies in want.items():
            slots = self._replica_slots.get(cid, [])
            fresh = cid not in have
            while len(slots) < copies:
                if not self._free_slots:
                    return
                owners = {s % self.num_owners for s in slots}
                if self.shard_owner is not None and not slots:
                    # shard mode: the primary copy must live on the owning
                    # worker's slot partition; a full partition keeps the
                    # cluster host-side this round (other shards' slots
                    # stay available to their own clusters)
                    slot = self._take_owner_slot(int(self.shard_owner[cid]))
                else:
                    slot = self._take_slot(owners, require_distinct=bool(slots))
                if slot is None:
                    break  # no eligible slot free: skip the copy
                if self.loader is not None and self.loader(cid, slot) is False:
                    # loader refused: release the slot, remember the refusal,
                    # keep the cluster on the host path permanently
                    self._free_slots.append(slot)
                    self._refused.add(cid)
                    self.stats.oversized_rejects += 1
                    break
                slots.append(slot)
                self._replica_slots[cid] = slots
                if fresh and len(slots) == 1:
                    self._resident[cid] = slot
                    self._transit[cid] = self.transit_substages
                else:
                    # extra replica: the primary stays visible, the new copy
                    # pays the same staging latency before it is routable
                    self._slot_transit[slot] = self.transit_substages
                    self.stats.replica_loads += 1
                self.stats.swaps += 1


# ---------------------------------------------------------------------------
# Eq. (2): KV-cache vs index-cache memory split
# ---------------------------------------------------------------------------


def plan_memory_split(
    total_bytes: int,
    *,
    t_gen: Callable[[int, float], float],
    t_ret: Callable[[float], float],
    rps_g: float,
    rps_r: float,
    kv_candidates: list[int],
) -> tuple[int, int]:
    """argmax_{KV_size} min{ T_G(KV_size, rps_G), T_R(rps_R) }   (paper Eq. 2)

    Returns (kv_bytes, index_cache_bytes).  ``t_gen``/``t_ret`` come from
    offline characterisation (benchmarks/bench_engines.py writes the tables).
    Ties break toward the *smallest* KV size — leftover memory is worth more
    as index cache.
    """
    tr = t_ret(rps_r)
    best = None
    for kv in sorted(c for c in kv_candidates if c <= total_bytes):
        score = min(t_gen(kv, rps_g), tr)
        if best is None or score > best[0] + 1e-12:
            best = (score, kv)
    if best is None:
        kv = min(kv_candidates)
        return kv, max(total_bytes - kv, 0)
    return best[1], total_bytes - best[1]


def capacity_from_bytes(cache_bytes: int, tile_len: int, dim: int,
                        dtype_bytes: int = 4) -> int:
    """How many cluster tiles fit in the index-cache budget."""
    per = tile_len * dim * dtype_bytes
    return max(cache_bytes // per, 0) if per else 0
