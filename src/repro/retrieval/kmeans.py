"""Mini-batch-free Lloyd k-means in JAX (IVF training).

Chunked assignment keeps the (N, K) distance matrix out of memory; the whole
update is jitted with a fori_loop so index training for ~1e5..1e6 vectors
stays fast on CPU and trivially maps to TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@partial(jax.jit, static_argnames=("chunk",))
def assign_clusters(data: jax.Array, centroids: jax.Array, chunk: int = 8192):
    """Returns (assignment (N,), min_dist (N,)) via chunked L2 argmin."""
    N, d = data.shape
    K = centroids.shape[0]
    pad = (-N) % chunk
    dp = jnp.pad(data, ((0, pad), (0, 0)))
    nchunks = dp.shape[0] // chunk
    c_sq = (centroids.astype(jnp.float32) ** 2).sum(-1)

    def body(i, acc):
        asn, dist = acc
        x = lax.dynamic_slice_in_dim(dp, i * chunk, chunk, axis=0).astype(jnp.float32)
        d2 = (x**2).sum(-1, keepdims=True) - 2.0 * x @ centroids.T.astype(jnp.float32) + c_sq
        a = jnp.argmin(d2, axis=-1).astype(jnp.int32)
        m = jnp.min(d2, axis=-1)
        asn = lax.dynamic_update_slice_in_dim(asn, a, i * chunk, axis=0)
        dist = lax.dynamic_update_slice_in_dim(dist, m, i * chunk, axis=0)
        return asn, dist

    asn = jnp.zeros((dp.shape[0],), jnp.int32)
    dist = jnp.zeros((dp.shape[0],), jnp.float32)
    asn, dist = lax.fori_loop(0, nchunks, body, (asn, dist))
    return asn[:N], dist[:N]


@partial(jax.jit, static_argnames=("k", "iters", "chunk"))
def kmeans(key: jax.Array, data: jax.Array, k: int, iters: int = 10, chunk: int = 8192):
    """Lloyd iterations; dead centroids re-seeded from random points.

    Returns (centroids (k, d), assignment (N,)).
    """
    N, d = data.shape
    idx = jax.random.choice(key, N, shape=(k,), replace=False)
    cent = data[idx].astype(jnp.float32)

    def step(i, cent):
        asn, _ = assign_clusters(data, cent, chunk=chunk)
        sums = jax.ops.segment_sum(data.astype(jnp.float32), asn, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones((N,), jnp.float32), asn, num_segments=k)
        new = sums / jnp.maximum(cnts, 1.0)[:, None]
        # reseed empties deterministically from data points
        reseed = data[(idx + i) % N].astype(jnp.float32)
        return jnp.where((cnts > 0)[:, None], new, reseed)

    cent = lax.fori_loop(0, iters, step, cent)
    asn, _ = assign_clusters(data, cent, chunk=chunk)
    return cent, asn
