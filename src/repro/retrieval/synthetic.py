"""Synthetic corpus + embedder with controllable RAG phenomenology.

The paper measures three workload phenomena on Wikipedia/e5-large that drive
its optimizations; offline we reproduce each with explicit knobs:

* **cluster access skew** (Fig. 8): topics drawn from a Zipf distribution so
  a small subset of IVF clusters absorbs most probes;
* **inter-retrieval similarity** (Fig. 7a): successive queries of one request
  are a bounded random walk around the request's topic vector;
* **intra-generation similarity** (Fig. 7b): the embedding of a partial
  generation converges to the final generation embedding as the prefix ratio
  grows.

Real-corpus integration point: anything implementing ``Embedder`` can replace
``SyntheticEmbedder`` (e.g. an e5 checkpoint wrapped in a jitted encoder).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

import numpy as np


class Embedder(Protocol):
    dim: int

    def embed_query(self, request_id: int, round_idx: int) -> np.ndarray: ...

    def embed_partial(self, request_id: int, round_idx: int, ratio: float) -> np.ndarray: ...


def _unit(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


@dataclasses.dataclass
class CorpusConfig:
    n_docs: int = 100_000
    dim: int = 256
    n_topics: int = 512
    zipf_alpha: float = 1.1      # topic popularity skew
    doc_noise: float = 0.35      # doc spread around its topic vector
    seed: int = 0


def make_corpus(cfg: CorpusConfig) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (doc_vectors (N, d) f32 unit-norm, doc_topic (N,), topic_vecs)."""
    rng = np.random.default_rng(cfg.seed)
    topics = _unit(rng.standard_normal((cfg.n_topics, cfg.dim)).astype(np.float32))
    # Zipf-ish popularity over topics
    ranks = np.arange(1, cfg.n_topics + 1, dtype=np.float64)
    pops = ranks ** (-cfg.zipf_alpha)
    pops /= pops.sum()
    doc_topic = rng.choice(cfg.n_topics, size=cfg.n_docs, p=pops).astype(np.int32)
    docs = topics[doc_topic] + cfg.doc_noise * rng.standard_normal(
        (cfg.n_docs, cfg.dim)
    ).astype(np.float32)
    return _unit(docs).astype(np.float32), doc_topic, topics


@dataclasses.dataclass
class DuplicateTrafficEmbedder:
    """Wrapper modelling cross-user duplicate/near-duplicate query traffic.

    A ``dup_ratio`` fraction of requests re-issue a *canonical* query drawn
    Zipf-style from a small trending pool (the inter-request skewness of
    paper §4.4 applied to the query stream itself — at millions of users,
    lookalike queries are the common case).  ``near_jitter > 0`` perturbs
    duplicates into near-duplicates with a controlled cosine distance, which
    is what the crossreq dedup threshold and the global cache's ball-bound
    answers are calibrated against.

    ``canonical_id`` is exposed so workloads can keep duplicate requests on
    the same workflow (same query -> same pipeline) and benchmarks can
    assert fused answers against independently executed searches.
    """

    base: "Embedder"
    dup_ratio: float = 0.3
    pool_size: int = 8
    near_jitter: float = 0.0
    zipf_alpha: float = 1.1
    seed: int = 77

    # canonical queries live in a reserved request-id space far above any
    # real request id, so they never collide with organic traffic
    _POOL_BASE = 10_000_000

    def __post_init__(self):
        self.dim = self.base.dim
        ranks = np.arange(1, self.pool_size + 1, dtype=np.float64)
        pops = ranks ** (-self.zipf_alpha)
        self._pops = pops / pops.sum()

    def canonical_id(self, request_id: int) -> int:
        """The id whose query stream this request re-issues (itself when the
        request is organic, a pool id when it is duplicate traffic)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, request_id]))
        if rng.random() < self.dup_ratio:
            return self._POOL_BASE + int(
                rng.choice(self.pool_size, p=self._pops))
        return request_id

    def is_duplicate(self, request_id: int) -> bool:
        return self.canonical_id(request_id) != request_id

    def _jitter(self, vec: np.ndarray, request_id: int, tag: int) -> np.ndarray:
        if self.near_jitter <= 0.0:
            return vec
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, request_id, tag]))
        noise = rng.standard_normal(self.dim) / np.sqrt(self.dim)
        return _unit((vec + self.near_jitter * noise)[None, :].astype(np.float32))[0]

    def embed_query(self, request_id: int, round_idx: int) -> np.ndarray:
        cid = self.canonical_id(request_id)
        v = self.base.embed_query(cid, round_idx)
        if cid == request_id:
            return v
        return self._jitter(v, request_id, 300 + round_idx)

    def embed_partial(self, request_id: int, round_idx: int, ratio: float) -> np.ndarray:
        cid = self.canonical_id(request_id)
        v = self.base.embed_partial(cid, round_idx, ratio)
        if cid == request_id:
            return v
        return self._jitter(v, request_id, 400 + round_idx)


@dataclasses.dataclass
class SyntheticEmbedder:
    """Per-request query/generation embedding process (see module docstring).

    inter_drift:  distance between consecutive round queries (Fig. 7a knob)
    partial_noise: residual distance of a ratio-r partial generation to the
                   final generation embedding decays as (1-r)**decay_pow.
    """

    topic_vecs: np.ndarray
    zipf_alpha: float = 1.1
    inter_drift: float = 0.25
    query_noise: float = 0.30
    partial_noise: float = 0.8
    decay_pow: float = 1.5
    seed: int = 1234

    def __post_init__(self):
        self.dim = int(self.topic_vecs.shape[1])
        n_topics = self.topic_vecs.shape[0]
        ranks = np.arange(1, n_topics + 1, dtype=np.float64)
        pops = ranks ** (-self.zipf_alpha)
        self._pops = pops / pops.sum()

    def _rng(self, request_id: int, tag: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, request_id, tag])
        )

    def request_topic(self, request_id: int) -> int:
        rng = self._rng(request_id, 0)
        return int(rng.choice(len(self._pops), p=self._pops))

    def embed_query(self, request_id: int, round_idx: int) -> np.ndarray:
        """Round-r retrieval query: random walk around the request topic."""
        t = self.request_topic(request_id)
        base = self.topic_vecs[t]
        rng0 = self._rng(request_id, 1)
        anchor = base + self.query_noise * rng0.standard_normal(self.dim)
        # bounded random walk: each round drifts by inter_drift from previous
        walk = np.zeros(self.dim, np.float64)
        for r in range(1, round_idx + 1):
            step = self._rng(request_id, 100 + r).standard_normal(self.dim)
            walk += self.inter_drift * step / np.sqrt(self.dim) * np.linalg.norm(anchor)
        return _unit((anchor + walk)[None, :].astype(np.float32))[0]

    def embed_partial(self, request_id: int, round_idx: int, ratio: float) -> np.ndarray:
        """Embedding of a partial generation with prefix ratio in [0, 1]."""
        final = self.embed_query(request_id, round_idx)
        resid = self._rng(request_id, 200 + round_idx).standard_normal(self.dim)
        amp = self.partial_noise * (1.0 - min(max(ratio, 0.0), 1.0)) ** self.decay_pow
        return _unit((final + amp * resid / np.sqrt(self.dim) * np.linalg.norm(final))[None, :])[0]
