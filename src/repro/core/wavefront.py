"""Wavefront scheduler + hybrid serving loop (paper §4.5, §5).

The loop models the paper's runtime: a *generation worker* (accelerator) and
a pool of ``num_ret_workers`` *retrieval workers* (host) execute
concurrently; whenever one goes idle the scheduler traverses the RAGraphs of
all in-flight requests in SLO-slack order, selects the next wavefront of
ready sub-nodes, applies graph transformations (split under the Eq.1 budget,
similarity reordering, speculative edges), and dispatches the transformed
sub-nodes to that worker's queue — retrieval sub-stages are placed by the
skew-aware policy in serving/dispatch.py (cluster affinity / least-loaded /
round-robin).  Time is tracked event-driven (worker completion / request
arrival), so baselines with coarse stages show their real head-of-line
blocking and the fine-grained mode shows real overlap — on any host,
including this single-CPU container, because work is *executed* exactly and
*charged* through the backend's per-worker timing model.

Modes (paper baselines, same loop, different policy switches):
  sequential  LangChain-like: whole-stage retrieval jobs, FIFO one at a time
  async       FlashRAG-like: whole-stage jobs, one-shot batch of all queued
  hedra       sub-stage splitting + dynamic batching + reorder/cache/spec +
              hot-cache device path
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core import stages
from repro.core.ownership import handoff, owned_by
from repro.core.runtime import RequestContext, RuntimeDAG
from repro.core.similarity import LocalCache
from repro.core.speculation import SpeculationPolicy, Speculator
from repro.core.substage import TimeBudget
from repro.core import transforms
from repro.retrieval.ivf import TopK
from repro.retrieval.plan import (
    BatchTopK,
    PlanBuilder,
    gather_scatter_rows,
    make_gather_plan,
)
from repro.serving import dispatch as dispatch_mod
from repro.serving import lifecycle as lifecycle_mod

SPEC_RET_K = 20  # top-k width of speculative LocalCache warmups (paper k')


@dataclasses.dataclass
class SchedulerConfig:
    mode: str = "hedra"  # hedra | async | sequential
    nprobe: int = 64
    topk: int = 5
    enable_substage: bool = True
    enable_reorder: bool = True
    enable_early_term: bool = True
    early_term_mode: str = "heuristic"  # heuristic (paper) | lossless
    early_term_patience: int = 4  # clusters without top-k improvement
    enable_cache_answer: bool = True
    speculation: SpeculationPolicy = dataclasses.field(default_factory=SpeculationPolicy)
    max_gen_batch: int = 64
    sched_overhead_us: float = 120.0
    straggler_redispatch: bool = True
    straggler_cap: float = 2.0  # re-dispatch when > cap x expected
    slo_us: float = 10e6  # default; overridden per-request via RequestContext
    num_ret_workers: int = 1
    dispatch_policy: str = "affinity"  # affinity | least_loaded | round_robin
    # --- cross-request coordination (repro.crossreq); all off by default,
    # in which case serving results are bit-identical to the uncoordinated
    # loop.  global_cache_size > 0 enables the shared semantic cache;
    # dedup_threshold > 0 enables in-flight query fusion in hedra mode
    # (1.0 = exact duplicates only, < 1.0 adds cosine-similar
    # near-duplicates, which are answered from the leader's result like an
    # O1 cache answer and are additionally gated by enable_cache_answer);
    # replication_factor > 1 replicates hot clusters across workers and
    # routes to replica holders (affinity policy, num_ret_workers > 1).
    global_cache_size: int = 0
    dedup_threshold: float = 0.0
    replication_factor: int = 1
    # --- streaming admission control (serving/dispatch.AdmissionController);
    # both off by default, in which case the pre-loaded batch path is
    # bit-identical to the legacy run-to-completion loop.  max_pending bounds
    # the arrival queue (0 = unbounded); admission_control additionally sheds
    # requests whose remaining SLO slack cannot cover a cost-model lower
    # bound of one pass over their graph, scaled by shed_margin.
    max_pending: int = 0
    admission_control: bool = False
    shed_margin: float = 1.0
    # --- distributed (shard-mode) retrieval: each retrieval worker owns a
    # contiguous cluster-range shard of the IVF table (retrieval.distributed
    # .ShardMap, balanced by vector mass); retrieval sub-stages are split by
    # owning shard into independent scatter tasks and the scheduler k-way
    # merges the partial top-k sets at completion — bit-identical to the
    # whole-index fold.  Off by default, in which case dispatch assumes
    # every worker sees the whole index and the serving path is
    # bit-identical to the unsharded loop.  shard_merge_us is the
    # cost-model charge per partial set folded at gather time (admission /
    # slack estimates model shard-mode service as max-over-shards + merge,
    # not a sum).
    index_sharding: bool = False
    shard_merge_us: float = 40.0
    # --- fault tolerance (serving/lifecycle.py + serving/faults.py): the
    # worker registry is always built (drain/rebind are operational APIs);
    # the *recovery* layer — per-job deadlines, retry/backoff of transiently
    # failed units, hedged duplicates for SUSPECT stragglers, shard failover
    # and degraded completion — activates when fault_tolerance is on or the
    # backend carries a FaultPlan.  With neither, the serving path is
    # bit-identical to the fault-unaware loop.  suspect/dead thresholds are
    # heartbeat-gap cutoffs on the virtual clock; timeout_factor scales the
    # cost-model charge into a per-job deadline; retry_budget bounds
    # re-dispatches per (request, node) before the unit completes degraded;
    # retry_backoff_us doubles per attempt; hedge_suspect duplicates
    # in-flight work of SUSPECT workers (first result wins);
    # failover_whole_index lets orphaned shard parts run on any serving
    # worker when no replica covers them (off: such parts degrade).
    fault_tolerance: bool = False
    # wall-clock serving (serving/ingress.py): heartbeats arrive as ingress
    # rows (Server.heartbeat_worker) instead of the always-fresh virtual
    # model, so real heartbeat gaps drive SUSPECT/DEAD detection.  Off by
    # default — with it off (and no FaultPlan) nothing ever transitions and
    # the loop is bit-identical to the heartbeat-unaware path.
    external_heartbeats: bool = False
    heartbeat_interval_us: float = 50_000.0
    suspect_after_us: float = 150_000.0
    dead_after_us: float = 400_000.0
    timeout_factor: float = 4.0
    retry_budget: int = 3
    retry_backoff_us: float = 20_000.0
    hedge_suspect: bool = True
    failover_whole_index: bool = True
    # --- observability (obs/): both layers are passive read-only taps —
    # enabling them changes no scheduling decision, RNG draw, or per-request
    # event log, so traces stay bit-identical to the knobs-off goldens.
    # tracing feeds an obs.trace.TraceRecorder (per-resource spans + flow
    # edges, exported as Chrome trace-event / Perfetto JSON and decomposed
    # by obs.attribution); telemetry attaches an
    # obs.registry.TelemetrySampler that samples queue depth, per-worker
    # utilization and lifecycle states every telemetry_interval_us of
    # virtual time into a labeled Prometheus-style registry.
    tracing: bool = False
    telemetry: bool = False
    telemetry_interval_us: float = 50_000.0

    @classmethod
    def preset(cls, mode: str, **kw) -> "SchedulerConfig":
        if mode == "hedra":
            return cls(mode="hedra", **kw)
        if mode == "async":
            base = dict(enable_substage=False, enable_reorder=False,
                        enable_early_term=False, enable_cache_answer=False,
                        speculation=SpeculationPolicy(mode="off"))
            base.update(kw)
            return cls(mode="async", **base)
        if mode == "sequential":
            base = dict(enable_substage=False, enable_reorder=False,
                        enable_early_term=False, enable_cache_answer=False,
                        speculation=SpeculationPolicy(mode="off"))
            base.update(kw)
            return cls(mode="sequential", **base)
        raise ValueError(mode)


# version of the summary()/window_summary() dict schema (bumped when keys
# are added/renamed/removed); documented in benchmarks/README.md
SUMMARY_SCHEMA_VERSION = 3


def _lat_ms(lat: "np.ndarray", q=None) -> float:
    """Latency statistic in milliseconds with the NaN-on-empty convention:
    ``q`` is a percentile (e.g. 50, 95), or None for the mean."""
    if not lat.size:
        return float("nan")
    v = lat.mean() if q is None else np.percentile(lat, q)
    return float(v / 1e3)


@dataclasses.dataclass
class Metrics:
    latencies_us: list = dataclasses.field(default_factory=list)
    finished: int = 0
    sim_time_us: float = 0.0
    gen_busy_us: float = 0.0
    # one slot per retrieval worker; ret_busy_us (total) is derived
    ret_busy_per_worker: list = dataclasses.field(default_factory=lambda: [0.0])
    gen_tokens: int = 0
    substages_gen: int = 0
    substages_ret: int = 0
    cache_answers: int = 0
    early_terms: int = 0
    reorders: int = 0
    spec_gen_attempts: int = 0
    spec_gen_validated: int = 0
    spec_gen_rollbacks: int = 0
    spec_ret_launches: int = 0
    straggler_redispatches: int = 0
    slo_violations: int = 0
    # cross-request coordination counters (all zero with crossreq disabled)
    global_cache_answers: int = 0
    global_cache_seeds: int = 0
    dedup_exact: int = 0
    dedup_near: int = 0
    dedup_fanout: int = 0
    dedup_saved_us: float = 0.0
    replica_routes: int = 0
    # hybrid-engine CacheStats snapshot, populated at the end of run()
    cache_stats: dict = dataclasses.field(default_factory=dict)
    # streaming admission + per-finish log: (finish_us, latency_us, under_slo)
    # rows power the window-based rates that exclude idle warmup/drain time
    submitted: int = 0
    shed_queue_full: int = 0
    shed_infeasible: int = 0
    # ingress re-admission accounting (serving/ingress.py closed loop): a
    # logical request's *first* shed bumps shed_*; every later attempt bumps
    # resubmissions only, and the attempt that finally lands bumps
    # shed_readmitted — so shed_final (= shed - shed_readmitted) counts
    # requests that actually left the system and the conservation identity
    # offered = submitted + shed_final holds with submitted = finished +
    # in_flight (each logical request is counted in exactly one bucket)
    resubmissions: int = 0
    shed_readmitted: int = 0
    finish_log: list = dataclasses.field(default_factory=list)
    # shard-mode scatter-gather counters (all zero with sharding disabled)
    shard_scatters: int = 0  # sub-stages split across shards
    shard_parts: int = 0  # partial scan tasks dispatched
    shard_merges: int = 0  # k-way gather merges completed
    # generic registry host stages (rerank / rewrite / compress / ...)
    stage_tasks: int = 0  # dispatched stage work batches / variant scans
    lexical_fusions: int = 0  # hybrid dense+lexical RRF folds applied
    # fault-tolerance counters (all zero with no faults and knobs off)
    worker_suspects: int = 0  # HEALTHY -> SUSPECT transitions
    worker_deaths: int = 0  # transitions into DEAD
    task_timeouts: int = 0  # jobs past their cost-model deadline
    redispatches: int = 0  # units lost on a dead worker, re-dispatched
    retries: int = 0  # transiently failed units re-dispatched
    transient_failures: int = 0  # injected transient unit failures observed
    hedged_dispatches: int = 0  # units duplicated onto idle workers
    hedged_wins: int = 0  # units completed by the hedge copy first
    failovers: int = 0  # shard parts routed off their dead/drained owner
    degraded_drops: int = 0  # units dropped after budget/coverage exhaustion
    degraded_completions: int = 0  # requests finished with partial results

    @property
    def ret_busy_us(self) -> float:
        return float(sum(self.ret_busy_per_worker))

    @property
    def shed(self) -> int:
        return self.shed_queue_full + self.shed_infeasible

    @property
    def shed_final(self) -> int:
        """Logical requests shed and never successfully re-admitted."""
        return self.shed - self.shed_readmitted

    # ------------------------------------------------------ windowed rates
    def window_summary(self, start_us: float, end_us: float) -> dict:
        """Rates/percentiles over finishes with ``start_us <= t < end_us``.

        ``summary()``'s ``throughput_rps`` divides by the *whole* simulated
        span including idle warmup and drain, which understates steady-state
        rates of streaming runs; this window variant is the streaming-side
        counterpart (goodput = finished under SLO per second)."""
        span = max(float(end_us) - float(start_us), 1e-9)
        rows = [f for f in self.finish_log if start_us <= f[0] < end_us]
        lat = np.asarray([l for _, l, _ in rows], np.float64)
        good = sum(1 for _, _, u in rows if u)
        out = {
            "schema_version": SUMMARY_SCHEMA_VERSION,
            "window_start_us": float(start_us),
            "window_end_us": float(end_us),
            "finished": len(rows),
            "finished_under_slo": int(good),
            "throughput_rps": len(rows) / (span / 1e6),
            "goodput_rps": good / (span / 1e6),
            "p50_latency_ms": _lat_ms(lat, 50),
            "p95_latency_ms": _lat_ms(lat, 95),
        }
        return {k: out[k] for k in sorted(out)}

    def goodput_timeline(self, window_us: float, step_us: float = 0.0) -> list:
        """Sliding-window goodput samples ``[(t_end_us, goodput_rps), ...]``
        stepping the window end by ``step_us`` (default: half a window) over
        the span of the finish log."""
        if not self.finish_log:
            return []
        window_us = float(window_us)
        step = float(step_us) if step_us > 0 else window_us / 2.0
        t0 = min(f[0] for f in self.finish_log)
        t1 = max(f[0] for f in self.finish_log)
        out = []
        t = t0 + window_us
        # at least one window even when the finish span is shorter than the
        # window — an empty list would be indistinguishable from no goodput
        t_end = max(t1 + step, t0 + window_us)
        while t <= t_end:
            good = sum(1 for f in self.finish_log
                       if t - window_us <= f[0] < t and f[2])
            out.append((float(t), good / (window_us / 1e6)))
            t += step
        return out

    def summary(self) -> dict:
        lat = np.asarray(self.latencies_us, np.float64)
        t = max(self.sim_time_us, 1e-9)
        per = np.asarray(self.ret_busy_per_worker or [0.0], np.float64)
        util = per / t
        # steady-state window: [first finish, last finish) + the last finish
        # itself — excludes the idle warmup before the first completion and
        # any drain after the last one (a batch run with a single burst sees
        # roughly the same span as the legacy whole-run rates).  A
        # degenerate span (every finish at one event instant, e.g. one
        # generation batch completing together) has no meaningful rate —
        # fall back to the whole-run figures instead of dividing by ~0.
        if len(self.finish_log) >= 2:
            f0 = min(f[0] for f in self.finish_log)
            f1 = max(f[0] for f in self.finish_log)
            steady = (self.window_summary(f0, np.nextafter(f1, np.inf))
                      if f1 > f0 else None)
        else:
            steady = None
        good = sum(1 for _, _, u in self.finish_log if u)
        out = {
            "schema_version": SUMMARY_SCHEMA_VERSION,
            "finished": self.finished,
            "avg_latency_ms": _lat_ms(lat),
            "p50_latency_ms": _lat_ms(lat, 50),
            "p95_latency_ms": _lat_ms(lat, 95),
            "throughput_rps": self.finished / (t / 1e6),
            "goodput_rps": good / (t / 1e6),
            "steady_throughput_rps": steady["throughput_rps"]
            if steady else self.finished / (t / 1e6),
            "steady_goodput_rps": steady["goodput_rps"]
            if steady else good / (t / 1e6),
            "submitted": self.submitted,
            "shed": self.shed,
            "shed_queue_full": self.shed_queue_full,
            "shed_infeasible": self.shed_infeasible,
            "resubmissions": self.resubmissions,
            "shed_readmitted": self.shed_readmitted,
            "shed_final": self.shed_final,
            "gen_util": self.gen_busy_us / t,
            "num_ret_workers": int(per.size),
            "ret_util": float(util.mean()),
            "ret_util_min": float(util.min()),
            "ret_util_max": float(util.max()),
            "ret_worker_skew": float(util.max() / util.mean())
            if util.mean() > 0 else 1.0,
            "gen_tokens": self.gen_tokens,
            "substages_gen": self.substages_gen,
            "substages_ret": self.substages_ret,
            "cache_answers": self.cache_answers,
            "early_terms": self.early_terms,
            "spec_gen_attempts": self.spec_gen_attempts,
            "spec_gen_validated": self.spec_gen_validated,
            "spec_gen_rollbacks": self.spec_gen_rollbacks,
            "spec_ret_launches": self.spec_ret_launches,
            "straggler_redispatches": self.straggler_redispatches,
            "slo_violations": self.slo_violations,
            "global_cache_answers": self.global_cache_answers,
            "global_cache_seeds": self.global_cache_seeds,
            "dedup_exact": self.dedup_exact,
            "dedup_near": self.dedup_near,
            "dedup_fanout": self.dedup_fanout,
            "dedup_saved_ms": float(self.dedup_saved_us / 1e3),
            "replica_routes": self.replica_routes,
            "shard_scatters": self.shard_scatters,
            "shard_parts": self.shard_parts,
            "shard_merges": self.shard_merges,
            "stage_tasks": self.stage_tasks,
            "lexical_fusions": self.lexical_fusions,
            "worker_suspects": self.worker_suspects,
            "worker_deaths": self.worker_deaths,
            "task_timeouts": self.task_timeouts,
            "redispatches": self.redispatches,
            "retries": self.retries,
            "transient_failures": self.transient_failures,
            "hedged_dispatches": self.hedged_dispatches,
            "hedged_wins": self.hedged_wins,
            "failovers": self.failovers,
            "degraded_drops": self.degraded_drops,
            "degraded_completions": self.degraded_completions,
            # hybrid-engine counters, surfaced so benches/--json records see
            # them without reaching into the backend
            "cache_hit_rate": float(self.cache_stats.get("hit_rate", 0.0)),
            "cache_oversized_rejects": int(
                self.cache_stats.get("oversized_rejects", 0)),
            "cache_stale_fallbacks": int(
                self.cache_stats.get("stale_fallbacks", 0)),
            "cache_replica_loads": int(
                self.cache_stats.get("replica_loads", 0)),
            "cache_replicated_clusters": int(
                self.cache_stats.get("replicated_clusters", 0)),
        }
        # deterministic key order: consumers diffing two summaries (or
        # serializing to JSON without sort_keys) see a stable layout
        return {k: out[k] for k in sorted(out)}


@dataclasses.dataclass
class _ShardGather:
    """One in-flight scatter set: a retrieval sub-stage split into per-shard
    partial scans.  Each completing part writes its item rows into ``board``
    (original probe order); when the last part lands, ``plan`` — the
    one-group whole-index replay plan carrying the stage's seed top-k and
    early-termination streak state — folds the board, so the merged result
    is bit-identical to a single worker scanning the whole probe list."""

    req: RequestContext
    sn: object  # runtime-DAG sub-node covering the scatter set
    clusters: list  # dispatched clusters, in probe (fold) order
    plan: object  # replay RetrievalPlan (one group)
    board: BatchTopK  # (n_clusters, plan.k) partial item rows
    remaining: int  # parts still in flight


@dataclasses.dataclass
class _FaultState:
    """Recovery-layer bookkeeping, built only when fault tolerance is active
    (``SchedulerConfig.fault_tolerance`` or a backend ``FaultPlan``).

    Every dispatched *unit* of retrieval-side work (sub-stage plan group,
    shard scatter part, registry stage plan group, host StageTask) gets a
    token; ``units[token]`` tracks how many in-flight copies exist (1, or 2
    while a hedge twin runs) and whether one already resolved — the
    first-result-wins dedup that makes hedging and late fenced results safe
    to apply exactly once."""

    plan: object = None  # serving.faults.FaultPlan (may be None)
    dispatch_seq: int = 0  # monotone counter feeding transient-fault draws
    next_token: int = 0
    units: dict = dataclasses.field(default_factory=dict)
    # (request_id, node_id) -> transient-retry attempts consumed
    attempts: dict = dataclasses.field(default_factory=dict)
    # request_id -> earliest virtual instant a retried unit may re-dispatch
    not_before: dict = dataclasses.field(default_factory=dict)
    # shard scatter parts lost on a dead worker: [(gather, positions), ...]
    orphan_parts: list = dataclasses.field(default_factory=list)


@owned_by("scheduler", expose=("metrics", "crossreq", "obs", "telemetry",
                               "lifecycle", "shard_map"))
class WavefrontScheduler:
    def __init__(self, backend, index, config: SchedulerConfig,
                 workload=None):
        from repro.serving.workload import WorkloadProfile

        self.backend = backend
        self.index = index
        self.cfg = config
        self.workload = workload or WorkloadProfile()
        self.dag = RuntimeDAG()
        self.budget = TimeBudget()
        self.spec = Speculator(config.speculation)
        self.num_ret_workers = max(1, int(config.num_ret_workers))
        # cross-request coordination layer (repro.crossreq): built only when
        # a knob enables it, so the disabled path stays bit-identical
        self.crossreq = None
        self._merge_unique = None
        if (config.global_cache_size > 0 or config.dedup_threshold > 0.0
                or config.replication_factor > 1):
            from repro.crossreq import CrossRequestCoordinator
            from repro.crossreq.globalcache import merge_unique

            self.crossreq = CrossRequestCoordinator(
                config, index, self.num_ret_workers)
            self._merge_unique = merge_unique
            hyb = getattr(backend, "hybrid", None)
            if (hyb is not None and config.replication_factor > 1
                    and self.num_ret_workers > 1):
                self.crossreq.attach_cache(
                    hyb.cache, self.num_ret_workers,
                    config.replication_factor)
        # shard-mode serving (retrieval.distributed.ShardMap): one contiguous
        # cluster-range shard per retrieval worker; built only when the knob
        # is on so the disabled path stays bit-identical to the unsharded
        # loop
        self.shard_map = None
        if config.index_sharding:
            from repro.retrieval.distributed import ShardMap

            self.shard_map = ShardMap.build(
                index.cluster_sizes(), self.num_ret_workers)
            hyb = getattr(backend, "hybrid", None)
            if hyb is not None and not hyb.sharded:
                hyb.enable_sharding(self.shard_map.owner,
                                    self.num_ret_workers)
        self.dispatcher = dispatch_mod.RetrievalDispatcher(
            self.num_ret_workers, index.n_clusters,
            policy=config.dispatch_policy,
            tracker=self.crossreq.tracker if self.crossreq else None,
            replica_map=self.crossreq.replicas if self.crossreq else None,
            shard_map=self.shard_map)
        # worker lifecycle registry: always built (drain/rebind are
        # operational APIs); with no fault plan and no drain calls every
        # worker stays HEALTHY and the loop is unchanged.  The *recovery*
        # machinery (_FaultState) activates only on an explicit knob or plan.
        self.lifecycle = lifecycle_mod.WorkerRegistry(
            self.num_ret_workers,
            heartbeat_interval_us=config.heartbeat_interval_us,
            suspect_after_us=config.suspect_after_us,
            dead_after_us=config.dead_after_us,
            external_heartbeats=config.external_heartbeats)
        fault_plan = getattr(backend, "fault_plan", None)
        self.ft: Optional[_FaultState] = None
        if config.fault_tolerance or fault_plan is not None:
            self.ft = _FaultState(plan=fault_plan)
        self.metrics = Metrics()
        self.metrics.ret_busy_per_worker = [0.0] * self.num_ret_workers
        # observability taps (obs/): lazily imported so the default path
        # never loads the package; both are purely passive recorders
        self.obs = None
        self.telemetry = None
        if config.tracing:
            from repro.obs.trace import TraceRecorder

            self.obs = TraceRecorder()
        if config.telemetry:
            from repro.obs.registry import TelemetrySampler

            self.telemetry = TelemetrySampler(
                interval_us=config.telemetry_interval_us)
        # arrival queue: heap keyed (arrival_us, ingress_seq) — O(log n)
        # admission instead of the old sort-on-every-insert list.  The
        # monotonic admission sequence number breaks exact-arrival ties in
        # *submission* order: request ids are allocated before admission, so
        # tying on request_id would let concurrent wall-clock submits replay
        # in a different order than they ran
        self._pending: list[tuple[float, int, RequestContext]] = []
        self._ingress_seq = 0
        self.active: list[RequestContext] = []
        self.done: list[RequestContext] = []
        self._cluster_sizes = index.cluster_sizes()
        # streaming event-loop state: lives on the instance so step() can
        # leave jobs in flight between calls and submissions can interleave
        self.now = 0.0
        self._gen_job = None
        self._ret_jobs: list = [None] * self.num_ret_workers
        self.admission = None
        if config.max_pending > 0 or config.admission_control:
            self.admission = dispatch_mod.AdmissionController(
                config, self.budget, self.backend.cluster_cost_model,
                self._cluster_sizes, shard_map=self.shard_map,
                lifecycle=self.lifecycle)
        self._ret_fifo: list[RequestContext] = []  # coarse-mode stage queue
        self._spec_ret_round: dict[int, int] = {}  # req -> last spec-ret round
        # request_id -> (query_vec, cluster queue) precomputed in one batched
        # probe_order call for all arrivals admitted in the same cycle
        self._probe_hints: dict[int, tuple] = {}
        # consecutive no-event cycles: trips the stranded-work degrade net
        self._idle_cycles = 0

    # ------------------------------------------------------------------ API
    @property
    def pending(self) -> list[RequestContext]:
        """Queued (not yet admitted-to-active) requests in arrival order
        (submission order at exact arrival ties)."""
        return [item[2] for item in sorted(self._pending, key=lambda x: x[:2])]

    @handoff("server")
    def add_request(self, req: RequestContext) -> bool:
        """Queue a request for admission at its arrival time.  Returns False
        when the admission layer sheds it (bounded queue / infeasible
        deadline) — only possible when a SchedulerConfig admission knob is
        enabled; the default configuration admits unconditionally.

        A request carrying the ``_shed`` state marker is a *re-admission
        attempt* of a previously shed logical request (the ingress loop's
        closed-loop retry): it bumps ``resubmissions`` instead of
        re-counting ``shed_*`` on failure, and bumps ``shed_readmitted``
        when it finally lands, so each logical request is counted in
        exactly one of {submitted, shed_final}."""
        resubmit = "_shed" in req.state
        if resubmit:
            self.metrics.resubmissions += 1
        if self.admission is not None:
            in_system = len(self._pending) + len(self.active)
            dec = self.admission.evaluate(req, self.now, in_system,
                                          active=self.active)
            if not dec.admitted:
                if not resubmit:
                    # first shed of this logical request: count it and fire
                    # the obs hooks exactly once
                    if dec.reason == "queue_full":
                        self.metrics.shed_queue_full += 1
                    else:
                        self.metrics.shed_infeasible += 1
                    if self.obs is not None:
                        self.obs.request_shed(req, self.now, dec.reason)
                    if self.telemetry is not None:
                        self.telemetry.on_shed(req, dec.reason)
                req.state["_shed"] = dec.reason
                return False
        if resubmit:
            del req.state["_shed"]
            self.metrics.shed_readmitted += 1
        self.metrics.submitted += 1
        if self.obs is not None:
            self.obs.request_submitted(req, self.now)
        req.ingress_seq = self._ingress_seq
        self._ingress_seq += 1
        heapq.heappush(self._pending,
                       (float(req.arrival_us), req.ingress_seq, req))
        return True

    # ------------------------------------------------- worker pool lifecycle
    @handoff("server")
    def register_worker(self) -> int:
        """Add a fresh retrieval worker to the pool mid-run.  The new worker
        starts HEALTHY and owns no shard — in shard mode it serves stage
        work, replica scans and whole-index failover until a resharding
        assigns it clusters."""
        wid = self.lifecycle.register(self.now)
        self.num_ret_workers += 1
        self.cfg.num_ret_workers = self.num_ret_workers
        self._ret_jobs.append(None)
        self.metrics.ret_busy_per_worker.append(0.0)
        self.dispatcher.add_worker()
        return wid

    @handoff("server")
    def drain_worker(self, wid: int) -> bool:
        """Operator-initiated leave: the worker finishes its in-flight job
        and takes no new work until ``rebind_worker``."""
        return self.lifecycle.drain(int(wid), self.now)

    @handoff("server")
    def rebind_worker(self, wid: int) -> bool:
        """Return a drained worker to the pool (JOINING -> HEALTHY)."""
        return self.lifecycle.rebind(int(wid), self.now)

    @handoff("server")
    def worker_heartbeat(self, wid: int, now: float) -> None:
        """External (ingress-fed) heartbeat for ``wid`` stamped ``now`` on
        the virtual clock.  The registry clamps: a stamp behind the last
        one recorded never regresses ``last_heartbeat_us``.  Only
        meaningful with ``external_heartbeats`` on — the default virtual
        model keeps live workers fresh without any feed."""
        self.lifecycle.heartbeat(int(wid), float(now))

    @handoff("server")
    def admission_load(self) -> dict:
        """Backlog snapshot for the ingress re-admission gate: in-system
        population, the bounded-queue limit (0 = unbounded), and the
        admission controller's in-flight backlog estimate (µs)."""
        out = {"in_system": len(self._pending) + len(self.active),
               "max_pending": int(self.cfg.max_pending),
               "backlog_us": 0.0}
        if self.admission is not None:
            out["backlog_us"] = float(self.admission.backlog_us(self.active))
        return out

    # -------------------------------------------------------------- helpers
    def _enter_stage(self, req: RequestContext, now: float) -> None:
        """(Re)initialise progress when a request sits at a fresh node.
        Loops through instant completions (cache answers / empty nodes)."""
        while True:
            if req.finished:
                return
            if req.current is None:
                req.start()
            if stages.spec_for(req.node).enter(self, req, now):
                continue  # stage completed instantly; next may be instant too
            return

    def _finish_ret_stage(self, req: RequestContext, now: float) -> None:
        node = req.node
        assert req.ret is not None
        stages.spec_for(node).write_output(self, req, now)
        req.sim_cache.update(req.ret.query_vec, req.ret.topk, self.index,
                             req.ret.searched)
        if req.ret.started_at >= 0:
            self.budget.observe_retrieval_stage(now - req.ret.started_at)
        req.round_idx += 1
        req.log(now, "ret_stage_done", node.node_id)
        if self.crossreq is not None:
            self._crossreq_stage_done(req, now)
        # speculation resolution (dependency rewiring)
        if req.gen is not None and req.gen.speculative_src is not None:
            self.metrics.spec_gen_attempts += 1
            ok = transforms.validate_or_rollback(self.dag, req, self.spec)
            if ok:
                self.metrics.spec_gen_validated += 1
            else:
                self.metrics.spec_gen_rollbacks += 1
            # move to the generation node, keeping (or restarting) gen progress
            nxt = req.graph.successor(req.current, req.state)
            req.ret = None
            from repro.core.ragraph import END

            if nxt is END:
                self._finish_request(req, now)
            else:
                req.current = int(nxt)
                if req.gen is not None:
                    req.gen.started_at = now if req.gen.started_at < 0 else req.gen.started_at
                    # validated speculation that already finished generating
                    if req.gen.done and req.gen.speculative_src is None:
                        self._finish_gen_stage(req, now)
            return
        req.ret = None
        self._advance_request(req, now)

    def _advance_request(self, req: RequestContext, now: float) -> None:
        """Shared stage-completion tail: advance to the successor node (or
        finish), preserving speculative generation progress across the hop."""
        gen_keep = req.gen
        if req.advance():
            # only restore generation progress onto the node it belongs to —
            # an unconditional restore can resurrect stale progress onto an
            # unrelated successor (e.g. the next node of a ret->ret chain)
            if (gen_keep is not None
                    and stages.spec_for(req.node).resource == stages.GEN
                    and gen_keep.node_id in (None, req.current)):
                req.gen = gen_keep
            self._enter_stage(req, now)
        else:
            self._finish_request(req, now)

    def _finish_stage(self, req: RequestContext, now: float) -> None:
        """Completion of a generic registry host stage (any kind beyond the
        dedicated gen/ret paths): fold the result into request state, feed
        the stage time into the Eq.(1) budget EMA, fan the output out to
        fused subscribers, and advance."""
        prog = req.stage
        assert prog is not None
        sp = stages.spec(prog.kind)
        node = req.node
        sp.write_output(self, req, now)
        if prog.started_at >= 0:
            self.budget.observe_retrieval_stage(now - prog.started_at)
        req.log(now, f"{prog.kind}_stage_done", node.node_id)
        if self.crossreq is not None and self.crossreq.fusion is not None:
            for sub, match in self.crossreq.fusion.complete_leader(
                    req.request_id):
                if (sub.finished or sub.stage is None
                        or not sub.stage.parked):
                    continue
                self.metrics.dedup_fanout += 1
                if self.obs is not None:
                    self.obs.fanout(req, sub, now, "stage")
                sp.adopt_from_leader(self, sub, req, match, now)
        req.stage = None
        self._advance_request(req, now)

    def _crossreq_stage_done(self, req: RequestContext, now: float) -> None:
        """Cross-request hooks at retrieval-stage completion: publish the
        finished search into the global cache (stages that actually
        searched — cache-answered and fanned-out stages carry no new
        information), then fan the merged top-k out to every fused
        subscriber so their stages complete at the same instant."""
        cr = self.crossreq
        ret = req.ret
        if (cr.global_cache is not None and ret.searched
                and not ret.answered_from_cache):
            wide = getattr(ret, "_wide_topk", None)
            cr.global_cache.insert(ret.query_vec,
                                   wide if wide is not None else ret.topk,
                                   self.index, list(ret.searched), ret.nprobe)
        if cr.fusion is None:
            return
        final = ret.topk
        searched = list(ret.searched)
        for sub, kind in cr.fusion.complete_leader(req.request_id):
            if sub.finished or sub.ret is None or sub.ret.done:
                continue
            k = sub.ret.k
            sub.ret.topk = TopK(k, final.dists[:k].copy(),
                                final.ids[:k].copy())
            if kind == "near":
                # the fanned-out distances are relative to the *leader's*
                # query; record that query in the subscriber's LocalCache
                # so the next round's O1 ball bound stays sound instead of
                # silently compounding the single-hop fusion tolerance
                sub.ret.query_vec = ret.query_vec.copy()
            sub.ret.searched = list(searched)
            sub.ret.answered_from_cache = True
            sub.ret.cluster_queue = []
            sub.ret._inflight = False  # type: ignore[attr-defined]
            self.metrics.dedup_fanout += 1
            if self.obs is not None:
                self.obs.fanout(req, sub, now, kind)
            self._finish_ret_stage(sub, now)

    def _finish_gen_stage(self, req: RequestContext, now: float) -> None:
        node = req.node
        assert req.gen is not None
        req.state[node.output] = {
            "tokens": req.gen.generated,
            "text": f"<gen:{req.request_id}:{node.node_id}>",
        }
        req.state.setdefault("_gen_history", []).append(node.node_id)
        self.metrics.gen_tokens += req.gen.generated
        req.gen_round += 1
        req.log(now, "gen_stage_done", node.node_id)
        req.gen = None
        if req.advance():
            self._enter_stage(req, now)
        else:
            self._finish_request(req, now)

    def _finish_request(self, req: RequestContext, now: float) -> None:
        req.finish_us = now
        lat = now - req.arrival_us
        self.metrics.latencies_us.append(lat)
        under_slo = lat <= (req.slo_us or self.cfg.slo_us)
        if not under_slo:
            self.metrics.slo_violations += 1
        self.metrics.finish_log.append((now, lat, under_slo))
        self.metrics.finished += 1
        if req.state.get("_degraded"):
            self.metrics.degraded_completions += 1
        self.active.remove(req)
        self.done.append(req)
        if self.obs is not None:
            self.obs.request_finished(req, now)
        if self.telemetry is not None:
            self.telemetry.on_finish(req, now)
        self.dag.gc()

    def _prime_probe_orders(self, reqs: list, now: float) -> None:
        """Batch the nprobe ranking for all arrivals admitted this cycle:
        one ``probe_order`` call per distinct nprobe instead of one per
        request.  Results are stashed as hints consumed by ``_enter_stage``."""
        by_nprobe: dict[int, list] = {}
        for r in reqs:
            if r.finished or r.ret is not None:
                continue
            nid = r.current if r.current is not None else r.graph.entry()
            node = r.graph.nodes.get(nid)
            if node is None:
                continue
            nprobe = stages.spec_for(node).probe_hint_nprobe(node, self.cfg)
            if nprobe is None:
                continue
            qv = self.backend.query_embedding(r, r.round_idx)
            by_nprobe.setdefault(nprobe, []).append((r, qv))
        for nprobe, lst in by_nprobe.items():
            order = self.index.probe_order(
                np.stack([qv for _, qv in lst]), nprobe)
            for (r, qv), row in zip(lst, order):
                self._probe_hints[r.request_id] = (
                    qv, [int(c) for c in row])

    # ------------------------------------------------------ work assembly
    def _slack_order(self, reqs, now: float) -> list:
        """Wavefront order: tightest SLO slack admitted to assembly first.
        In shard mode remaining-time estimates use the scatter-gather
        service model (max over shards + merge term).  With workers dead or
        draining, per-request estimates inflate by the static/effective pool
        ratio so slack ordering sees the shrunken pool."""
        scale = 1.0
        if not self.lifecycle.all_healthy():
            eff = self.lifecycle.effective_pool_size()
            if 0 < eff < self.num_ret_workers:
                scale = self.num_ret_workers / eff
        return dispatch_mod.order_by_slack(
            reqs, now, self.budget, self.backend.cluster_cost_model,
            self._cluster_sizes, self.cfg.slo_us, self.shard_map,
            self.cfg.shard_merge_us if self.shard_map is not None else 0.0,
            pool_scale=scale)

    def _assemble_gen(self, now: float):
        """Continuous-batching generation sub-stage across requests."""
        ready = [
            r for r in self.active
            if r.gen is not None and not r.gen.done
            and r.gen.engine_seq != "inflight"
        ]
        batch = self._slack_order(ready, now)[: self.cfg.max_gen_batch]
        if not batch:
            return None
        n_steps = self.budget.gen_steps_for_budget(len(batch))
        n_prefill_tokens = sum(
            self.workload.prompt_tokens(r.request_id, r.current or 0)
            for r in batch if not r.gen.prefilled
        )
        dur = self.backend.gen_duration(n_prefill_tokens, len(batch), n_steps)
        dur = self._mitigate_straggler(dur, expected=dur)
        for r in batch:
            r.gen.engine_seq = "inflight"
        self.metrics.substages_gen += 1
        return {"reqs": batch, "n_steps": n_steps, "end": now + dur, "dur": dur}

    def _assemble_ret(self, now: float, idle: list[int]) -> dict:
        """Assemble retrieval jobs for the idle workers; returns {wid: job}."""
        if self.crossreq is not None:
            # decay the shared popularity histogram and refresh the replica
            # map once per assembly cycle
            self.crossreq.tick()
        if self.cfg.mode == "hedra":
            return self._assemble_ret_substage(now, idle)
        return self._assemble_ret_coarse(now, idle)

    def _finalize_ret_job(self, now: float, wid: int, plan,
                          tasks=(), hedge_tokens=None) -> dict:
        charge = 0.0
        results_fn = None
        if plan is not None:
            charge, results_fn = self.backend.search_charged(plan,
                                                             worker_id=wid)
        task_runs = []
        for t in tasks:
            c, fn = self.backend.stage_charged(t, worker_id=wid)
            charge += c
            task_runs.append((t, fn))
        dur = self._mitigate_straggler(charge, expected=charge, worker_id=wid)
        if self.ft is not None and self.ft.plan is not None:
            # injected stall windows inflate service time *after* straggler
            # mitigation — they are exactly what the timeout/hedging layer
            # must cover, so the cap must not silently absorb them
            dur = self.backend.fault_latency(dur, worker_id=wid, now_us=now)
        self.dispatcher.note_busy(wid, dur)
        self.metrics.substages_ret += 1
        job = {"plan": plan, "results_fn": results_fn, "tasks": task_runs,
               "end": now + dur, "dur": dur, "worker": wid}
        if self.ft is not None:
            job["deadline"] = (now + charge * self.cfg.timeout_factor
                               + self.cfg.sched_overhead_us)
            self._ft_register_job(job, wid, hedge_tokens)
        if self.obs is not None:
            self.obs.ret_job(job, wid, now, hedge=hedge_tokens is not None)
        if self.telemetry is not None:
            self.telemetry.on_ret_job(job, wid)
        return job

    def _add_ret_group(self, builder: PlanBuilder, r: RequestContext,
                       clusters, sn) -> None:
        """One plan group per request sub-stage, seeded with the running
        top-k and the early-termination streak state at assembly time.
        A fused leader's group carries its current subscriber fan-out so
        the backend charges the group once for the whole set."""
        fanout = 1
        out_k = None
        if self.crossreq is not None:
            if self.crossreq.fusion is not None:
                fanout = self.crossreq.fusion.fanout(r.request_id)
            if self.crossreq.global_cache is not None:
                # widen the scoreboard (not group_k: streaks and returned
                # results are untouched) so the stage can publish a top-k'
                # entry to the global cache at no extra scan cost
                out_k = max(r.ret.topk.k, SPEC_RET_K)
        builder.add(
            r.ret.query_vec, clusters,
            k=r.ret.topk.k,
            meta=("ret", r, sn, list(clusters)),
            seed=r.ret.topk,
            last_kth=r.ret.last_kth,
            no_improve=r.ret.no_improve,
            fanout=fanout,
            out_k=out_k,
        )

    # ------------------------------------------------ shard scatter-gather
    def _scatter_ret(self, builders: dict, cycle_load: dict,
                     r: RequestContext, idle: list[int], cm, now: float,
                     *, whole_stage: bool) -> None:
        """Shard-mode dispatch of one request's next retrieval sub-stage:
        take the Eq.(1) budget prefix of the (reordered) cluster queue (the
        whole queue for coarse stages), split it by owning shard, and hand
        each part to its owner — or, for hot clusters replicated onto other
        workers' slabs, to the least-loaded replica holder.  Parts whose
        eligible workers are all busy stay queued (order preserved) for a
        later cycle; the dispatched parts form one ``_ShardGather`` whose
        completion performs the whole-index k-way merge.

        When the pool is impaired, parts whose owner is DEAD or DRAINING
        fail over (replica holder, then whole-index-capable worker); parts
        nothing can ever cover are dropped and the stage completes degraded
        rather than hanging."""
        queue = r.ret.cluster_queue
        if not queue:
            return
        if whole_stage:
            n = len(queue)
        else:
            n = self.budget.clusters_for_budget(queue, cm,
                                                self._cluster_sizes)
        prefix = queue[:n]
        assign = []
        taken = set()
        dropped = set()
        impaired = not self.lifecycle.all_healthy()
        for shard, part in self.shard_map.split(prefix):
            if impaired and not self.lifecycle.owner_serves(shard):
                wid, can_wait = self._pick_failover_worker(part, idle,
                                                           cycle_load)
                if wid is not None:
                    assign.append((shard, wid, part))
                    taken.add(shard)
                    self.metrics.failovers += 1
                    if self.obs is not None:
                        self.obs.failover(r, wid, now)
                elif not can_wait:
                    dropped.add(shard)
                continue
            wid = self.dispatcher.pick_shard_worker(part, shard, idle,
                                                    extra_load=cycle_load)
            if wid is not None:
                assign.append((shard, wid, part))
                taken.add(shard)
        if not assign and not dropped:
            return
        own = self.shard_map.owner
        dispatched = [c for c in prefix if int(own[c]) in taken]
        r.ret.cluster_queue = (
            [c for c in prefix
             if int(own[c]) not in taken and int(own[c]) not in dropped]
            + queue[n:])
        if dropped:
            self.metrics.degraded_drops += len(dropped)
            self._flag_degraded(r, now)
        if not assign:
            # every placeable part degraded away; the stage may now be done
            if r.ret.done:
                self._finish_ret_stage(r, now)
            return
        gather = self._new_gather(r, dispatched, len(assign))
        owners = self.shard_map.owner_of(dispatched)
        fanout = 1
        if self.crossreq is not None and self.crossreq.fusion is not None:
            fanout = self.crossreq.fusion.fanout(r.request_id)
        for shard, wid, part in assign:
            positions = np.flatnonzero(owners == shard)
            builders[wid].add(
                r.ret.query_vec, part, k=r.ret.topk.k,
                meta=("shard", gather, positions),
                fanout=fanout, out_k=gather.board.k)
            self.dispatcher.note_dispatch(wid, part)
            cycle_load[wid] = cycle_load.get(wid, 0.0) + cm.batch_cost_us(
                self._cluster_sizes[np.asarray(part, np.int64)])
            self.metrics.shard_parts += 1
        r.ret._inflight = True  # type: ignore[attr-defined]
        self.metrics.shard_scatters += 1

    def _new_gather(self, r: RequestContext, clusters: list,
                    n_parts: int) -> _ShardGather:
        """Open a scatter set: the runtime-DAG sub-node covering it plus the
        one-group replay plan seeded with the stage's running top-k and
        early-termination streaks (widened to top-k' when the global cache
        wants a publishable entry, like the whole-index path)."""
        sn = self.dag.new_subnode(r, "ret", {"clusters": list(clusters)})
        out_k = None
        if (self.crossreq is not None
                and self.crossreq.global_cache is not None):
            out_k = max(r.ret.topk.k, SPEC_RET_K)
        plan = make_gather_plan(
            r.ret.query_vec, clusters, k=r.ret.topk.k, seed=r.ret.topk,
            last_kth=r.ret.last_kth, no_improve=r.ret.no_improve,
            out_k=out_k)
        return _ShardGather(
            req=r, sn=sn, clusters=list(clusters), plan=plan,
            board=BatchTopK.empty(len(clusters), plan.k),
            remaining=int(n_parts))

    def _finish_gather(self, gather: _ShardGather, now: float) -> None:
        """All parts of a scatter set have landed: fold the board with the
        replay plan (k-way merge, bit-identical to the whole-index path) and
        run the same stage-completion logic the unsharded path runs."""
        r = gather.req
        self.metrics.shard_merges += 1
        if self.obs is not None:
            self.obs.gather_merge(gather, now)
        if r.finished or r.ret is None:
            return
        res = gather.plan.finalize(gather.board)
        self._apply_ret_result(r, res, 0, int(gather.plan.group_k[0]),
                               gather.plan.k, gather.clusters, gather.sn, now)

    def _apply_ret_result(self, r: RequestContext, res, g: int, kg: int,
                          plan_k: int, clusters, sn, now: float) -> None:
        """Stage-completion core shared by the whole-index path
        (``_complete_ret``'s ``ret`` groups) and the shard-mode gather: fold
        group ``g`` of ``res`` into the request's running state, tick the
        early-termination check, and close the stage when it is done.  Both
        paths MUST go through here — the shard-mode bit-identity guarantee
        is exactly that the two run the same completion logic."""
        r.ret.topk = res.group_topk(g, kg)
        if (self.crossreq is not None
                and self.crossreq.global_cache is not None
                and plan_k > kg):
            # accumulate the widened top-k' entry for the global cache
            # across the stage's sub-stages; id dedup keeps the shared
            # seed prefix from duplicating
            row = res.group_topk(g, plan_k)
            prev = getattr(r.ret, "_wide_topk", None)
            r.ret._wide_topk = (  # type: ignore[attr-defined]
                row if prev is None
                else self._merge_unique(prev, row, plan_k))
        r.ret.no_improve = int(res.no_improve[g])
        r.ret.last_kth = float(res.last_kth[g])
        r.ret.searched.extend(clusters)
        r.ret._inflight = False  # type: ignore[attr-defined]
        if sn is not None:
            self.dag.complete(sn)
        if self.cfg.enable_early_term and not r.ret.done:
            if transforms.maybe_early_terminate(
                    self.index, r, mode=self.cfg.early_term_mode,
                    patience=self.cfg.early_term_patience):
                self.metrics.early_terms += 1
        if r.ret.done:
            self._finish_ret_stage(r, now)
        elif (self.shard_map is not None and self.cfg.mode != "hedra"
              and r not in self._ret_fifo):
            # coarse shard-mode stage with deferred parts (busy owners at
            # dispatch): back into the stage queue for the next assembly
            self._ret_fifo.append(r)

    def _assemble_ret_substage(self, now: float, idle: list[int]) -> dict:
        builders: dict[int, PlanBuilder] = {w: PlanBuilder() for w in idle}
        # estimated cost handed to each worker *this cycle*; lets the
        # dispatcher spread simultaneous sub-stages instead of piling them
        # onto the worker that was least loaded when the cycle started
        cycle_load: dict[int, float] = {w: 0.0 for w in idle}
        tasks: dict[int, list] = {w: [] for w in idle}
        cm = self.backend.cluster_cost_model
        if (self.ft is not None and self.ft.orphan_parts
                and self.shard_map is not None):
            self._place_orphans(builders, cycle_load, idle, now)
        nb = self.ft.not_before if self.ft is not None else None
        ready = [r for r in self.active
                 if (nb is None or nb.get(r.request_id, 0.0) <= now)
                 and ((r.ret is not None and not r.ret.done
                       and not getattr(r.ret, "_inflight", False))
                      or (r.stage is not None and not r.stage.done
                          and not r.stage.parked and r.stage.work_queue))]
        ordered = self._slack_order(ready, now)
        if self.crossreq is not None and self.crossreq.fusion is not None:
            ordered = self._fuse_wavefront(ordered)
        for r in ordered:
            if r.stage is not None:
                # generic registry stage: the spec splits its own work-unit
                # queue under the budget and dispatches plan groups and/or
                # host StageTasks (shard mode included — host arrays hold
                # the whole index, so stage work is placement-free)
                stages.spec(r.stage.kind).assemble(
                    self, r, builders, tasks, cycle_load, idle, now,
                    whole_stage=False)
                continue
            if self.shard_map is not None:
                self._scatter_ret(builders, cycle_load, r, idle, cm, now,
                                  whole_stage=False)
                continue
            sn = transforms.split_retrieval_next(
                self.dag, r, self.budget, cm, self._cluster_sizes,
            )
            if sn is None:
                continue
            clusters = sn.payload["clusters"]
            wid = self.dispatcher.pick_worker(clusters, idle,
                                              extra_load=cycle_load)
            r.ret.cluster_queue = r.ret.cluster_queue[len(clusters):]
            r.ret._inflight = True  # type: ignore[attr-defined]
            self.dispatcher.note_dispatch(wid, clusters)
            cycle_load[wid] += cm.batch_cost_us(
                self._cluster_sizes[np.asarray(clusters, np.int64)])
            self._add_ret_group(builders[wid], r, clusters, sn)
        spec_items = self._maybe_spec_retrieval(now)
        if spec_items and self.shard_map is not None:
            # shard mode: a warmup is best effort, and its LocalCache update
            # is a single *replace* (query, top-k, probed set) — splitting
            # it across shards would leave only the last-completing part in
            # the cache.  Dispatch the largest part with a placeable worker
            # and drop the rest: one consistent (emb, top-k, probed) update.
            for r, emb, probes in spec_items:
                parts = sorted(self.shard_map.split(probes),
                               key=lambda sp: (-len(sp[1]), sp[0]))
                for shard, part in parts:
                    wid = self.dispatcher.pick_shard_worker(
                        part, shard, idle, cycle_load, count_routes=False)
                    if wid is not None:
                        builders[wid].add(emb, part, k=SPEC_RET_K,
                                          meta=("spec", r, emb, part))
                        break
        elif spec_items:
            spec_wid = self.dispatcher.least_loaded(idle, extra_load=cycle_load)
            for r, emb, probes in spec_items:
                builders[spec_wid].add(emb, probes, k=SPEC_RET_K,
                                       meta=("spec", r, emb, probes))
        jobs = {}
        for wid in idle:
            if builders[wid].empty and not tasks[wid]:
                continue
            plan = None if builders[wid].empty else builders[wid].build()
            jobs[wid] = self._finalize_ret_job(now, wid, plan, tasks[wid])
        return jobs

    def _fuse_wavefront(self, ordered: list) -> list:
        """In-flight dedup/fusion pass: a *fresh* retrieval stage whose query
        matches an executing leader's (exact byte hash, or cosine >= the
        dedup threshold) subscribes to the leader's result instead of
        assembling its own sub-stages; the rest proceed, with fresh stages
        registered as matchable leaders.  Subscribers are parked in-flight
        and completed by the leader's fan-out."""
        fusion = self.crossreq.fusion
        allow_near = self.cfg.enable_cache_answer
        out = []
        for r in ordered:
            sp = stages.spec_for(r.node)
            if not sp.fusion_fresh(r):  # mid-stage: executing, cannot fuse
                out.append(r)
                continue
            sig = sp.fusion_signature(self, r)
            if sig is None:  # stage kind opts out of fusion
                out.append(r)
                continue
            kind = fusion.try_subscribe(r, sig, allow_near=allow_near)
            if kind is not None:
                sp.park_subscriber(self, r)
                if kind == "exact":
                    self.metrics.dedup_exact += 1
                else:
                    self.metrics.dedup_near += 1
                continue
            fusion.register_leader(r, sig)
            out.append(r)
        return out

    def _assemble_ret_coarse(self, now: float, idle: list[int]) -> dict:
        """Whole-stage jobs: sequential = FIFO-1, async = batch-all-queued.
        Coarse baselines keep the paper's single-retrieval-worker shape: the
        whole batch lands on one (least-loaded) worker."""
        self._ret_fifo = [
            r for r in self._ret_fifo if r in self.active
            and ((r.ret is not None and not r.ret.done)
                 or (r.stage is not None and not r.stage.done))]
        if not self._ret_fifo:
            return {}
        if self.shard_map is not None:
            # shard mode: whole stages still scatter by cluster ownership —
            # a worker cannot scan shards it does not hold.  Requests whose
            # parts could not all be placed (busy owners) keep their
            # leftover clusters queued and stay in the stage FIFO.
            builders: dict[int, PlanBuilder] = {w: PlanBuilder() for w in idle}
            cycle_load: dict[int, float] = {w: 0.0 for w in idle}
            tasks: dict[int, list] = {w: [] for w in idle}
            cm = self.backend.cluster_cost_model
            if self.ft is not None and self.ft.orphan_parts:
                self._place_orphans(builders, cycle_load, idle, now)
            nb = self.ft.not_before if self.ft is not None else None
            keep = []
            for r in self._ret_fifo:
                if nb is not None and nb.get(r.request_id, 0.0) > now:
                    keep.append(r)  # retry backoff still running
                    continue
                if r.stage is not None:
                    # registry stages are placement-free (host arrays hold
                    # the whole index): dispatch the whole unit queue
                    if not r.stage.parked and r.stage.work_queue:
                        stages.spec(r.stage.kind).assemble(
                            self, r, builders, tasks, cycle_load, idle, now,
                            whole_stage=True)
                    continue
                if getattr(r.ret, "_inflight", False):
                    keep.append(r)
                    continue
                self._scatter_ret(builders, cycle_load, r, idle, cm, now,
                                  whole_stage=True)
                if r.ret is not None and r.ret.cluster_queue:
                    keep.append(r)
            self._ret_fifo = keep
            jobs = {}
            for wid in idle:
                if builders[wid].empty and not tasks[wid]:
                    continue
                plan = None if builders[wid].empty else builders[wid].build()
                jobs[wid] = self._finalize_ret_job(now, wid, plan, tasks[wid])
            return jobs
        # both coarse baselines dispatch whole stages, one-shot batched over
        # everything queued; 'sequential' additionally holds the global lock
        take = list(self._ret_fifo)
        self._ret_fifo = []
        if self.ft is not None and self.ft.not_before:
            nb = self.ft.not_before
            self._ret_fifo = [r for r in take
                              if nb.get(r.request_id, 0.0) > now]
            take = [r for r in take if nb.get(r.request_id, 0.0) <= now]
        builder = PlanBuilder()
        wid = self.dispatcher.least_loaded(idle)
        task_list: list = []
        cycle_load = {wid: 0.0}
        for r in take:
            if r.stage is not None:
                if not r.stage.parked and r.stage.work_queue:
                    stages.spec(r.stage.kind).assemble(
                        self, r, {wid: builder}, {wid: task_list}, cycle_load,
                        [wid], now, whole_stage=True)
                continue
            clusters = list(r.ret.cluster_queue)
            r.ret.cluster_queue = []
            r.ret._inflight = True  # type: ignore[attr-defined]
            self.dispatcher.note_dispatch(wid, clusters)
            self._add_ret_group(builder, r, clusters, None)
        if builder.empty and not task_list:
            return {}
        plan = None if builder.empty else builder.build()
        return {wid: self._finalize_ret_job(now, wid, plan, task_list)}

    def _maybe_spec_retrieval(self, now: float):
        """Generation→Retrieval speculation: warm the LocalCache from a
        partial-generation embedding (runs as low-priority ret work)."""
        pol = self.cfg.speculation
        ret_util = self.metrics.ret_busy_us / max(now * self.num_ret_workers, 1.0)
        if not self.spec.throughput_gate(ret_util, 1.0):
            return []
        items = []
        for r in self.active:
            if r.gen is None or r.gen.done or r.gen.speculative_src is not None:
                continue
            node = r.graph.nodes.get(r.current)
            if node is None or not stages.spec_for(node).emits_partial_queries:
                continue
            nxt = r.graph.successor(r.current, r.state)
            nxt_node = r.graph.nodes.get(nxt) if isinstance(nxt, int) else None
            if (nxt_node is None
                    or not stages.spec_for(nxt_node).accepts_probe_warmup):
                continue
            ratio = r.gen.generated / max(r.gen.target_tokens, 1)
            if ratio < pol.spec_ret_ratio or self._spec_ret_round.get(r.request_id, -1) == r.round_idx:
                continue
            self._spec_ret_round[r.request_id] = r.round_idx
            emb = self.backend.partial_embedding(r, r.round_idx, ratio)
            probes = self.index.probe_order(emb[None], max(4, self.cfg.nprobe // 8))[0]
            items.append((r, emb, [int(c) for c in probes[:4]]))
            self.metrics.spec_ret_launches += 1
            if len(items) >= pol.max_spec_per_cycle:
                break
        return items

    def _maybe_spec_generation(self, now: float) -> None:
        """Retrieval→Generation speculation: start the follower generation
        from partial top-k when the gen engine is underutilised."""
        pol = self.cfg.speculation
        gen_load = len([r for r in self.active if r.gen is not None and not r.gen.done])
        if not self.spec.throughput_gate(gen_load / self.cfg.max_gen_batch, 1.0):
            return
        cands = []
        for r in self.active:
            if r.ret is None or r.ret.done or r.gen is not None:
                continue
            nxt = r.graph.successor(r.current, r.state)
            nxt_node = r.graph.nodes.get(nxt) if isinstance(nxt, int) else None
            if (nxt_node is None
                    or not stages.spec_for(nxt_node).supports_spec_start):
                continue
            total = len(r.ret.searched) + len(r.ret.cluster_queue)
            d0 = float(np.sqrt(max(
                self.index.centroid_dists(r.ret.query_vec[None])[0].min(), 1e-12)))
            if self.spec.spec_gen_ready(len(r.ret.searched), total,
                                        float(np.sqrt(max(r.ret.topk.kth, 0.0)))
                                        if np.isfinite(r.ret.topk.kth) else np.inf,
                                        d0):
                cands.append((r.ret.topk.kth, r, nxt))
        for _, r, nxt in self.spec.rank_spec_gen(cands)[: pol.max_spec_per_cycle]:
            node = r.graph.nodes[nxt]
            tgt = self.workload.gen_tokens(r.request_id, node.node_id, node.max_tokens)
            basis = self.dag.new_subnode(r, "ret", {"clusters": list(r.ret.searched)})
            self.dag.complete(basis)
            transforms.add_speculative_generation(self.dag, r, basis, node, tgt,
                                                  self.budget)
            r.gen.started_at = now

    def _mitigate_straggler(self, dur: float, expected: float,
                            worker_id: int = -1) -> float:
        raw = self.backend.maybe_straggle(dur, worker_id=worker_id)
        if raw > self.cfg.straggler_cap * expected and self.cfg.straggler_redispatch:
            self.metrics.straggler_redispatches += 1
            return self.cfg.straggler_cap * expected + self.cfg.sched_overhead_us
        return raw

    # ------------------------------------------------------- fault recovery
    def _ft_register_job(self, job, wid: int, hedge_tokens=None) -> None:
        """Token-register every recoverable unit of a freshly dispatched job
        and draw each dispatch's transient-failure fate from the seeded
        stream.  Tokens give hedged twins and fenced late results
        exactly-once application; speculative warmups are best-effort and
        carry no token."""
        ft = self.ft
        tokens: dict = {}
        failed: set = set()
        plan = job["plan"]
        if plan is not None:
            for g, meta in enumerate(plan.group_meta):
                if meta[0] not in ("ret", "shard", "stage"):
                    continue
                if hedge_tokens is not None and g in hedge_tokens:
                    tok = hedge_tokens[g]
                    unit = ft.units.get(tok)
                    if unit is None:
                        # twin settled between selection and dispatch: keep
                        # a resolved token so this copy's result is fenced
                        ft.units[tok] = {"meta": meta, "inflight": 1,
                                         "resolved": True}
                    else:
                        unit["inflight"] += 1
                else:
                    tok = ft.next_token
                    ft.next_token += 1
                    ft.units[tok] = {"meta": meta, "inflight": 1,
                                     "resolved": False}
                tokens[g] = tok
                seq = ft.dispatch_seq
                ft.dispatch_seq += 1
                if ft.plan is not None and ft.plan.transient_fault(wid, seq):
                    failed.add(("g", g))
        task_tokens: dict = {}
        for i, (task, _fn) in enumerate(job["tasks"]):
            tok = ft.next_token
            ft.next_token += 1
            ft.units[tok] = {"task": task, "inflight": 1, "resolved": False}
            task_tokens[i] = tok
            seq = ft.dispatch_seq
            ft.dispatch_seq += 1
            if ft.plan is not None and ft.plan.transient_fault(wid, seq):
                failed.add(("t", i))
        job["tokens"] = tokens
        job["task_tokens"] = task_tokens
        job["failed"] = failed

    def _ft_tick(self, now: float) -> None:
        """Per-cycle fault housekeeping: fold heartbeat state into lifecycle
        transitions (recovering a dead worker's lost units), expire retry
        backoffs, mark jobs past their cost-model deadline, and hedge
        in-flight work of timed-out or SUSPECT workers."""
        ft = self.ft
        for wid, old, new in self.lifecycle.tick(now, ft.plan):
            if self.obs is not None:
                self.obs.worker_transition(wid, old, new, now)
            if new == lifecycle_mod.SUSPECT:
                self.metrics.worker_suspects += 1
            elif new == lifecycle_mod.DEAD:
                self.metrics.worker_deaths += 1
                self._on_worker_dead(wid, now)
        if ft.not_before:
            for rid in [r for r, t in ft.not_before.items() if t <= now]:
                del ft.not_before[rid]
        for wid, job in enumerate(self._ret_jobs):
            if job is None or job.get("lost"):
                continue
            if (not job.get("timed_out")
                    and job.get("deadline") is not None
                    and job["deadline"] <= now < job["end"]):
                job["timed_out"] = True
                self.metrics.task_timeouts += 1
            if (self.cfg.hedge_suspect and not job.get("hedge")
                    and not job.get("hedged")
                    and (job.get("timed_out")
                         or self.lifecycle.state_of(wid)
                         == lifecycle_mod.SUSPECT)):
                hedged_units = self._hedge_job(wid, job, now)
                if hedged_units:
                    job["hedged"] = True
                    self.metrics.hedged_dispatches += hedged_units

    def _job_crashed(self, wid: int, job) -> bool:
        """True when the fault plan kills the worker before this job's
        completion instant — its results are lost and must be fenced."""
        plan = self.ft.plan
        if plan is None:
            return False
        c = plan.crash_at(wid)
        return c is not None and c < job["end"]

    def _on_worker_dead(self, wid: int, now: float) -> None:
        """Recover everything in flight on a worker just declared DEAD: the
        job's results are fenced and every lost unit re-dispatched (the
        sub-stage is the re-dispatch quantum).  Crash recovery does not
        consume the transient retry budget — a worker dies at most once."""
        ft = self.ft
        job = self._ret_jobs[wid]
        if job is None:
            return
        self._ret_jobs[wid] = None
        toks = list(job.get("tokens", {}).values())
        toks += list(job.get("task_tokens", {}).values())
        for tok in toks:
            unit = ft.units.get(tok)
            if unit is None:
                continue
            unit["inflight"] -= 1
            if unit["resolved"]:
                if unit["inflight"] <= 0:
                    del ft.units[tok]
                continue
            if unit["inflight"] > 0:
                continue  # a hedge twin still runs this unit
            del ft.units[tok]
            self.metrics.redispatches += 1
            if self.obs is not None:
                self.obs.open_gap(self._unit_req(unit), now, "fault_recovery")
            self._ft_requeue_unit(unit, now)

    def _ft_settle_group(self, job, g: int, now: float) -> bool:
        """First-result-wins settlement of one completed plan group.
        Returns True when the result should be applied (this copy won and
        did not fail transiently)."""
        ft = self.ft
        tok = job["tokens"].get(g)
        if tok is None:
            return True  # spec warmup: no recovery semantics
        unit = ft.units.get(tok)
        if unit is None:
            return False  # fully settled already: fence the late copy
        unit["inflight"] -= 1
        if unit["resolved"]:
            if unit["inflight"] <= 0:
                del ft.units[tok]
            return False
        if ("g", g) in job["failed"]:
            self.metrics.transient_failures += 1
            if unit["inflight"] <= 0:
                del ft.units[tok]
                self._ft_retry_or_degrade(unit, now)
            return False
        unit["resolved"] = True
        if unit["inflight"] <= 0:
            del ft.units[tok]
        if job.get("hedge"):
            self.metrics.hedged_wins += 1
        return True

    def _ft_settle_task(self, job, i: int, now: float) -> bool:
        """Task-batch analogue of ``_ft_settle_group``."""
        ft = self.ft
        tok = job["task_tokens"].get(i)
        if tok is None:
            return True
        unit = ft.units.get(tok)
        if unit is None:
            return False
        unit["inflight"] -= 1
        if unit["resolved"]:
            if unit["inflight"] <= 0:
                del ft.units[tok]
            return False
        if ("t", i) in job["failed"]:
            self.metrics.transient_failures += 1
            if unit["inflight"] <= 0:
                del ft.units[tok]
                self._ft_retry_or_degrade(unit, now)
            return False
        unit["resolved"] = True
        if unit["inflight"] <= 0:
            del ft.units[tok]
        return True

    @staticmethod
    def _unit_req(unit):
        meta = unit.get("meta")
        if meta is not None:
            return meta[1].req if meta[0] == "shard" else meta[1]
        return unit["task"].req

    def _ft_retry_or_degrade(self, unit, now: float) -> None:
        """A unit failed transiently: re-dispatch with exponential backoff
        while the per-(request, node) budget lasts, then complete the stage
        degraded."""
        ft = self.ft
        r = self._unit_req(unit)
        if r is None or r.finished:
            return
        key = (r.request_id, r.current)
        att = ft.attempts.get(key, 0) + 1
        ft.attempts[key] = att
        if att > self.cfg.retry_budget:
            self.metrics.degraded_drops += 1
            self._ft_degrade_unit(unit, now)
            return
        self.metrics.retries += 1
        if self.obs is not None:
            self.obs.open_gap(r, now, "retry_hedge_failover")
        back = self.cfg.retry_backoff_us * (2.0 ** (att - 1))
        ft.not_before[r.request_id] = max(
            ft.not_before.get(r.request_id, 0.0), now + back)
        self._ft_requeue_unit(unit, now)

    def _ft_requeue_unit(self, unit, now: float) -> None:
        """Put a lost/failed unit back at the head of its owner's queue; the
        next assembly cycle re-dispatches it, possibly on another worker."""
        meta = unit.get("meta")
        if meta is None:
            task = unit["task"]
            r = task.req
            if task.sn is not None:
                self.dag.complete(task.sn)
            prog = r.stage
            if r.finished or prog is None or prog.kind != task.kind:
                return
            prog.work_queue[0:0] = list(task.units)
            prog.inflight_units -= len(task.units)
            self._requeue_coarse(r)
            return
        kind = meta[0]
        if kind == "ret":
            _, r, sn, clusters = meta
            if sn is not None:
                self.dag.complete(sn)
            if r.finished or r.ret is None:
                return
            r.ret.cluster_queue = list(clusters) + r.ret.cluster_queue
            r.ret._inflight = False  # type: ignore[attr-defined]
            self._requeue_coarse(r)
        elif kind == "shard":
            _, gather, positions = meta
            self.ft.orphan_parts.append((gather, positions))
        else:  # "stage": one registry plan group (e.g. a rewrite variant)
            _, r, sp, ref = meta
            vi, sid = ref
            prog = r.stage
            if r.finished or prog is None or prog.kind != sp.kind:
                return
            pl = prog.payload
            pending = pl["sn_pending"].get(sid)
            if pending is not None:
                pending[1] -= 1
                if pending[1] <= 0:
                    self.dag.complete(pending[0])
                    del pl["sn_pending"][sid]
            prog.work_queue.insert(0, vi)
            prog.inflight_units -= 1
            self._requeue_coarse(r)

    def _ft_degrade_unit(self, unit, now: float) -> None:
        """Retry budget exhausted (or nothing can ever run the unit): drop
        the work and complete the stage with whatever partial results exist,
        flagged degraded — the contract is partial top-k, never a hang."""
        meta = unit.get("meta")
        if meta is None:
            task = unit["task"]
            r = task.req
            if task.sn is not None:
                self.dag.complete(task.sn)
            prog = r.stage
            if r.finished or prog is None or prog.kind != task.kind:
                return
            prog.inflight_units -= len(task.units)
            self._flag_degraded(r, now)
            if prog.done:
                self._finish_stage(r, now)
            else:
                self._requeue_coarse(r)
            return
        kind = meta[0]
        if kind == "ret":
            _, r, sn, clusters = meta
            if sn is not None:
                self.dag.complete(sn)
            if r.finished or r.ret is None:
                return
            r.ret._inflight = False  # type: ignore[attr-defined]
            self._flag_degraded(r, now)
            if r.ret.done:
                self._finish_ret_stage(r, now)
            else:
                self._requeue_coarse(r)
        elif kind == "shard":
            _, gather, positions = meta
            gather.remaining -= 1
            r = gather.req
            if not r.finished and r.ret is not None:
                self._flag_degraded(r, now)
            if gather.remaining <= 0:
                self._finish_gather(gather, now)
        else:
            _, r, sp, ref = meta
            vi, sid = ref
            prog = r.stage
            if r.finished or prog is None or prog.kind != sp.kind:
                return
            pl = prog.payload
            pending = pl["sn_pending"].get(sid)
            if pending is not None:
                pending[1] -= 1
                if pending[1] <= 0:
                    self.dag.complete(pending[0])
                    del pl["sn_pending"][sid]
            prog.inflight_units -= 1
            self._flag_degraded(r, now)
            if prog.done:
                self._finish_stage(r, now)
            else:
                self._requeue_coarse(r)

    def _requeue_coarse(self, r: RequestContext) -> None:
        if (self.cfg.mode != "hedra" and r in self.active
                and r not in self._ret_fifo):
            self._ret_fifo.append(r)

    def _flag_degraded(self, r: RequestContext, now: float) -> None:
        if self.obs is not None and not r.state.get("_degraded"):
            self.obs.degraded(r, now)
        r.state["_degraded"] = True
        r.log(now, "degraded", r.current)

    def _degrade_stranded(self, now: float) -> None:
        """No worker can take retrieval-side work (all DEAD or DRAINING, or
        nothing eligible is ever coming back): complete every queued
        retrieval/stage unit degraded instead of hanging.  Generation work
        is unaffected (separate worker)."""
        if self.ft is not None and self.ft.orphan_parts:
            parts = self.ft.orphan_parts
            self.ft.orphan_parts = []
            for gather, positions in parts:
                self.metrics.degraded_drops += 1
                gather.remaining -= 1
                r = gather.req
                if r.finished or r.ret is None:
                    continue
                self._flag_degraded(r, now)
                if gather.remaining <= 0:
                    self._finish_gather(gather, now)
        for r in list(self.active):
            if r.finished:
                continue
            if (r.ret is not None and not r.ret.done
                    and not getattr(r.ret, "_inflight", False)):
                self.metrics.degraded_drops += 1
                r.ret.cluster_queue = []
                self._flag_degraded(r, now)
                self._finish_ret_stage(r, now)
            elif (r.stage is not None and not r.stage.done
                  and not r.stage.parked and r.stage.work_queue
                  and r.stage.inflight_units == 0):
                self.metrics.degraded_drops += 1
                r.stage.work_queue = []
                self._flag_degraded(r, now)
                self._finish_stage(r, now)

    def _hedge_job(self, wid: int, job, now: float) -> int:
        """Duplicate a straggling job's unresolved retrieval groups onto an
        idle HEALTHY worker (first result wins via the unit tokens).  Host
        StageTasks are not hedged — their work re-dispatches on death.
        Returns the number of duplicated units (0 = nothing hedged)."""
        plan = job["plan"]
        if plan is None or not job.get("tokens"):
            return 0
        cand = [w for w in range(self.num_ret_workers)
                if w != wid and self._ret_jobs[w] is None
                and self.lifecycle.can_schedule(w)]
        if not cand:
            return 0
        ft = self.ft
        builder = PlanBuilder()
        tokens: dict = {}
        g_new = 0
        for g, meta in enumerate(plan.group_meta):
            tok = job["tokens"].get(g)
            unit = ft.units.get(tok) if tok is not None else None
            if unit is None or unit["resolved"] or unit["inflight"] != 1:
                continue
            if meta[0] == "ret":
                _, r, sn, clusters = meta
                if r.finished or r.ret is None:
                    continue
                builder.add(r.ret.query_vec, clusters,
                            k=int(plan.group_k[g]), meta=meta,
                            seed=r.ret.topk, last_kth=r.ret.last_kth,
                            no_improve=r.ret.no_improve)
            elif meta[0] == "shard":
                _, gather, positions = meta
                r = gather.req
                if r.finished or r.ret is None:
                    continue
                part = [gather.clusters[int(i)] for i in positions]
                builder.add(r.ret.query_vec, part,
                            k=int(plan.group_k[g]), meta=meta,
                            out_k=gather.board.k)
            else:
                continue  # stage variant scans: recovered on death instead
            tokens[g_new] = tok
            g_new += 1
        if builder.empty:
            return 0
        wid2 = self.dispatcher.least_loaded(cand)
        hjob = self._finalize_ret_job(now, wid2, builder.build(),
                                      hedge_tokens=tokens)
        hjob["hedge"] = True
        self._ret_jobs[wid2] = hjob
        if self.obs is not None:
            self.obs.hedge_link(job, hjob, now)
        return g_new

    def _pick_failover_worker(self, part, idle, cycle_load):
        """Where an orphaned shard part can run now that its owner is DEAD
        or DRAINING: a crossreq replica holder whose slab covers the whole
        part, else (failover_whole_index) any serving worker modelling a
        shared-storage whole-index scan.  Returns ``(wid, can_wait)`` — wid
        None with can_wait True means eligible workers exist but are busy
        (keep the part queued); None/False means nothing can ever cover it
        (complete degraded)."""
        eligible = set()
        if self.crossreq is not None and self.crossreq.replicas is not None:
            for w in self.crossreq.replicas.covering_holders(part):
                if self.lifecycle.serving(w):
                    eligible.add(int(w))
        if self.cfg.failover_whole_index:
            for w in range(self.num_ret_workers):
                if self.lifecycle.serving(w):
                    eligible.add(w)
        if not eligible:
            return None, False
        ready = [w for w in idle if w in eligible]
        if not ready:
            return None, True
        return self.dispatcher.least_loaded(ready, extra_load=cycle_load), True

    def _place_orphans(self, builders, cycle_load, idle, now) -> None:
        """Re-dispatch shard scatter parts lost on dead workers: the owner
        first (if it serves again), then replica holders, then whole-index
        failover; parts nothing covers complete their request degraded."""
        ft = self.ft
        cm = self.backend.cluster_cost_model
        keep = []
        for gather, positions in ft.orphan_parts:
            r = gather.req
            if r.finished or r.ret is None:
                gather.remaining -= 1
                continue
            if ft.not_before.get(r.request_id, 0.0) > now:
                keep.append((gather, positions))
                continue
            part = [gather.clusters[int(i)] for i in positions]
            shard = int(self.shard_map.owner[part[0]])
            if self.lifecycle.owner_serves(shard):
                wid = self.dispatcher.pick_shard_worker(
                    part, shard, idle, extra_load=cycle_load)
                can_wait = True
            else:
                wid, can_wait = self._pick_failover_worker(part, idle,
                                                           cycle_load)
            if wid is None:
                if can_wait:
                    keep.append((gather, positions))
                else:
                    self.metrics.degraded_drops += 1
                    self._flag_degraded(r, now)
                    gather.remaining -= 1
                    if gather.remaining <= 0:
                        self._finish_gather(gather, now)
                continue
            builders[wid].add(r.ret.query_vec, part, k=r.ret.topk.k,
                              meta=("shard", gather, positions),
                              out_k=gather.board.k)
            self.dispatcher.note_dispatch(wid, part)
            cycle_load[wid] = cycle_load.get(wid, 0.0) + cm.batch_cost_us(
                self._cluster_sizes[np.asarray(part, np.int64)])
            self.metrics.shard_parts += 1
            if wid != shard:
                self.metrics.failovers += 1
                if self.obs is not None:
                    self.obs.failover(r, wid, now)
        ft.orphan_parts = keep

    # ------------------------------------------------------------ main loop
    def _cycle(self, *, horizon: Optional[float] = None,
               hard_cutoff: Optional[float] = None) -> str:
        """One scheduling cycle: admit arrivals due at ``self.now``, make
        speculation decisions, assemble work for idle workers, then advance
        the event clock to the next completion/arrival and process it.

        Returns:
          ``"advanced"``  the clock moved (or instant progress was made);
                          call again.
          ``"done"``      nothing pending, in flight, or active.
          ``"horizon"``   the next event lies beyond ``horizon``; the clock
                          did not move and in-flight jobs stay in flight
                          (streaming ``step()`` stop condition).
          ``"cutoff"``    the clock moved past ``hard_cutoff`` (legacy
                          ``run(max_time_us)`` stop condition; completions at
                          that instant are *not* processed, matching the
                          pre-streaming batch loop exactly).
        """
        now = self.now
        nw = self.num_ret_workers
        if self.telemetry is not None:
            self.telemetry.maybe_sample(self, now)
        if self.ft is not None:
            self._ft_tick(now)
        if (not self.lifecycle.all_healthy()
                and self.lifecycle.alive_for_work() == 0):
            # nobody left to take retrieval-side work: complete it degraded
            # instead of hanging (generation has its own worker)
            self._degrade_stranded(now)
        # admit arrivals (probe orders batched across the whole cycle)
        admitted = []
        while self._pending and self._pending[0][0] <= now:
            key_t, seq, req = heapq.heappop(self._pending)
            if req.arrival_us != key_t:
                # the request was re-dated after queuing (e.g. journal
                # recovery deferring re-admission); lazily re-key with the
                # live arrival instead of admitting at the stale stamp
                heapq.heappush(self._pending,
                               (float(req.arrival_us), seq, req))
                continue
            self.active.append(req)
            admitted.append(req)
        if admitted:
            self._prime_probe_orders(admitted, now)
            for req in admitted:
                self._enter_stage(req, now)
        # speculation decisions on the current wavefront
        if self.cfg.speculation.enabled:
            self._maybe_spec_generation(now)
        # dispatch to idle workers
        ret_inflight = any(j is not None for j in self._ret_jobs)
        sequential_lock = (self.cfg.mode == "sequential" and
                           (self._gen_job is not None or ret_inflight))
        if self._gen_job is None and not sequential_lock:
            self._gen_job = self._assemble_gen(now)
            if self._gen_job is not None:
                if self.obs is not None:
                    self.obs.gen_job(self._gen_job, now)
                if self.telemetry is not None:
                    self.telemetry.on_gen_job(self._gen_job)
        sequential_lock = (self.cfg.mode == "sequential" and
                           (self._gen_job is not None or ret_inflight))
        if self.lifecycle.all_healthy():
            idle = [w for w in range(nw) if self._ret_jobs[w] is None]
        else:
            idle = [w for w in range(nw) if self._ret_jobs[w] is None
                    and self.lifecycle.can_schedule(w)]
        if idle and not sequential_lock:
            for wid, job in self._assemble_ret(now, idle).items():
                self._ret_jobs[wid] = job
        # advance virtual time
        events = []
        if self._gen_job:
            events.append(self._gen_job["end"])
        events.extend(j["end"] for j in self._ret_jobs
                      if j is not None and not j.get("lost"))
        if self._pending:
            events.append(self._pending[0][0])
        if self.ft is not None:
            # fault-driven wakeups: lifecycle state changes (crash/stall
            # detection instants), per-job deadlines, retry-backoff expiry
            t = self.lifecycle.next_transition_us(now, self.ft.plan)
            if t is not None:
                events.append(t)
            for j in self._ret_jobs:
                if j is None or j.get("lost") or j.get("timed_out"):
                    continue
                d = j.get("deadline")
                if d is not None and now < d < j["end"]:
                    events.append(d)
            events.extend(t for t in self.ft.not_before.values() if t > now)
        if not events:
            if self.active:
                # no work assembled but requests active -> enter stages
                for r in list(self.active):
                    self._enter_stage(r, now)
                self._idle_cycles += 1
                if (self._idle_cycles > 2
                        and (self.ft is not None
                             or not self.lifecycle.all_healthy())):
                    # retrieval work exists but nothing can ever schedule
                    # it (e.g. sole eligible worker gone): degrade it
                    self._degrade_stranded(now)
                if not self.active or any(r.gen or r.ret or r.stage
                                          for r in self.active):
                    return "advanced"
                raise RuntimeError(
                    f"deadlock: {len(self.active)} active requests, no work")
            return "done"
        self._idle_cycles = 0
        nxt = min(events)
        if horizon is not None and nxt > horizon:
            return "horizon"
        self.now = now = nxt
        if hard_cutoff is not None and now > hard_cutoff:
            return "cutoff"
        # completions
        if self._gen_job and self._gen_job["end"] <= now:
            self.metrics.gen_busy_us += self._gen_job["dur"]
            self._complete_gen(self._gen_job, now)
            self._gen_job = None
        for wid in range(nw):
            job = self._ret_jobs[wid]
            if job is None or job.get("lost") or job["end"] > now:
                continue
            if self.ft is not None and self._job_crashed(wid, job):
                # the worker died mid-job: fence its results; the lost
                # units are recovered when missed heartbeats declare it
                # DEAD (lifecycle transition instants are in the events)
                job["lost"] = True
                if self.obs is not None:
                    self.obs.ret_job_lost(job, now)
                continue
            # the dispatcher is the single policy-side load source;
            # Metrics mirrors its completed share instead of
            # double-booking an accumulator of its own
            self.dispatcher.note_complete(wid, job["dur"])
            self.metrics.ret_busy_per_worker[wid] = (
                self.dispatcher.workers[wid].completed_us)
            self._complete_ret(job, now)
            self._ret_jobs[wid] = None
        return "advanced"

    @handoff("server")
    def run(self, max_time_us: float = 4e9) -> Metrics:
        """Run to completion (or the time cutoff) from the current clock.
        On a fresh scheduler with every request pre-loaded this is the
        legacy batch loop, event for event; after streaming ``step()`` /
        mid-run submissions it drains whatever remains."""
        guard = 0
        while True:
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("scheduler stuck — no progress")
            status = self._cycle(hard_cutoff=max_time_us)
            if status in ("done", "cutoff"):
                break
        return self._finalize_metrics()

    @handoff("server")
    def step(self, until_us: float) -> Metrics:
        """Incremental streaming core: advance the event clock to
        ``until_us``, processing every completion/arrival due by then, and
        return with any later-ending jobs still in flight.  Mid-run
        submissions (``add_request`` with ``arrival_us >= self.now``) between
        ``step()`` calls interleave exactly as if they had been pre-loaded."""
        until = float(until_us)
        if until <= self.now:
            # the clock is already at (or past) the horizon: defer
            # admission+assembly to the next cycle, so several submissions
            # stamped with the *same* arrival time — step(t); submit(a, t);
            # step(t); submit(b, t) — are admitted together there, exactly
            # as the batch path admits equal arrivals in one cycle
            self.metrics.sim_time_us = self.now
            return self.metrics
        guard = 0
        while True:
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("scheduler stuck — no progress")
            status = self._cycle(horizon=until)
            if status != "advanced":
                break
            if self.now >= until:
                # the clock just reached the horizon: stop *before* the next
                # cycle's admission+assembly phase, so a submission stamped
                # exactly ``until`` (including one coinciding with the
                # completion we just processed) still joins that assembly —
                # the batch loop admits arrivals ahead of assembly within
                # the same cycle, and fingerprint identity requires the
                # streaming path to preserve that ordering at exact ties
                break
        if until > self.now:
            self.now = until
        self.metrics.sim_time_us = self.now
        return self.metrics

    @handoff("server")
    def drain(self, max_time_us: float = 4e9) -> Metrics:
        """Finish all admitted/in-flight work (streaming shutdown)."""
        return self.run(max_time_us=max_time_us)

    def _finalize_metrics(self) -> Metrics:
        self.metrics.sim_time_us = self.now
        if self.telemetry is not None:
            self.telemetry.finalize(self, self.now)
        hyb = getattr(self.backend, "hybrid", None)
        if hyb is not None:
            self.metrics.cache_stats = hyb.stats()
        self.metrics.replica_routes = self.dispatcher.replica_routes
        self.metrics.dedup_saved_us = float(
            getattr(self.backend, "fused_saved_us", 0.0))
        return self.metrics

    # ----------------------------------------------------------- completion
    def _complete_gen(self, job, now: float) -> None:
        for r in job["reqs"]:
            # rolled back mid-flight: gen was replaced by a fresh progress
            if r.gen is None or r.gen.engine_seq != "inflight":
                continue
            r.gen.engine_seq = None
            if not r.gen.prefilled:
                r.gen.prefilled = True
            r.gen.generated = min(r.gen.generated + job["n_steps"],
                                  r.gen.target_tokens)
            if r.gen.done:
                if r.gen.speculative_src is not None:
                    continue  # wait for retrieval validation
                node = r.graph.nodes.get(r.current)
                if (node is not None
                        and stages.spec_for(node).resource == stages.GEN):
                    self._finish_gen_stage(r, now)

    def _complete_ret(self, job, now: float) -> None:
        plan = job["plan"]
        if plan is not None:
            results = job["results_fn"]()  # item-level BatchTopK scoreboard
            # one vectorized fold: per-group merged top-k + improvement
            # streaks.  Shard-mode partials only need the raw item rows (the
            # gather plan folds them once, at merge time), so an all-shard
            # job skips the fold
            res = (plan.finalize(results)
                   if any(m[0] != "shard" for m in plan.group_meta) else None)
            for g, meta in enumerate(plan.group_meta):
                kind = meta[0]
                kg = int(plan.group_k[g])
                if (self.ft is not None
                        and not self._ft_settle_group(job, g, now)):
                    continue  # fenced duplicate, hedged loser, or retrying
                if kind == "ret":
                    _, r, sn, clusters = meta
                    self._apply_ret_result(r, res, g, kg, plan.k, clusters,
                                           sn, now)
                elif kind == "shard":
                    # one per-shard partial scan: scatter its item rows into
                    # the gather board (original probe order); the last part
                    # to land triggers the k-way merge
                    _, gather, positions = meta
                    gather_scatter_rows(
                        gather.board, positions, results,
                        int(plan.group_start[g]), int(plan.group_start[g + 1]))
                    gather.remaining -= 1
                    if gather.remaining == 0:
                        self._finish_gather(gather, now)
                elif kind == "stage":
                    # plan group owned by a registry stage (e.g. one rewrite
                    # query-variant scan): hand the folded rows to its spec
                    _, r, sp, ref = meta
                    sp.complete_plan_group(self, r, ref, res, g, kg, now)
                else:  # speculative warmup: results land in the LocalCache
                    _, r, emb, probed = meta
                    if r.sim_cache is None:
                        r.sim_cache = LocalCache()
                    r.sim_cache.update(emb, res.group_topk(g, kg), self.index,
                                       probed)
                    self.spec.stats.attempted_ret += 1
        for i, (task, fn) in enumerate(job.get("tasks", ())):
            if (self.ft is not None
                    and not self._ft_settle_task(job, i, now)):
                continue
            stages.spec(task.kind).complete_task(self, task, fn(), now)
