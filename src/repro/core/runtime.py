"""Runtime request state + the sub-node DAG the scheduler transforms.

The static RAGraph unfolds, per request, into a *runtime DAG* of fine-grained
sub-nodes (paper §4.2/§4.5).  Sub-nodes are materialised lazily — the next
slice of a stage is created each scheduling cycle under the current time
budget, which is what makes partitioning "dynamic".  Graph transformations
(transforms.py) mutate this DAG: splitting appends sequentially-dependent
sub-nodes, reordering permutes a retrieval stage's remaining cluster queue,
speculative edges add sub-nodes whose results need validation, and rewiring
re-parents dependants after validation/rollback.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

import numpy as np

from repro.core.ragraph import END, Node, RAGraph
from repro.retrieval.ivf import TopK

_sid_counter = itertools.count()


# ---------------------------------------------------------------------------
# Per-stage progress
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GenProgress:
    target_tokens: int  # known in sim mode; cap in real mode
    generated: int = 0
    engine_seq: Optional[Any] = None  # real-engine sequence handle
    prefilled: bool = False
    started_at: float = -1.0
    speculative_src: Optional[str] = None  # sub-node id speculation is based on
    spec_basis: Optional[np.ndarray] = None  # partial top-k ids used to start
    node_id: Optional[int] = None  # generation node this progress belongs to

    @property
    def done(self) -> bool:
        return self.prefilled and self.generated >= self.target_tokens


@dataclasses.dataclass
class RetProgress:
    query_vec: np.ndarray
    cluster_queue: list[int]  # remaining clusters, in (possibly reordered) order
    topk: TopK
    k: int
    nprobe: int
    searched: list[int] = dataclasses.field(default_factory=list)
    answered_from_cache: bool = False
    early_terminated: bool = False
    started_at: float = -1.0
    # adaptive-termination tracking: clusters since the kth distance improved
    no_improve: int = 0
    last_kth: float = float("inf")

    @property
    def done(self) -> bool:
        return (
            self.answered_from_cache
            or self.early_terminated
            or not self.cluster_queue
        )


@dataclasses.dataclass
class StageProgress:
    """Generic host-side stage progress for registry stage kinds beyond the
    paper's original two (rerank / rewrite / compress / ...).  The scheduler
    treats it as an opaque queue of splittable work units; unit semantics
    (candidate blocks, query variants) belong to the owning StageSpec, which
    keeps spec-private state in ``payload``."""

    kind: str
    work_queue: list  # remaining work units, spec-defined granularity
    total_units: int
    payload: dict = dataclasses.field(default_factory=dict)
    started_at: float = -1.0
    inflight_units: int = 0  # units dispatched, not yet completed
    parked: bool = False  # fused subscriber: completed by the leader

    @property
    def done(self) -> bool:
        return not self.work_queue and self.inflight_units == 0


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestContext:
    request_id: int
    graph: RAGraph
    state: dict  # workflow variables ({"input": ..., outputs of nodes, ...})
    arrival_us: float = 0.0
    slo_us: float = 0.0  # per-request latency SLO; 0 -> scheduler default
    # monotonic admission sequence (scheduler-assigned): breaks pending-heap
    # ties at equal arrival stamps in submission order, so concurrent
    # wall-clock submits replay exactly as they ran
    ingress_seq: Optional[int] = None
    current: Optional[int] = None  # active node id; None before START/after END
    finished: bool = False
    finish_us: float = -1.0
    gen: Optional[GenProgress] = None
    ret: Optional[RetProgress] = None
    stage: Optional[StageProgress] = None  # registry stage kinds beyond gen/ret
    round_idx: int = 0  # retrieval round counter (drives embedder)
    gen_round: int = 0
    # similarity cache (core/similarity.py LocalCache) — one per request
    sim_cache: Any = None
    # event log [(t_us, event, payload)] for latency accounting + the journal
    events: list = dataclasses.field(default_factory=list)

    def log(self, t_us: float, event: str, payload=None):
        self.events.append((t_us, event, payload))

    @property
    def node(self) -> Node:
        assert self.current is not None
        return self.graph.nodes[self.current]

    def advance(self) -> bool:
        """Move to the successor node.  Returns False when the request ends."""
        nxt = self.graph.successor(self.current, self.state)
        self.gen, self.ret, self.stage = None, None, None
        if nxt is END:
            self.current = None
            self.finished = True
            return False
        self.current = int(nxt)
        return True

    def start(self) -> None:
        self.current = self.graph.entry()


# ---------------------------------------------------------------------------
# Sub-node DAG
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SubNode:
    sid: str
    req: RequestContext
    node_id: int
    kind: str  # 'gen' | 'ret'
    payload: dict  # gen: {'n_steps': int}; ret: {'clusters': list[int]}
    deps: set = dataclasses.field(default_factory=set)
    speculative: bool = False
    status: str = "ready"  # ready | running | done | invalid
    result: Any = None

    def __hash__(self):
        return hash(self.sid)


class RuntimeDAG:
    """Materialised sub-nodes of all in-flight requests."""

    def __init__(self):
        self.subnodes: dict[str, SubNode] = {}
        self.spec_edges: list[tuple[str, str]] = []  # (basis sub-node, spec sub-node)

    def new_subnode(self, req: RequestContext, kind: str, payload: dict,
                    deps=(), speculative=False) -> SubNode:
        sid = f"{kind}-{req.request_id}-{next(_sid_counter)}"
        sn = SubNode(sid, req, req.current if req.current is not None else -1,
                     kind, payload, set(deps), speculative)
        self.subnodes[sid] = sn
        return sn

    def add_spec_edge(self, basis: SubNode, spec: SubNode) -> None:
        self.spec_edges.append((basis.sid, spec.sid))

    def ready(self) -> list[SubNode]:
        out = []
        for sn in self.subnodes.values():
            if sn.status != "ready":
                continue
            if all(self.subnodes[d].status == "done" for d in sn.deps
                   if d in self.subnodes):
                out.append(sn)
        return out

    def complete(self, sn: SubNode, result=None) -> None:
        sn.status = "done"
        sn.result = result

    def invalidate(self, sn: SubNode) -> None:
        """Speculation rollback: mark a speculative sub-node (and dependants)
        invalid so the scheduler re-materialises the work."""
        sn.status = "invalid"
        for other in self.subnodes.values():
            if sn.sid in other.deps and other.status in ("ready", "running"):
                self.invalidate(other)

    def rewire(self, sn: SubNode, new_deps: set) -> None:
        sn.deps = set(new_deps)

    def gc(self) -> None:
        """Drop sub-nodes of finished requests (journal keeps the history)."""
        dead = [sid for sid, sn in self.subnodes.items() if sn.req.finished]
        for sid in dead:
            del self.subnodes[sid]
        self.spec_edges = [
            (a, b) for a, b in self.spec_edges
            if a in self.subnodes and b in self.subnodes
        ]
