"""Thread-ownership markers for the serving runtime.

The wall-clock ingress work (ROADMAP item 1) puts ``Server.submit`` on a
real ingress thread while the wavefront loop keeps running on the scheduler
thread.  Today everything runs on one thread, so the markers below are pure
metadata — zero runtime behaviour — but they let ``repro.analysis.lint``
machine-check the discipline *before* the threads arrive:

* ``@owned_by(domain, expose=(...))`` on a class declares which logical
  thread domain owns its mutable state.  ``expose`` names fields that other
  domains may *call through* (read-only projections such as ``metrics`` or
  the obs recorders); everything else is private to the owning domain.
* ``@handoff(*callers)`` on a method declares it a sanctioned cross-domain
  entry point: the listed caller domains (``"*"`` or no argument = any) may
  invoke it from their own threads.  Handoff methods are where locking /
  queue-crossing will land when the ingress thread becomes real.

The static checker (``repro/analysis/lint/ownership.py``) flags any write
or method call that crosses domains without going through a declared
handoff or exposed field.  Keeping the declarations *in the code* rather
than in the analyzer's config means the annotations travel with refactors
and show up in reviews.
"""
from __future__ import annotations

from typing import Callable, Iterable, Type, TypeVar

C = TypeVar("C")
F = TypeVar("F", bound=Callable)


def owned_by(domain: str, *, expose: Iterable[str] = ()) -> Callable[[Type[C]], Type[C]]:
    """Class decorator: all mutable state of the class belongs to ``domain``.

    ``expose`` lists attribute names that constitute the class's read-only
    surface — cross-domain code may call methods *through* them (e.g.
    ``server.sched.metrics.summary()``) without a handoff declaration.
    """
    domain_s = str(domain)
    expose_t = tuple(str(e) for e in expose)

    def mark(cls: Type[C]) -> Type[C]:
        cls.__owner_domain__ = domain_s
        cls.__owner_expose__ = expose_t
        return cls

    return mark


def handoff(*callers: str) -> Callable[[F], F]:
    """Method decorator: a declared cross-domain entry point.

    ``callers`` are the domains allowed to invoke the method from their own
    threads; no arguments (or ``"*"``) means any domain.  The decorator is
    a no-op at runtime — it exists for the static ownership checker and as
    the documented place where synchronisation will be added once the
    ingress thread is real.
    """
    caller_t = tuple(str(c) for c in callers) or ("*",)

    def mark(fn: F) -> F:
        fn.__handoff_callers__ = caller_t
        return fn

    return mark
