"""Execution backends for the wavefront scheduler.

``SimBackend`` — *exact results, modelled time*: retrieval work is executed
for real against the IVF index (and the hot-cache hybrid path, so cache hit
rates and result contents are genuine), but the scheduler is *charged*
calibrated-model durations.  This is how scheduling policies are compared
honestly on a single-CPU container: the paper's CPU∥GPU overlap becomes two
modelled resources with measured cost curves (Fig. 4/6 shapes), while every
search result, cache decision, reorder and speculation validation is real.

``RealBackend`` — wall-clock everything: ties the same scheduler to the JAX
generation engine (serving/engine.py) and the hybrid retrieval engine;
used by the end-to-end examples and integration tests.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.retrieval.hybrid import HybridRetrievalEngine
from repro.retrieval.ivf import ClusterCostModel, IVFIndex, TopK
from repro.retrieval.plan import RetrievalPlan


class SimBackend:
    def __init__(
        self,
        index: IVFIndex,
        embedder,
        *,
        hybrid: Optional[HybridRetrievalEngine] = None,
        cost_model: Optional[ClusterCostModel] = None,
        # generation cost curve (Fig. 4a shape): step(batch) = a + b*batch
        gen_step_base_us: float = 1200.0,
        gen_step_per_seq_us: float = 35.0,
        prefill_us_per_token: float = 8.0,
        gen_noise_sigma: float = 0.20,  # decode-step variation (Fig. 6a)
        # device (hot-cache) search: per-vector speedup + kernel launch cost
        device_speedup: float = 8.0,
        device_launch_us: float = 60.0,
        # fault injection
        straggler_prob: float = 0.0,
        straggler_factor: float = 4.0,
        fault_plan=None,  # serving.faults.FaultPlan: seeded chaos script
        seed: int = 0,
    ):
        self.index = index
        self.embedder = embedder
        self.hybrid = hybrid
        self.cluster_cost_model = cost_model or ClusterCostModel()
        self.gen_step_base_us = gen_step_base_us
        self.gen_step_per_seq_us = gen_step_per_seq_us
        self.prefill_us_per_token = prefill_us_per_token
        self.gen_noise_sigma = gen_noise_sigma
        self.device_speedup = device_speedup
        self.device_launch_us = device_launch_us
        self.straggler_prob = straggler_prob
        self.straggler_factor = straggler_factor
        self.fault_plan = fault_plan
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._sizes = index.cluster_sizes()
        # per-retrieval-worker timing state: independent straggler streams +
        # accumulated busy time, so multi-worker runs expose per-worker
        # stragglers and utilization skew
        self._worker_rng: dict[int, np.random.Generator] = {}
        self.worker_busy_us: dict[int, float] = {}
        # crossreq accounting: modeled cost of the duplicate scans avoided by
        # fused groups (a group with fanout f charges once, not f times)
        self.fused_saved_us = 0.0
        self._lexical = None  # lazily-built lexical channel (hybrid fusion)

    def _rng_for_worker(self, worker_id: int) -> np.random.Generator:
        rng = self._worker_rng.get(worker_id)
        if rng is None:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, worker_id + 1]))
            self._worker_rng[worker_id] = rng
        return rng

    # ----------------------------------------------------------- embeddings
    def query_embedding(self, req, round_idx: int) -> np.ndarray:
        return self.embedder.embed_query(req.request_id, round_idx)

    def partial_embedding(self, req, round_idx: int, ratio: float) -> np.ndarray:
        return self.embedder.embed_partial(req.request_id, round_idx, ratio)

    # ------------------------------------------------------------ generation
    def gen_duration(self, n_prefill_tokens: int, batch: int, n_steps: int) -> float:
        step = self.gen_step_base_us + self.gen_step_per_seq_us * batch
        noise = float(self._rng.lognormal(0.0, self.gen_noise_sigma))
        pre = self.prefill_us_per_token * n_prefill_tokens
        return (step * n_steps) * noise + pre

    # ------------------------------------------------------------- retrieval
    def search_charged(
        self, work, worker_id: int = 0,
    ) -> tuple[float, Callable]:
        """Returns (charged_us, results_fn).

        ``work`` is a :class:`RetrievalPlan` (the SoA sub-stage protocol:
        results_fn() -> the plan's item-level ``BatchTopK`` scoreboard) or a
        legacy per-item work list (results_fn() -> per-item (dists, ids)
        candidate arrays).  The plan path charges over the segment table in
        one vectorized pass, with device residency *snapshotted here* — at
        dispatch time — and threaded into execution, so the charged
        host/device partition and the executed one agree even when cache
        swaps land between dispatch and completion.
        """
        if isinstance(work, RetrievalPlan):
            return self._search_charged_plan(work, worker_id)
        if not work:
            return 0.0, lambda: []
        # --- charge: host clusters at CPU rate, resident clusters at device
        # rate; the two paths overlap (max), matching the paper's engine.
        by_cluster: dict[int, int] = {}
        for _, cid, _ in work:
            by_cluster[cid] = by_cluster.get(cid, 0) + 1
        host_us = dev_us = 0.0
        n_dev = 0
        for cid, nq in by_cluster.items():
            c = self.cluster_cost_model.cost_us(int(self._sizes[cid]), nq)
            if self.hybrid is not None and self.hybrid.cache.is_resident(cid):
                dev_us += c / self.device_speedup
                n_dev += 1
            else:
                host_us += c
        if n_dev:
            dev_us += self.device_launch_us
        charge = max(host_us, dev_us)
        self.worker_busy_us[worker_id] = (
            self.worker_busy_us.get(worker_id, 0.0) + charge)

        # --- execute exactly (records accesses, drives cache updates)
        def results_fn(work=tuple(work)) -> list:
            base = [(q, cid, TopK.empty(tk.k)) for q, cid, tk in work]
            if self.hybrid is not None:
                res, _ = self.hybrid.search_substage(base)
            else:
                res = self.index.search_cluster_batch(base)
            return [(r.dists[r.ids >= 0], r.ids[r.ids >= 0]) for r in res]

        return charge, results_fn

    def _search_charged_plan(
        self, plan: RetrievalPlan, worker_id: int,
    ) -> tuple[float, Callable]:
        """Vectorized charge over the plan's segment table + deferred exact
        execution through the plan executor."""
        seg_sizes = self._sizes[plan.seg_cluster]
        costs = self.cluster_cost_model.cost_vec_us(seg_sizes, plan.seg_counts())
        if self.hybrid is not None:
            # dispatch-time snapshot; in shard mode the executing worker
            # only sees its own slot partition (plus staged replicas)
            owner = worker_id if self.hybrid.sharded else None
            resident = self.hybrid.resident_mask(owner)
            dev = resident[plan.seg_cluster]
            host_us = float(costs[~dev].sum())
            dev_us = float(costs[dev].sum()) / self.device_speedup
            if dev.any():
                dev_us += self.device_launch_us
        else:
            resident = None
            host_us, dev_us = float(costs.sum()), 0.0
        charge = max(host_us, dev_us)
        self.worker_busy_us[worker_id] = (
            self.worker_busy_us.get(worker_id, 0.0) + charge)
        # fused groups are charged once for the whole subscriber set; account
        # the counterfactual cost the extra subscribers would have added,
        # at the rate their clusters would actually have been charged
        # (device-resident clusters at the device rate)
        fan = getattr(plan, "group_fanout", None)
        if fan is not None and fan.size and int(fan.max()) > 1:
            extra = (fan[plan.item_group] - 1).astype(np.float64)
            item_cost = self.cluster_cost_model.cost_vec_us(
                self._sizes[plan.cluster_ids], np.ones(plan.n_items))
            if resident is not None:
                item_cost = np.where(resident[plan.cluster_ids],
                                     item_cost / self.device_speedup,
                                     item_cost)
            self.fused_saved_us += float((item_cost * extra).sum())

        # --- execute exactly (records accesses, drives cache updates); the
        # snapshot rides in the closure so execution partitions like the charge
        def results_fn(plan=plan, resident=resident, worker_id=worker_id):
            if self.hybrid is not None:
                owner = worker_id if self.hybrid.sharded else None
                return self.hybrid.search_plan(plan, resident=resident,
                                               owner=owner)
            return self.index.search_plan(plan)

        return charge, results_fn

    # --------------------------------------------------------- host stages
    def stage_charged(self, task, worker_id: int = 0):
        """Modelled-cost analogue of search_charged for generic host-stage
        work (rerank/compress scoring batches): the scheduler is charged the
        StageSpec's modelled cost while the exact compute is deferred to
        completion time; a fused group charges once for the whole
        subscriber set."""
        charge = float(task.cost_us)
        self.worker_busy_us[worker_id] = (
            self.worker_busy_us.get(worker_id, 0.0) + charge)
        if task.fanout > 1:
            self.fused_saved_us += charge * (task.fanout - 1)
        return charge, task.execute

    def lexical_scores(self, text: str, doc_ids) -> dict:
        """Lexical (term-overlap) channel for dense+lexical hybrid fusion."""
        if self._lexical is None:
            from repro.retrieval.lexical import LexicalScorer
            self._lexical = LexicalScorer()
        return self._lexical.scores(text, doc_ids)

    # ------------------------------------------------------ fault injection
    def maybe_straggle(self, dur: float, worker_id: int = -1) -> float:
        """Per-worker straggler streams: worker_id -1 is the generation
        worker; retrieval workers draw from independent seeded streams so a
        slow worker in one pool slot does not perturb the others."""
        if self.straggler_prob and self._rng_for_worker(worker_id).random() < self.straggler_prob:
            return dur * self.straggler_factor
        return dur

    def fault_latency(self, dur: float, worker_id: int = -1,
                      now_us: float = 0.0) -> float:
        """FaultPlan timing hook: inflate a job's service time by the stall
        window active on its worker at dispatch time.  Applied *after*
        straggler mitigation — injected stalls are what the scheduler's
        timeout/hedging layer must cover, so the straggler cap must not
        silently absorb them.  Identity without a plan."""
        if self.fault_plan is None:
            return dur
        return dur * self.fault_plan.stall_factor(worker_id, now_us)

    def worker_report(self) -> dict:
        """Per-retrieval-worker *modeled charge* (us) accumulated by
        search_charged, before straggler injection/mitigation and including
        speculative warmup items.  The scheduler-side wall occupancy (after
        mitigation) lives in ``Metrics.ret_busy_per_worker``."""
        return dict(sorted(self.worker_busy_us.items()))

    # -------------------------------------------------------- calibration
    @classmethod
    def calibrated(cls, index: IVFIndex, embedder, **kw) -> "SimBackend":
        """Measure the host cluster-search cost curve on this machine."""
        cm = ClusterCostModel.calibrate(index)
        return cls(index, embedder, cost_model=cm, **kw)


class RealBackend:
    """Wall-clock backend: real JAX generation engine + hybrid retrieval."""

    def __init__(self, gen_engine, index: IVFIndex, embedder,
                 hybrid: Optional[HybridRetrievalEngine] = None):
        self.gen_engine = gen_engine
        self.index = index
        self.embedder = embedder
        self.hybrid = hybrid or HybridRetrievalEngine(index, cache_capacity=0)
        self.cluster_cost_model = ClusterCostModel.calibrate(index)
        self._sizes = index.cluster_sizes()
        self.worker_busy_us: dict[int, float] = {}
        # modeled (calibrated cost-curve) estimate of the duplicate scans
        # avoided by crossreq-fused groups; wall time cannot measure work
        # that was never executed.  device_speedup mirrors SimBackend's
        # default so resident clusters are discounted comparably.
        self.fused_saved_us = 0.0
        self.device_speedup = 8.0
        self.fault_plan = None  # chaos scripts target the simulated clock
        self._lexical = None

    def query_embedding(self, req, round_idx: int) -> np.ndarray:
        return self.embedder.embed_query(req.request_id, round_idx)

    def partial_embedding(self, req, round_idx: int, ratio: float) -> np.ndarray:
        return self.embedder.embed_partial(req.request_id, round_idx, ratio)

    def gen_duration(self, n_prefill_tokens: int, batch: int, n_steps: int) -> float:
        """Execute n_steps of real decoding on the engine; return measured us.
        The scheduler passes the request set via bind_gen_batch beforehand."""
        # RealBackend measures *actual* execution; the virtual clock only
        # advances by these measured durations, so reading the wall clock
        # here is the sanctioned boundary between real and virtual time.
        t0 = time.perf_counter()  # repro-lint: disable=wall-clock
        self.gen_engine.step_batch(n_steps)
        return (time.perf_counter() - t0) * 1e6  # repro-lint: disable=wall-clock

    def search_charged(self, work, worker_id: int = 0):
        if isinstance(work, RetrievalPlan):
            fan = work.group_fanout
            if fan.size and int(fan.max()) > 1:
                extra = (fan[work.item_group] - 1).astype(np.float64)
                item_cost = self.cluster_cost_model.cost_vec_us(
                    self._sizes[work.cluster_ids], np.ones(work.n_items))
                # same residency discount as SimBackend so the two report
                # comparable savings (device-resident clusters are cheap)
                resident = self.hybrid.resident_mask(
                    worker_id if self.hybrid.sharded else None)
                item_cost = np.where(resident[work.cluster_ids],
                                     item_cost / self.device_speedup,
                                     item_cost)
                self.fused_saved_us += float((item_cost * extra).sum())
            # real-time measurement boundary (see gen_duration)
            t0 = time.perf_counter()  # repro-lint: disable=wall-clock
            batch = self.hybrid.search_plan(
                work, owner=worker_id if self.hybrid.sharded else None)
            measured = (time.perf_counter() - t0) * 1e6  # repro-lint: disable=wall-clock
            self.worker_busy_us[worker_id] = (
                self.worker_busy_us.get(worker_id, 0.0) + measured)
            return measured, lambda: batch
        if not work:
            return 0.0, lambda: []
        # real-time measurement boundary (see gen_duration)
        t0 = time.perf_counter()  # repro-lint: disable=wall-clock
        base = [(q, cid, TopK.empty(tk.k)) for q, cid, tk in work]
        res, timing = self.hybrid.search_substage(base)
        out = [(r.dists[r.ids >= 0], r.ids[r.ids >= 0]) for r in res]
        measured = (time.perf_counter() - t0) * 1e6  # repro-lint: disable=wall-clock
        self.worker_busy_us[worker_id] = (
            self.worker_busy_us.get(worker_id, 0.0) + measured)
        return measured, lambda: out

    def stage_charged(self, task, worker_id: int = 0):
        """Wall-clock host-stage execution: run the batch now, charge the
        measured time, hand completion a closure over the result."""
        if task.fanout > 1:
            self.fused_saved_us += float(task.cost_us) * (task.fanout - 1)
        # real-time measurement boundary (see gen_duration)
        t0 = time.perf_counter()  # repro-lint: disable=wall-clock
        result = task.execute()
        measured = (time.perf_counter() - t0) * 1e6  # repro-lint: disable=wall-clock
        self.worker_busy_us[worker_id] = (
            self.worker_busy_us.get(worker_id, 0.0) + measured)
        return measured, lambda: result

    def lexical_scores(self, text: str, doc_ids) -> dict:
        if self._lexical is None:
            from repro.retrieval.lexical import LexicalScorer
            self._lexical = LexicalScorer()
        return self._lexical.scores(text, doc_ids)

    def maybe_straggle(self, dur: float, worker_id: int = -1) -> float:
        return dur

    def fault_latency(self, dur: float, worker_id: int = -1,
                      now_us: float = 0.0) -> float:
        if self.fault_plan is None:
            return dur
        return dur * self.fault_plan.stall_factor(worker_id, now_us)

    def worker_report(self) -> dict:
        return dict(sorted(self.worker_busy_us.items()))
